//! Protein library design: the downstream workflow the paper targets.
//!
//! Generates a candidate library with SpecMER, scores every sequence by
//! target-model NLL and the pLDDT foldability proxy, filters to the most
//! plausible designs (the paper's "top-20" protocol), and writes them as
//! FASTA with per-sequence annotations plus a diversity report.
//!
//!     cargo run --release --example library_design -- [--protein GB1]
//!         [--library 40] [--keep 10] [--out library.fa]

use specmer::config::Method;
use specmer::coordinator::engine_for_bench;
use specmer::decode::GenConfig;
use specmer::eval::diversity;
use specmer::kmer::KmerSet;
use specmer::msa::fasta::Record;
use specmer::util::cli::Args;
use specmer::util::stats;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let (engine, _real) = engine_for_bench();
    let protein = args.str_or("protein", &engine.families()[0].meta.name);
    let library = args.usize_or("library", 40)?;
    let keep = args.usize_or("keep", 10)?;
    let out_path = args.str_or("out", "library.fa");

    let fam = engine.family(&protein)?;
    let scorer = fam.plddt_scorer();
    let wt = fam.wt_tokens.clone();
    println!(
        "designing a library for {protein} ({} residues, MSA depth {})",
        fam.meta.length, fam.meta.msa_depth
    );

    // 1. generate candidates with SpecMER
    let cfg = GenConfig {
        gamma: 5,
        c: 5,
        temp: 1.0,
        top_p: 0.95,
        kset: KmerSet::new(true, true, true),
        max_len: 10_000,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let mut designs = Vec::new();
    // resolve the per-sequence scoring plan (family context + k-mer table
    // handle) once; the library loop only varies the seed
    let mut spec = engine.spec(&protein, Method::SpecMer, &cfg)?;
    for i in 0..library {
        spec.cfg.seed = 1000 + i as u64;
        let out = engine.generate(&spec)?;
        let nll = engine.score_nll(&out.tokens)?;
        let residues: Vec<u8> = out
            .tokens
            .iter()
            .copied()
            .filter(|&t| specmer::tokenizer::is_residue(t))
            .collect();
        let plddt = scorer.score(&residues);
        designs.push((residues, nll, plddt, out.acceptance_ratio()));
    }
    let gen_s = t0.elapsed().as_secs_f64();
    println!(
        "generated {library} candidates in {gen_s:.1}s ({:.1} seq/min)",
        library as f64 / gen_s * 60.0
    );

    // 2. rank: primary = NLL (lower better), tiebreak pLDDT (higher better)
    designs.sort_by(|a, b| {
        (a.1 - 2.0 * a.2)
            .partial_cmp(&(b.1 - 2.0 * b.2))
            .unwrap()
    });
    let kept = &designs[..keep.min(designs.len())];

    // 3. report
    let all_nll: Vec<f64> = designs.iter().map(|d| d.1).collect();
    let kept_nll: Vec<f64> = kept.iter().map(|d| d.1).collect();
    let kept_plddt: Vec<f64> = kept.iter().map(|d| d.2).collect();
    println!("\nlibrary NLL      : {}", stats::pm(&all_nll, 3));
    println!("kept NLL         : {}", stats::pm(&kept_nll, 3));
    println!("kept pLDDT-proxy : {}", stats::pm(&kept_plddt, 3));
    let seqs: Vec<Vec<u8>> = kept.iter().map(|d| d.0.clone()).collect();
    let wt_d = diversity::wt_distances(&wt, &seqs);
    let inter = diversity::inter_seq_distances(&seqs, 200, 1);
    println!("WT Hamming dist  : {}", stats::pm(&wt_d, 1));
    println!("inter-seq dist   : {}", stats::pm(&inter, 1));

    // 4. write FASTA
    let records: Vec<Record> = kept
        .iter()
        .enumerate()
        .map(|(i, (res, nll, plddt, acc))| Record {
            id: format!("{protein}_design_{i} nll={nll:.3} plddt={plddt:.3} accept={acc:.3}"),
            seq: specmer::tokenizer::decode(res),
        })
        .collect();
    specmer::msa::fasta::write_path(std::path::Path::new(&out_path), &records)?;
    println!("\nwrote {} designs to {out_path}", records.len());
    Ok(())
}
