//! Quickstart: load the engine, generate a handful of sequences with all
//! three decoding methods, and print what SpecMER buys you.
//!
//!     cargo run --release --example quickstart
//!
//! Uses `artifacts/` if built (`make artifacts`), otherwise a synthetic
//! fallback engine so the example always runs.

use specmer::config::Method;
use specmer::coordinator::engine_for_bench;
use specmer::decode::GenConfig;
use specmer::kmer::KmerSet;

fn main() -> anyhow::Result<()> {
    let (engine, real) = engine_for_bench();
    let protein = engine.families()[0].meta.name.clone();
    println!(
        "engine: {} | protein: {protein} (context {} residues)\n",
        if real { "AOT artifacts via PJRT" } else { "synthetic fallback" },
        engine.family(&protein)?.meta.context,
    );

    let cfg = GenConfig {
        gamma: 5,
        c: 3,
        temp: 1.0,
        top_p: 0.95,
        kset: KmerSet::new(true, true, false),
        max_len: 10_000,
        seed: 7,
        ..Default::default()
    };

    for method in [Method::TargetOnly, Method::Speculative, Method::SpecMer] {
        let t0 = std::time::Instant::now();
        let out = engine.generate_for(&protein, method, &cfg)?;
        let dt = t0.elapsed().as_secs_f64();
        let nll = engine.score_nll(&out.tokens)?;
        println!(
            "{:<12} {:>6.1} tok/s  accept={:.3}  nll={:.3}\n  {}\n",
            method.label(),
            out.new_tokens() as f64 / dt,
            out.acceptance_ratio(),
            nll,
            &specmer::tokenizer::decode(&out.tokens)
        );
    }
    println!("speculative ≈ target-distributed but faster; specmer adds k-mer guidance\n(see EXPERIMENTS.md for the full paper reproduction)");
    Ok(())
}
