//! End-to-end serving driver (the paper's motivating workload, §1):
//! a high-throughput screening campaign fires batches of generation
//! requests at the full serving stack — HTTP server → router → dynamic
//! batcher → worker engines running speculative decoding — and reports
//! latency percentiles, throughput and acceptance, for SpecMER vs the
//! target-only baseline.
//!
//!     cargo run --release --example high_throughput_screening [-- --n 40]
//!
//! Results from this driver are recorded in EXPERIMENTS.md §End-to-end.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use specmer::config::Config;
use specmer::coordinator::{
    engine_for_bench, EngineFactory, FamilyRegistry, Metrics, Router, Scheduler,
};
use specmer::util::cli::Args;
use specmer::util::json::Json;
use specmer::util::stats;

fn http_post(addr: std::net::SocketAddr, path: &str, body: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    out.split("\r\n\r\n")
        .nth(1)
        .map(|b| b.to_string())
        .ok_or_else(|| anyhow::anyhow!("bad http response"))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let n_per_protein = args.usize_or("n", 20)?;
    let methods = ["specmer", "speculative", "target"];

    // --- stand up the full serving stack in-process --------------------
    let metrics = Arc::new(Metrics::new());
    let factory: EngineFactory = Arc::new(|| Ok(engine_for_bench().0));
    let sched = Arc::new(Scheduler::start(
        1, // single-core testbed; bump --workers on real hardware
        8,
        std::time::Duration::from_millis(2),
        factory,
        Arc::clone(&metrics),
    ));
    // the router resolves per-sequence SeqSpecs at submission; a throwaway
    // probe engine supplies the same family set the workers will load
    let (probe, _) = engine_for_bench();
    let proteins: Vec<String> =
        probe.families().iter().map(|f| f.meta.name.clone()).take(3).collect();
    let registry = Arc::new(FamilyRegistry::new(probe.families().to_vec()));
    drop(probe);
    let router = Arc::new(Router::new(sched, registry));
    let cfg = Config { port: 0, ..Default::default() };
    let server = specmer::server::serve(&cfg, Arc::clone(&router), Arc::clone(&metrics))?;
    println!("serving stack up at http://{}\n", server.addr);

    println!("screening campaign: {} proteins x {n_per_protein} seqs x {} methods", proteins.len(), methods.len());
    println!("{:-<72}", "");
    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>9} {:>9}",
        "method", "seqs", "tok/s", "accept", "p50 (s)", "wall (s)"
    );

    for method in methods {
        let t0 = Instant::now();
        let mut tokens = 0f64;
        let mut decode_s = 0f64;
        let mut accepts = Vec::new();
        let mut p50s = Vec::new();
        let mut n_seqs = 0usize;
        for protein in &proteins {
            let body = format!(
                r#"{{"protein":"{protein}","method":"{method}","n":{n_per_protein},"c":3,"gamma":5,"seed":11}}"#
            );
            let resp = http_post(server.addr, "/generate", &body)?;
            let j = Json::parse(&resp).map_err(|e| anyhow::anyhow!("{e}: {resp}"))?;
            if let Some(err) = j.get("error") {
                anyhow::bail!("server error: {err}");
            }
            n_seqs += j.get("sequences").unwrap().as_arr().unwrap().len();
            tokens += j.get("tokens").unwrap().as_f64().unwrap();
            let tps = j.get("tokens_per_second").unwrap().as_f64().unwrap();
            if tps > 0.0 {
                decode_s += j.get("tokens").unwrap().as_f64().unwrap() / tps;
            }
            accepts.push(j.get("acceptance_ratio").unwrap().as_f64().unwrap());
            p50s.push(j.get("latency_p50").unwrap().as_f64().unwrap());
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<12} {:>10} {:>10.1} {:>10.3} {:>9.3} {:>9.1}",
            method,
            n_seqs,
            if decode_s > 0.0 { tokens / decode_s } else { 0.0 },
            stats::mean(&accepts),
            stats::mean(&p50s),
            wall
        );
    }

    println!("{:-<72}", "");
    println!("\nserver metrics after the campaign:\n");
    println!("{}", metrics.text_dump());
    server.stop();
    Ok(())
}
