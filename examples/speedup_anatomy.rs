//! Anatomy of the speedup: measures each phase of a SpecMER round (draft
//! dispatch, k-mer scoring, verify dispatch, coupling) and compares the
//! observed end-to-end speedup against the paper's analytic bounds
//! (Eq. 1 and Appendix-A Eq. 9) evaluated with the measured α and c_e.
//!
//!     cargo run --release --example speedup_anatomy -- [--n 10]

use specmer::config::Method;
use specmer::coordinator::engine_for_bench;
use specmer::decode::GenConfig;
use specmer::kmer::{score_block, KmerSet};
use specmer::theory;
use specmer::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let n = args.usize_or("n", 10)?;
    let (engine, _real) = engine_for_bench();
    let protein = engine.families()[0].meta.name.clone();
    let kset = KmerSet::new(true, true, false);

    // --- per-method throughput -----------------------------------------
    let mut tps = std::collections::BTreeMap::new();
    let mut alpha = 0.0;
    for (label, method, c) in [
        ("draft", Method::DraftOnly, 1usize),
        ("target", Method::TargetOnly, 1),
        ("spec c=1", Method::Speculative, 1),
        ("specmer c=3", Method::SpecMer, 3),
    ] {
        let mut tokens = 0usize;
        let mut accepts = Vec::new();
        let t0 = std::time::Instant::now();
        for i in 0..n {
            let cfg = GenConfig {
                gamma: 5,
                c,
                kset,
                max_len: 10_000,
                seed: 100 + i as u64,
                ..Default::default()
            };
            let out = engine.generate_for(&protein, method, &cfg)?;
            tokens += out.new_tokens();
            if method == Method::Speculative {
                accepts.push(out.acceptance_ratio());
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        tps.insert(label, tokens as f64 / dt);
        if method == Method::Speculative {
            alpha = specmer::util::stats::mean(&accepts);
        }
        println!("{label:<12} {:>8.1} tok/s", tokens as f64 / dt);
    }

    // --- k-mer scoring really is near-zero cost (paper §3.2) ------------
    let table = &engine.family(&protein)?.table;
    let cand: Vec<u8> = specmer::tokenizer::encode("MKTAYIAKQRVLKGE");
    let t0 = std::time::Instant::now();
    let iters = 200_000;
    let mut acc = 0f32;
    for _ in 0..iters {
        acc += score_block(table, &cand[..5], kset);
    }
    let kmer_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("\nk-mer score of a γ=5 block: {kmer_ns:.0} ns (sum={acc:.1})");

    // --- bounds ----------------------------------------------------------
    let c_e = tps["target"] / tps["draft"]; // M_p/M_q as a time ratio
    let measured = tps["spec c=1"] / tps["target"];
    println!("\nmeasured: α={alpha:.3}  c_e={c_e:.3}  speedup={measured:.2}x");
    for gamma in [5usize, 10, 15] {
        let eq1 = theory::speedup_eq1(alpha, gamma, c_e);
        let eq9 = theory::speedup_eq9(alpha, gamma, theory::c_draft(c_e * gamma as f64, kmer_ns * 1e-9, 1.0));
        println!("  γ={gamma:<3} Eq.1 bound={eq1:.2}x  Eq.9 (batched)={eq9:.2}x");
    }
    println!("\n(measured speedup should sit at or below the bounds; see EXPERIMENTS.md)");
    Ok(())
}
