# SpecMER repo verification entry points.
#
#   make verify       hygiene gates (rustfmt check + clippy -D warnings),
#                     tier-1 (release build + tests), the same test suite
#                     again with SPECMER_FORCE_PORTABLE=1 (both SIMD
#                     dispatch arms must stay green — the kernels pin
#                     bitwise equality between them), the tree-speculation
#                     suites as a named gate (degenerate chain-shaped trees
#                     bitwise-identical to the flat driver, the seeded
#                     distribution-identity test for genuine branching, and
#                     the lockstep degenerate-tree batch pin), plus a
#                     bench_micro smoke run, which writes machine-readable
#                     round and kernel latencies — including the
#                     scalar-vs-vectorized GEMM and prepacked-logits-head
#                     speedups, the batched-vs-serial B=4 decode
#                     throughput, and the tree-vs-flat acceptance entry —
#                     to rust/results/bench_micro.json (cargo runs bench
#                     binaries from the package root), so perf regressions
#                     on the draft/verify/serving hot paths show up there,
#                     not just in prose.
#   make test-tree    just the tree-structured speculation suites.
#   make bench-micro  full (non-smoke) micro benches.

CARGO ?= cargo

.PHONY: verify fmt-check lint build test test-portable test-tree bench-smoke bench-micro

verify: fmt-check lint build test test-portable test-tree bench-smoke

fmt-check:
	$(CARGO) fmt --check

lint:
	$(CARGO) clippy -q -- -D warnings

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# force the portable chunked-lane kernels (the dispatcher consumes the env
# var once per process) so the non-AVX2 arm stays green everywhere
test-portable:
	SPECMER_FORCE_PORTABLE=1 $(CARGO) test -q

# the tree-structured speculation suites, named so the bitwise degenerate
# pin and the seeded distribution-identity test stay visible gates (they
# also run as part of `test`; SPECMER_FORCE_PORTABLE in the environment
# switches both invocations to the portable kernel arm)
test-tree:
	$(CARGO) test -q --test tree_speculation
	$(CARGO) test -q --test batch_decode_equivalence lockstep_degenerate_tree

bench-smoke:
	SPECMER_BENCH_SMOKE=1 $(CARGO) bench --bench bench_micro

bench-micro:
	$(CARGO) bench --bench bench_micro
