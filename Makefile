# SpecMER repo verification entry points.
#
#   make verify       hygiene gates (rustfmt check + clippy -D warnings),
#                     tier-1 (release build + tests), the same test suite
#                     again with SPECMER_FORCE_PORTABLE=1 (both SIMD
#                     dispatch arms must stay green — the kernels pin
#                     bitwise equality between them), plus a bench_micro
#                     smoke run, which writes machine-readable round and
#                     kernel latencies — including the scalar-vs-vectorized
#                     GEMM and prepacked-logits-head speedups and the
#                     batched-vs-serial B=4 decode throughput — to
#                     rust/results/bench_micro.json (cargo runs bench
#                     binaries from the package root), so perf regressions
#                     on the draft/verify/serving hot paths show up there,
#                     not just in prose.
#   make bench-micro  full (non-smoke) micro benches.

CARGO ?= cargo

.PHONY: verify fmt-check lint build test test-portable bench-smoke bench-micro

verify: fmt-check lint build test test-portable bench-smoke

fmt-check:
	$(CARGO) fmt --check

lint:
	$(CARGO) clippy -q -- -D warnings

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# force the portable chunked-lane kernels (the dispatcher consumes the env
# var once per process) so the non-AVX2 arm stays green everywhere
test-portable:
	SPECMER_FORCE_PORTABLE=1 $(CARGO) test -q

bench-smoke:
	SPECMER_BENCH_SMOKE=1 $(CARGO) bench --bench bench_micro

bench-micro:
	$(CARGO) bench --bench bench_micro
