# SpecMER repo verification entry points.
#
#   make verify       tier-1 (release build + tests) plus a bench_micro
#                     smoke run, which writes machine-readable round
#                     latencies to rust/results/bench_micro.json (cargo
#                     runs bench binaries from the package root) — perf
#                     regressions on the draft/verify hot paths show up
#                     there, not just in prose.
#   make bench-micro  full (non-smoke) micro benches.

CARGO ?= cargo

.PHONY: verify build test bench-smoke bench-micro

verify: build test bench-smoke

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench-smoke:
	SPECMER_BENCH_SMOKE=1 $(CARGO) bench --bench bench_micro

bench-micro:
	$(CARGO) bench --bench bench_micro
