# SpecMER repo verification entry points.
#
#   make verify       hygiene gates (rustfmt check + clippy -D warnings),
#                     tier-1 (release build + tests), the same test suite
#                     again with SPECMER_FORCE_PORTABLE=1 (both SIMD
#                     dispatch arms must stay green — the kernels pin
#                     bitwise equality between them), the tree-speculation
#                     suites as a named gate (degenerate chain-shaped trees
#                     bitwise-identical to the flat driver, the seeded
#                     distribution-identity test for genuine branching, and
#                     the lockstep degenerate-tree batch pin), plus a
#                     bench_micro smoke run, which writes machine-readable
#                     round and kernel latencies — including the
#                     scalar-vs-vectorized GEMM and prepacked-logits-head
#                     speedups, the batched-vs-serial B=4 decode
#                     throughput, and the tree-vs-flat acceptance entry —
#                     to rust/results/bench_micro.json (cargo runs bench
#                     binaries from the package root), so perf regressions
#                     on the draft/verify/serving hot paths show up there,
#                     not just in prose.
#   make test-tree    just the tree-structured speculation suites.
#   make test-prefix  the shared-prefix KV cache gates: the prefix_*
#                     bitwise pins (cache-hit admission, chunked prefill,
#                     eviction mid-stream) plus the prefix-store and
#                     prefill-cache unit tests. Part of `verify`.
#   make test-fast    the SPECMER_FAST tier: the accuracy-bounded suites
#                     (quantization pins, fast-tier ulp/tolerance bounds)
#                     plus the self-comparing equivalence suites under
#                     SPECMER_FAST=1 (lockstep and tree pins compare the
#                     model against itself, so they must hold within any
#                     one tier; the f32-scalar-reference pins stay on the
#                     default tier, which is the only bitwise one).
#   make test-bf16    the same env-robust suites under
#                     SPECMER_WEIGHT_DTYPE=bf16 (the narrow-dtype arm of
#                     the CI matrix; per-dtype bitwise contract).
#   make bench-micro  full (non-smoke) micro benches.
#   make bench-serve-smoke  open-loop serving-stack load smoke (fixed seed,
#                     trivial load; pins the results/bench_serve.json
#                     schema and zero sheds / zero deadline misses). Part
#                     of `verify`; `make bench-serve` is the full 2x-
#                     overload run. See docs/serving.md.
#   make lint-specmer the repo-native static analyzer (rust/lint): SAFETY
#                     comments on every unsafe, no nondeterminism in
#                     runtime/decode, the bitwise-accumulation contract in
#                     the kernels, no panics and no unbounded growth
#                     primitives on the serving path, module headers.
#                     Policy: docs/unsafe-policy.md.

CARGO ?= cargo

.PHONY: verify fmt-check lint lint-specmer build test test-portable test-tree test-fast \
	test-prefix test-bf16 bench-smoke bench-micro bench-serve-smoke bench-serve

verify: fmt-check lint lint-specmer build test test-portable test-tree test-fast test-prefix \
	bench-smoke bench-serve-smoke

fmt-check:
	$(CARGO) fmt --check

# clippy at -D warnings, plus the unsafe-hygiene gates backing
# docs/unsafe-policy.md (the crate root also sets
# #![deny(unsafe_op_in_unsafe_fn)] so local builds catch it without clippy)
lint:
	$(CARGO) clippy -q -- -D warnings \
		-D clippy::undocumented_unsafe_blocks \
		-D unsafe_op_in_unsafe_fn

# repo-native rules clippy can't express (see docs/unsafe-policy.md)
lint-specmer:
	$(CARGO) run -q -p specmer-lint

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# force the portable chunked-lane kernels (the dispatcher consumes the env
# var once per process) so the non-AVX2 arm stays green everywhere
test-portable:
	SPECMER_FORCE_PORTABLE=1 $(CARGO) test -q

# the tree-structured speculation suites, named so the bitwise degenerate
# pin and the seeded distribution-identity test stay visible gates (they
# also run as part of `test`; SPECMER_FORCE_PORTABLE in the environment
# switches both invocations to the portable kernel arm)
test-tree:
	$(CARGO) test -q --test tree_speculation
	$(CARGO) test -q --test batch_decode_equivalence lockstep_degenerate_tree

# the fast tier is accuracy-bounded, not bitwise: run its dedicated bound
# suites plus the suites that compare the model against itself (those pins
# hold within any single tier) with SPECMER_FAST=1 in the environment; the
# scalar-reference pins (cpu_batched_equivalence, kernel_equivalence) are
# exact-tier-only by design and keep running in `test`/`test-portable`
test-fast:
	SPECMER_FAST=1 $(CARGO) test -q --test quantization --test fast_tier
	SPECMER_FAST=1 $(CARGO) test -q --test batch_decode_equivalence --test tree_speculation

# the shared-prefix KV cache gates, named so the copy-on-write hit,
# chunked-prefill, and eviction-mid-stream bitwise pins stay visible (they
# also run as part of `test`): the prefix_* equivalence pins plus the
# prefix-store / prefill-cache / CoW unit tests in the library
test-prefix:
	$(CARGO) test -q --test batch_decode_equivalence prefix_
	$(CARGO) test -q --lib prefix

# narrow-dtype arm: the bitwise contract is per dtype (AVX2 == portable ==
# dequant oracle), not vs the f32 tier, so the same env-robust suites run
# with bf16 weight panels selected by env
test-bf16:
	SPECMER_WEIGHT_DTYPE=bf16 $(CARGO) test -q --test quantization --test fast_tier
	SPECMER_WEIGHT_DTYPE=bf16 $(CARGO) test -q --test batch_decode_equivalence --test tree_speculation

bench-smoke:
	SPECMER_BENCH_SMOKE=1 $(CARGO) bench --bench bench_micro

bench-micro:
	$(CARGO) bench --bench bench_micro

# serving-stack load harness smoke: fixed-seed open-loop run at trivial
# load; asserts the results/bench_serve.json schema and that nothing was
# shed and no deadline was missed (docs/serving.md)
bench-serve-smoke:
	SPECMER_BENCH_SMOKE=1 $(CARGO) bench --bench bench_serve

# full open-loop run: calibrates the sustainable rate, then offers 2x it —
# the stack must shed (bounded queues) instead of growing memory
bench-serve:
	$(CARGO) bench --bench bench_serve
