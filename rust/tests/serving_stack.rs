//! Integration tests over the full serving stack (scheduler + router +
//! batcher + HTTP) using the synthetic engine — plus, when artifacts are
//! present, one end-to-end pass over the real PJRT engine.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use specmer::config::{Config, Method};
use specmer::coordinator::engine::{synthetic_engine, synthetic_families};
use specmer::coordinator::{EngineFactory, FamilyRegistry, GenEngine, Metrics, Router, Scheduler};
use specmer::decode::GenConfig;
use specmer::util::json::Json;

fn stack(workers: usize) -> (Arc<Router>, Arc<Metrics>) {
    let metrics = Arc::new(Metrics::new());
    let factory: EngineFactory =
        Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
    let sched = Arc::new(Scheduler::start(
        workers,
        4,
        Duration::from_millis(1),
        factory,
        Arc::clone(&metrics),
    ));
    let registry = Arc::new(FamilyRegistry::new(synthetic_families(3)));
    (Arc::new(Router::new(sched, registry)), metrics)
}

#[test]
fn burst_of_mixed_requests_completes() {
    let (router, metrics) = stack(2);
    let (tx, rx) = channel();
    let n = 24;
    for i in 0..n {
        let protein = if i % 2 == 0 { "SynA" } else { "SynB" };
        let method = match i % 3 {
            0 => Method::TargetOnly,
            1 => Method::Speculative,
            _ => Method::SpecMer,
        };
        router.submit(
            protein,
            method,
            GenConfig { max_len: 24, seed: i as u64, c: 2, ..Default::default() },
            tx.clone(),
        );
    }
    drop(tx);
    let mut ok = 0;
    for resp in rx.iter() {
        assert!(resp.result.is_ok(), "{:?}", resp.result.err());
        assert!(resp.latency >= resp.decode_seconds * 0.99);
        ok += 1;
    }
    assert_eq!(ok, n);
    assert_eq!(metrics.completed.load(Ordering::Relaxed), n as u64);
    assert!(metrics.tokens_per_second() > 0.0);
    assert!(metrics.latency_percentile(99.0) >= metrics.latency_percentile(50.0));
}

#[test]
fn same_seed_same_sequence_across_workers() {
    // routing must not change results: generation is engine-deterministic
    let (router, _m) = stack(3);
    let collect = |router: &Router| -> Vec<String> {
        let (tx, rx) = channel();
        for _ in 0..3 {
            router.submit(
                "SynA",
                Method::SpecMer,
                GenConfig { max_len: 24, seed: 9, c: 3, ..Default::default() },
                tx.clone(),
            );
        }
        drop(tx);
        rx.iter().map(|r| r.sequence()).collect()
    };
    let seqs = collect(&router);
    assert!(seqs.iter().all(|s| s == &seqs[0]), "{seqs:?}");
}

#[test]
fn http_server_full_roundtrip_with_metrics() {
    let (router, metrics) = stack(1);
    let cfg = Config { port: 0, ..Default::default() };
    let handle = specmer::server::serve(&cfg, router, Arc::clone(&metrics)).unwrap();

    let post = |path: &str, body: &str| -> String {
        let mut s = TcpStream::connect(handle.addr).unwrap();
        write!(
            s,
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };
    let r = post(
        "/generate",
        r#"{"protein":"SynB","method":"speculative","n":3,"gamma":5,"seed":4}"#,
    );
    assert!(r.contains("200 OK"), "{r}");
    let j = Json::parse(r.split("\r\n\r\n").nth(1).unwrap()).unwrap();
    let seqs = j.get("sequences").unwrap().as_arr().unwrap();
    assert_eq!(seqs.len(), 3);
    for s in seqs {
        assert!(!s.as_str().unwrap().is_empty());
    }
    // metrics reflect the traffic
    let mut s = TcpStream::connect(handle.addr).unwrap();
    write!(s, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    assert!(out.contains("specmer_completed_total 3"), "{out}");
    assert!(out.contains("specmer_cross_key_admitted_total"), "{out}");
    assert!(out.contains("specmer_group_distinct_proteins_avg"), "{out}");
    handle.stop();
}

#[test]
fn throughput_under_sustained_load() {
    // smoke the batcher's grouping: many same-protein requests should
    // complete without starving the odd-protein ones submitted after.
    let (router, _m) = stack(1);
    let (tx, rx) = channel();
    for i in 0..10 {
        router.submit(
            "SynA",
            Method::Speculative,
            GenConfig { max_len: 20, seed: i, ..Default::default() },
            tx.clone(),
        );
    }
    router.submit(
        "SynB",
        Method::Speculative,
        GenConfig { max_len: 20, seed: 99, ..Default::default() },
        tx.clone(),
    );
    drop(tx);
    let t0 = Instant::now();
    let mut got_b = false;
    let mut count = 0;
    for resp in rx.iter() {
        count += 1;
        if &*resp.protein == "SynB" {
            got_b = true;
        }
    }
    assert_eq!(count, 11);
    assert!(got_b, "cross-protein request starved");
    assert!(t0.elapsed() < Duration::from_secs(60));
}

#[test]
fn real_artifacts_through_the_stack() {
    // End-to-end over PJRT when artifacts exist (skips otherwise).
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let metrics = Arc::new(Metrics::new());
    let cfg = Config { artifacts: dir, ..Default::default() };
    let registry = Arc::new(FamilyRegistry::load(&cfg.artifacts).unwrap());
    let cfg2 = cfg.clone();
    let reg2 = Arc::clone(&registry);
    let factory: EngineFactory = Arc::new(move || {
        specmer::coordinator::build_engine_with(&cfg2, reg2.families().to_vec())
    });
    let sched = Arc::new(Scheduler::start(
        1,
        4,
        Duration::from_millis(1),
        factory,
        Arc::clone(&metrics),
    ));
    let router = Router::new(sched, registry);
    let (tx, rx) = channel();
    for i in 0..3u64 {
        router.submit(
            "GB1",
            Method::SpecMer,
            GenConfig { max_len: 60, seed: i, c: 3, ..Default::default() },
            tx.clone(),
        );
    }
    drop(tx);
    for resp in rx.iter() {
        let out = resp.result.expect("generation over PJRT");
        assert!(out.new_tokens() > 0);
        assert!(out.acceptance_ratio() > 0.2);
    }
    assert!(metrics.acceptance_ratio() > 0.2);
}
