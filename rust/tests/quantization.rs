//! Quantization-layer pins for the dtype-tagged weight panels
//! (`params::Panel`) and the fused dequant-in-register GEMM kernels
//! (`gemm::matmul_panel`), using the in-repo mini-proptest.
//!
//! Contract under test (see the `runtime` module docs):
//!
//!   * bf16/f16 round-trips are **exact** on representable values, and the
//!     conversions round to nearest-even elsewhere;
//!   * int8 per-row scales reconstruct every element within one scale step
//!     (|x − q·s| ≤ s/2 ≤ one scale-ulp), with zero rows and single-element
//!     rows exact;
//!   * for a fixed dtype, the AVX2 arm, the portable arm, and the oracle
//!     `matmul` over the dequantized panel are **bitwise-equal** — the
//!     narrow tiers trade values once at quantization, never per arm —
//!     across lane-tail widths that don't divide the SIMD lane count.

use specmer::params::{
    bf16_to_f32, f16_to_f32, f32_to_bf16, f32_to_f16, Panel, PanelRef, WeightDtype,
};
use specmer::runtime::gemm;
use specmer::runtime::simd::Kernel;
use specmer::util::proptest::{check, Gen};

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

// ---------------------------------------------------------------------------
// Scalar conversion pins
// ---------------------------------------------------------------------------

#[test]
fn bf16_round_trip_exact_on_representable_values() {
    check("bf16 representable round-trip", 200, |g| {
        // construct a representable bf16 by truncating a random f32
        let x = g.f64_in(-1e6..1e6) as f32;
        let h = f32_to_bf16(x);
        let back = bf16_to_f32(h);
        // back is representable by construction: converting again is lossless
        assert_eq!(f32_to_bf16(back), h);
        assert_eq!(bf16_to_f32(f32_to_bf16(back)).to_bits(), back.to_bits());
        // rounding moved x by at most one bf16 ulp (2^-8 relative)
        if x.is_finite() && x != 0.0 {
            assert!(((back - x) / x).abs() <= 1.0 / 256.0, "{x} -> {back}");
        }
    });
}

#[test]
fn f16_round_trip_exact_on_representable_values() {
    check("f16 representable round-trip", 200, |g| {
        // keep inside the f16 normal range so quantization can't saturate
        let x = g.f64_in(-60000.0..60000.0) as f32;
        let h = f32_to_f16(x);
        let back = f16_to_f32(h);
        assert_eq!(f32_to_f16(back), h, "{x} -> {h:#06x} -> {back}");
        assert_eq!(f16_to_f32(f32_to_f16(back)).to_bits(), back.to_bits());
        // f16 has a 10-bit stored mantissa: normals round within 2^-11 rel.
        if x.abs() >= 6.2e-5 {
            assert!(((back - x) / x).abs() <= 1.0 / 2048.0, "{x} -> {back}");
        }
    });
}

#[test]
fn f16_edge_values_pin() {
    // exact cardinal values of the binary16 format
    assert_eq!(f32_to_f16(0.0), 0x0000);
    assert_eq!(f32_to_f16(-0.0), 0x8000);
    assert_eq!(f32_to_f16(1.0), 0x3c00);
    assert_eq!(f32_to_f16(-2.0), 0xc000);
    assert_eq!(f32_to_f16(65504.0), 0x7bff); // largest finite half
    assert_eq!(f32_to_f16(65520.0), 0x7c00); // rounds up to +inf
    assert_eq!(f32_to_f16(1e9), 0x7c00); // overflow → +inf
    assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
    assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xfc00);
    assert_eq!(f32_to_f16(6.103_515_6e-5), 0x0400); // smallest normal half
    assert_eq!(f32_to_f16(5.960_464_5e-8), 0x0001); // smallest subnormal half
    assert_eq!(f32_to_f16(1e-10), 0x0000); // below half-subnormal → +0
    assert_eq!(f16_to_f32(0x0001), 5.960_464_5e-8);
    assert_eq!(f16_to_f32(0x0400), 6.103_515_6e-5);
    assert_eq!(f16_to_f32(0x3c00), 1.0);
    assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
    assert!(f16_to_f32(0x7e00).is_nan());
    assert!(f32_to_f16(f32::NAN) & 0x7c00 == 0x7c00 && f32_to_f16(f32::NAN) & 0x03ff != 0);
}

#[test]
fn bf16_edge_values_pin() {
    assert_eq!(bf16_to_f32(f32_to_bf16(0.0)), 0.0);
    assert_eq!(f32_to_bf16(1.0), 0x3f80);
    assert_eq!(bf16_to_f32(0x3f80), 1.0);
    // round-to-nearest-even at the halfway point: 1.0 + 2^-9 is exactly
    // between two bf16 values and must round to the even mantissa (1.0)
    let halfway = f32::from_bits(0x3f80_8000);
    assert_eq!(f32_to_bf16(halfway), 0x3f80);
    // one ulp above halfway rounds up
    assert_eq!(f32_to_bf16(f32::from_bits(0x3f80_8001)), 0x3f81);
    assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
    let qnan = f32_to_bf16(f32::NAN);
    assert!(qnan & 0x7f80 == 0x7f80 && qnan & 0x007f != 0, "NaN must stay NaN: {qnan:#06x}");
}

// ---------------------------------------------------------------------------
// Panel::quantize pins
// ---------------------------------------------------------------------------

#[test]
fn int8_per_row_scale_reconstruction_within_one_scale_step() {
    check("int8 row reconstruction", 120, |g| {
        let k = g.usize_in(1..8);
        let n = g.usize_in(1..40);
        let w: Vec<f32> = (0..k * n).map(|_| g.f64_in(-3.0..3.0) as f32).collect();
        let p = Panel::quantize(&w, k, n, WeightDtype::Int8);
        let back = p.to_f32(k, n);
        let scales = match &p {
            Panel::Int8 { scales, .. } => scales.clone(),
            _ => unreachable!(),
        };
        for i in 0..k {
            let s = scales[i];
            let row = &w[i * n..(i + 1) * n];
            let maxabs = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            assert!((s - maxabs / 127.0).abs() <= f32::EPSILON * maxabs, "scale formula");
            for (j, (&x, &r)) in row.iter().zip(&back[i * n..(i + 1) * n]).enumerate() {
                // round-to-nearest quantization: within half a scale step,
                // padded to one step to absorb the f32 rounding of x·inv
                assert!(
                    (x - r).abs() <= s * 0.5 + s * 1e-3,
                    "row {i} col {j}: {x} vs {r} (scale {s})"
                );
            }
        }
    });
}

#[test]
fn int8_zero_row_and_single_element_edge_cases() {
    // an all-zero row gets scale 0 and reconstructs exactly
    let w = vec![0.0f32; 6];
    let p = Panel::quantize(&w, 2, 3, WeightDtype::Int8);
    assert_eq!(p.to_f32(2, 3), w);
    match &p {
        Panel::Int8 { q, scales } => {
            assert!(q.iter().all(|&x| x == 0));
            assert_eq!(scales, &vec![0.0, 0.0]);
        }
        _ => unreachable!(),
    }
    // a single-element row is its own maxabs: reconstructs exactly (q=±127)
    let w = vec![0.75f32, -1.5];
    let p = Panel::quantize(&w, 2, 1, WeightDtype::Int8);
    let back = p.to_f32(2, 1);
    assert!((back[0] - 0.75).abs() < 1e-6);
    assert!((back[1] + 1.5).abs() < 1e-6);
    // mixed: one zero row between nonzero rows stays exact
    let w = vec![1.0f32, 2.0, 0.0, 0.0, -4.0, 3.0];
    let p = Panel::quantize(&w, 3, 2, WeightDtype::Int8);
    let back = p.to_f32(3, 2);
    assert_eq!(&back[2..4], &[0.0, 0.0]);
}

#[test]
fn narrow_dtype_dequant_is_exact_for_16bit_floats() {
    check("bf16/f16 panel dequant exact", 60, |g| {
        let k = g.usize_in(1..6);
        let n = g.usize_in(1..30);
        let w: Vec<f32> = (0..k * n).map(|_| g.f64_in(-2.0..2.0) as f32).collect();
        for dtype in [WeightDtype::Bf16, WeightDtype::F16] {
            let p = Panel::quantize(&w, k, n, dtype);
            let d1 = p.to_f32(k, n);
            // dequantized values are representable: re-quantizing loses nothing
            let p2 = Panel::quantize(&d1, k, n, dtype);
            let d2 = p2.to_f32(k, n);
            assert!(bits_eq(&d1, &d2), "{dtype:?} second trip changed bits");
        }
    });
}

#[test]
fn panel_weight_bytes_accounting() {
    let w = vec![0.5f32; 4 * 10];
    assert_eq!(Panel::quantize(&w, 4, 10, WeightDtype::F32).weight_bytes(), 160);
    assert_eq!(Panel::quantize(&w, 4, 10, WeightDtype::Bf16).weight_bytes(), 80);
    assert_eq!(Panel::quantize(&w, 4, 10, WeightDtype::F16).weight_bytes(), 80);
    // int8: 40 q bytes + 4 row scales × 4 bytes
    assert_eq!(Panel::quantize(&w, 4, 10, WeightDtype::Int8).weight_bytes(), 56);
}

// ---------------------------------------------------------------------------
// Fused-kernel bitwise pins (per dtype, across arms and lane tails)
// ---------------------------------------------------------------------------

/// For every dtype: the AVX2 arm, the portable arm, and the oracle f32
/// `matmul` over `Panel::to_f32` agree bitwise, across shapes straddling
/// the 8-lane and 16-column tile boundaries, both skip modes, and inputs
/// with exact zeros (the skip edge).
#[test]
fn fused_dequant_kernels_bitwise_equal_across_arms() {
    check("panel kernels bitwise equal", 60, |g| {
        let m = g.usize_in(1..7);
        let k = g.usize_in(1..24);
        let n = g.usize_in(1..52); // crosses 8/16 tiles and scalar tails
        let a: Vec<f32> = (0..m * k)
            .map(|_| if g.f64_in(0.0..1.0) < 0.25 { 0.0 } else { g.f64_in(-2.0..2.0) as f32 })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| g.f64_in(-2.0..2.0) as f32).collect();
        for dtype in
            [WeightDtype::F32, WeightDtype::Bf16, WeightDtype::F16, WeightDtype::Int8]
        {
            let p = Panel::quantize(&w, k, n, dtype);
            let dense = p.to_f32(k, n);
            for skip in [true, false] {
                // oracle: the bitwise-pinned f32 kernel over the dequantized
                // panel (same per-element order as the fused kernels)
                let mut want = vec![0.0f32; m * n];
                if skip {
                    gemm::matmul_st_with(Kernel::Portable, &a, &dense, m, k, n, &mut want);
                } else {
                    gemm::matmul_dense_st_with(Kernel::Portable, &a, &dense, m, k, n, &mut want);
                }
                for kernel in [Kernel::Avx2, Kernel::Portable] {
                    let mut got = vec![0.0f32; m * n];
                    gemm::matmul_panel_st_with(
                        kernel,
                        &a,
                        p.view(),
                        m,
                        k,
                        n,
                        &mut got,
                        skip,
                        false,
                    );
                    assert!(
                        bits_eq(&got, &want),
                        "{dtype:?} {kernel:?} skip={skip} ({m},{k},{n})"
                    );
                }
            }
        }
    });
}

/// Threaded `matmul_panel` must match the single-threaded kernel bitwise
/// (row partitioning keeps each element's serial accumulator), including
/// for narrow panels on a shape large enough to engage the pool.
#[test]
fn threaded_panel_matmul_bitwise_equal_single_thread() {
    let (m, k, n) = (16usize, 256usize, 520usize);
    let mut g = Gen::new(17);
    let a: Vec<f32> = (0..m * k).map(|_| g.f64_in(-1.0..1.0) as f32).collect();
    let w: Vec<f32> = (0..k * n).map(|_| g.f64_in(-1.0..1.0) as f32).collect();
    for dtype in [WeightDtype::F32, WeightDtype::Bf16, WeightDtype::Int8] {
        let p = Panel::quantize(&w, k, n, dtype);
        let mut par = vec![0.0f32; m * n];
        gemm::matmul_panel(&a, p.view(), m, k, n, &mut par, true, false);
        let mut st = vec![0.0f32; m * n];
        gemm::matmul_panel_st_with(
            specmer::runtime::simd::active(),
            &a,
            p.view(),
            m,
            k,
            n,
            &mut st,
            true,
            false,
        );
        assert!(bits_eq(&par, &st), "{dtype:?} row partitioning changed bits");
    }
}

/// `matmul_panel` over an f32 panel with the fast tier off must be
/// byte-identical to the plain `matmul`/`matmul_dense` hot path it routes
/// through — the no-env-set compatibility guarantee.
#[test]
fn f32_panel_routes_through_exact_hot_path() {
    let (m, k, n) = (5usize, 33usize, 47usize);
    let mut g = Gen::new(23);
    let a: Vec<f32> = (0..m * k)
        .map(|_| if g.f64_in(0.0..1.0) < 0.3 { 0.0 } else { g.f64_in(-2.0..2.0) as f32 })
        .collect();
    let w: Vec<f32> = (0..k * n).map(|_| g.f64_in(-2.0..2.0) as f32).collect();
    let pr = PanelRef::F32(&w);
    let mut via_panel = vec![0.0f32; m * n];
    gemm::matmul_panel(&a, pr, m, k, n, &mut via_panel, true, false);
    let mut direct = vec![0.0f32; m * n];
    gemm::matmul(&a, &w, m, k, n, &mut direct);
    assert!(bits_eq(&via_panel, &direct), "skip route");
    let mut via_panel_d = vec![0.0f32; m * n];
    gemm::matmul_panel(&a, pr, m, k, n, &mut via_panel_d, false, false);
    let mut direct_d = vec![0.0f32; m * n];
    gemm::matmul_dense(&a, &w, m, k, n, &mut direct_d);
    assert!(bits_eq(&via_panel_d, &direct_d), "dense route");
}
