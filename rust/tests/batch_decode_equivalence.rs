//! Equivalence suite for cross-request lockstep decoding (ISSUE 2
//! tentpole): `speculative_generate_batch` over B mixed-length requests
//! must yield, per sequence, exactly the tokens and accept/reject/bonus
//! counts of B separate `speculative_generate` calls with the same seeds.
//! The batched path shares draft dispatches of `[B·c, D]` rows and ragged
//! verify dispatches, so this pins the whole stack: ragged forward, cache
//! arena, per-sequence RNG streams, and mid-flight drop-out of finished
//! sequences.
//!
//! The `prefix_*` tests extend the suite to the shared-prefix KV cache
//! (worker-resident `runtime::prefix_store`): a cache-hit admission that
//! attaches cached rows copy-on-write, a chunked cold prefill spread over
//! round boundaries, and an eviction landing mid-stream must all leave
//! every token stream bitwise identical to cold solo runs.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use specmer::coordinator::engine::synthetic_engine;
use specmer::coordinator::GenEngine;
use specmer::config::Method;
use specmer::decode::{
    speculative_generate, speculative_generate_batch, speculative_generate_continuous,
    speculative_generate_continuous_with, AdmissionHook, AdmitItem, GenConfig, GenOutput,
    LockstepShape, PrefixParams, SpecBatchItem, TreePolicy,
};
use specmer::kmer::{KmerSet, KmerTable};
use specmer::msa::simulate::generate_family;
use specmer::runtime::cpu_ref::CpuModel;
use specmer::runtime::PrefixStore;
use specmer::tokenizer::BOS;

fn cfg(c: usize, gamma: usize, seed: u64, max_len: usize) -> GenConfig {
    GenConfig {
        c,
        gamma,
        seed,
        max_len,
        kset: KmerSet::new(true, true, true),
        ..Default::default()
    }
}

/// The acceptance-criterion scenario: B=4 requests with different context
/// lengths, seeds and max_lens — sequences finish at different rounds, so
/// the batch shrinks mid-flight — against independent sequential runs.
#[test]
fn lockstep_b4_mixed_lengths_equals_sequential() {
    let (_prof, msa) = generate_family("T", 40, 30, 5);
    let table = Arc::new(KmerTable::build(&msa));
    // distinct draft/target so rejections and corrections actually occur
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);

    let ctxs: [&[u8]; 4] = [
        &[BOS, 5, 9],
        &[BOS, 7],
        &[BOS, 5, 9, 13, 7, 4],
        &[BOS, 11, 3],
    ];
    let cfgs = [
        cfg(3, 5, 3, 40),
        cfg(3, 5, 11, 24), // shortest: drops out while others continue
        cfg(3, 5, 21, 48),
        cfg(3, 5, 33, 36),
    ];

    let solo: Vec<_> = ctxs
        .iter()
        .zip(&cfgs)
        .map(|(ctx, cfg)| speculative_generate(&d, &t, Some(&table), ctx, cfg).unwrap())
        .collect();
    let items: Vec<SpecBatchItem<'_>> = ctxs
        .iter()
        .zip(&cfgs)
        .map(|(ctx, cfg)| SpecBatchItem { context: ctx, cfg, table: Some(table.clone()) })
        .collect();
    let batch = speculative_generate_batch(&d, &t, &items);

    // the mixed max_lens must actually produce mixed-length outputs, or the
    // drop-out path was never exercised
    let lens: Vec<usize> = solo.iter().map(|o| o.tokens.len()).collect();
    assert!(
        lens.iter().any(|&l| l != lens[0]),
        "test setup: sequences should finish at different lengths ({lens:?})"
    );

    for (b, (got, want)) in batch.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("lockstep item failed");
        assert_eq!(got.tokens, want.tokens, "seq {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "seq {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "seq {b}: rejected");
        assert_eq!(got.bonus, want.bonus, "seq {b}: bonus");
        assert_eq!(got.rounds, want.rounds, "seq {b}: rounds");
        assert_eq!(got.draft_calls, want.draft_calls, "seq {b}: draft calls");
        assert_eq!(got.target_calls, want.target_calls, "seq {b}: target calls");
        assert!(
            (got.online_nll_sum - want.online_nll_sum).abs() < 1e-9,
            "seq {b}: online NLL"
        );
    }
}

/// Vanilla speculative decoding (c = 1, no table) through the same batch
/// machinery.
#[test]
fn lockstep_c1_no_table_equals_sequential() {
    let d = CpuModel::synthetic(2, 16, 2, 96, 17);
    let t = CpuModel::synthetic(2, 16, 2, 96, 18);
    let ctxs: [&[u8]; 3] = [&[BOS, 5], &[BOS, 5, 9, 13], &[BOS, 2, 4]];
    let cfgs = [cfg(1, 5, 1, 40), cfg(1, 5, 2, 32), cfg(1, 5, 3, 44)];
    let solo: Vec<_> = ctxs
        .iter()
        .zip(&cfgs)
        .map(|(ctx, cfg)| speculative_generate(&d, &t, None, ctx, cfg).unwrap())
        .collect();
    let items: Vec<SpecBatchItem<'_>> = ctxs
        .iter()
        .zip(&cfgs)
        .map(|(ctx, cfg)| SpecBatchItem { context: ctx, cfg, table: None })
        .collect();
    let batch = speculative_generate_batch(&d, &t, &items);
    for (b, (got, want)) in batch.iter().zip(&solo).enumerate() {
        assert_eq!(got.as_ref().unwrap().tokens, want.tokens, "seq {b} diverged");
    }
}

/// A batch of one degenerates to exactly the sequential engine.
#[test]
fn lockstep_b1_is_the_sequential_engine() {
    let d = CpuModel::synthetic(2, 16, 2, 96, 27);
    let t = CpuModel::synthetic(2, 16, 2, 96, 28);
    let ctx: &[u8] = &[BOS, 5, 9];
    let c = cfg(2, 5, 9, 40);
    let want = speculative_generate(&d, &t, None, ctx, &c).unwrap();
    let got = speculative_generate_batch(
        &d,
        &t,
        &[SpecBatchItem { context: ctx, cfg: &c, table: None }],
    );
    assert_eq!(got.len(), 1);
    let out = got[0].as_ref().unwrap();
    assert_eq!(out.tokens, want.tokens);
    assert_eq!(out.accepted, want.accepted);
}

/// The degenerate-tree acceptance criterion (ISSUE 6): a lockstep batch
/// whose shape carries a `branch == 1` chain-shaped [`TreePolicy`] runs the
/// *tree* round driver — `draft_tree` forests, root-to-leaf path scoring,
/// `verify_tree` with trunk re-feeding — and must still be bitwise
/// identical to solo *flat* decodes with the same seeds.
#[test]
fn lockstep_degenerate_tree_equals_flat_sequential() {
    let (_prof, msa) = generate_family("T", 40, 30, 5);
    let table = Arc::new(KmerTable::build(&msa));
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);

    let ctxs: [&[u8]; 3] = [&[BOS, 5, 9], &[BOS, 7], &[BOS, 5, 9, 13, 7, 4]];
    let flat_cfgs = [cfg(3, 5, 3, 40), cfg(3, 5, 11, 24), cfg(3, 5, 21, 48)];
    let chain = TreePolicy { branch: 1, split_mask: 0b110 };
    let mut tree_cfgs = flat_cfgs.clone();
    for c in &mut tree_cfgs {
        c.tree = chain;
    }

    // the oracle is the *flat* sequential engine — no tree code at all
    let solo: Vec<_> = ctxs
        .iter()
        .zip(&flat_cfgs)
        .map(|(ctx, cfg)| speculative_generate(&d, &t, Some(&table), ctx, cfg).unwrap())
        .collect();
    let items: Vec<SpecBatchItem<'_>> = ctxs
        .iter()
        .zip(&tree_cfgs)
        .map(|(ctx, cfg)| SpecBatchItem { context: ctx, cfg, table: Some(table.clone()) })
        .collect();
    let batch = speculative_generate_batch(&d, &t, &items);

    for (b, (got, want)) in batch.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("degenerate-tree item failed");
        assert_eq!(got.tokens, want.tokens, "seq {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "seq {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "seq {b}: rejected");
        assert_eq!(got.bonus, want.bonus, "seq {b}: bonus");
        assert_eq!(got.rounds, want.rounds, "seq {b}: rounds");
        assert_eq!(got.tree_nodes, want.tree_nodes, "seq {b}: nodes drafted");
    }
}

/// Scripted admission source for the continuous-batching driver: each item
/// joins the group once its arrival boundary is reached; the hook records
/// how many sequences were in flight at each admission.
struct Scripted {
    pending: Vec<(usize, AdmitItem)>,
    boundary: usize,
    active_at_admission: Vec<usize>,
    done: Vec<(u64, anyhow::Result<GenOutput>)>,
}

impl AdmissionHook for Scripted {
    fn admit(&mut self, active: usize) -> Vec<AdmitItem> {
        let b = self.boundary;
        self.boundary += 1;
        let (now, later): (Vec<_>, Vec<_>) = self.pending.drain(..).partition(|(at, _)| *at <= b);
        self.pending = later;
        for _ in &now {
            self.active_at_admission.push(active);
        }
        now.into_iter().map(|(_, item)| item).collect()
    }
    fn complete(&mut self, ticket: u64, result: anyhow::Result<GenOutput>) {
        self.done.push((ticket, result));
    }
}

/// [`Scripted`] plus a scripted mid-flight cancellation: once `boundary`
/// reaches `cancel_after`, `cancel_ticket` is handed back to the driver at
/// the round boundary — the same path the coordinator's deadline
/// enforcement uses — recording how many sequences were resident.
struct CancelScripted {
    inner: Scripted,
    cancel_ticket: u64,
    cancel_after: usize,
    active_at_cancel: Option<usize>,
}

impl AdmissionHook for CancelScripted {
    fn admit(&mut self, active: usize) -> Vec<AdmitItem> {
        self.inner.admit(active)
    }
    fn complete(&mut self, ticket: u64, result: anyhow::Result<GenOutput>) {
        self.inner.complete(ticket, result);
    }
    fn cancel(&mut self, resident: &[u64]) -> Vec<(u64, anyhow::Error)> {
        if self.active_at_cancel.is_none()
            && self.inner.boundary >= self.cancel_after
            && resident.contains(&self.cancel_ticket)
        {
            self.active_at_cancel = Some(resident.len());
            return vec![(self.cancel_ticket, anyhow::anyhow!("cancelled by test"))];
        }
        Vec::new()
    }
}

/// The mid-flight cancellation acceptance criterion (serving hardening):
/// cancelling one resident sequence at a round boundary — exactly what the
/// coordinator's deadline enforcement does — retires it through the
/// group's normal completion path and leaves every surviving batchmate's
/// token stream (and accept/reject/round stats) bitwise identical to its
/// solo run. Per-sequence RNG and caches make removal indistinguishable
/// from an early natural finish.
#[test]
fn mid_flight_cancellation_leaves_batchmates_bitwise_identical() {
    let (_prof, msa) = generate_family("T", 40, 30, 5);
    let table = Arc::new(KmerTable::build(&msa));
    // distinct draft/target so rejections and corrections actually occur
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);

    let ctxs: [&[u8]; 3] = [&[BOS, 5, 9], &[BOS, 7], &[BOS, 11, 3]];
    // the doomed request (ticket 1) would run longest; it is cancelled at
    // the third round boundary, well before its natural finish
    let cfgs = [cfg(3, 5, 3, 40), cfg(3, 5, 11, 96), cfg(3, 5, 33, 44)];

    let solo: Vec<_> = ctxs
        .iter()
        .zip(&cfgs)
        .map(|(ctx, cfg)| speculative_generate(&d, &t, Some(&table), ctx, cfg).unwrap())
        .collect();

    let mut hook = CancelScripted {
        inner: Scripted {
            pending: ctxs
                .iter()
                .zip(&cfgs)
                .enumerate()
                .map(|(i, (ctx, cfg))| {
                    let item = AdmitItem {
                        ticket: i as u64,
                        context: ctx.to_vec(),
                        cfg: cfg.clone(),
                        table: Some(table.clone()),
                    };
                    (0usize, item)
                })
                .collect(),
            boundary: 0,
            active_at_admission: Vec::new(),
            done: Vec::new(),
        },
        cancel_ticket: 1,
        cancel_after: 3,
        active_at_cancel: None,
    };
    speculative_generate_continuous(&d, &t, LockstepShape::of(&cfgs[0]), &mut hook);

    // the cancellation must have happened with batchmates resident, or the
    // mid-group removal path was never exercised
    let resident = hook.active_at_cancel.expect("cancellation never fired");
    assert!(resident >= 2, "cancel fired with no batchmates resident ({resident})");

    assert_eq!(hook.inner.done.len(), 3, "every request answered, cancelled included");
    hook.inner.done.sort_by_key(|(ticket, _)| *ticket);
    for (b, ((ticket, got), want)) in hook.inner.done.iter().zip(&solo).enumerate() {
        if *ticket == 1 {
            let err = got.as_ref().expect_err("cancelled sequence must error");
            assert!(format!("{err:#}").contains("cancelled by test"), "{err:#}");
            continue;
        }
        let got = got.as_ref().expect("surviving batchmate failed");
        assert_eq!(got.tokens, want.tokens, "survivor {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "survivor {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "survivor {b}: rejected");
        assert_eq!(got.bonus, want.bonus, "survivor {b}: bonus");
        assert_eq!(got.rounds, want.rounds, "survivor {b}: rounds");
        assert_eq!(got.draft_calls, want.draft_calls, "survivor {b}: draft calls");
        assert_eq!(got.target_calls, want.target_calls, "survivor {b}: target calls");
    }
}

/// The continuous-batching acceptance criterion: requests admitted into an
/// in-flight lockstep group at round boundaries emit token streams (and
/// accept/reject/bonus/round stats) bitwise-identical to solo decodes with
/// the same seed — resident sequences' RNG streams are never perturbed by
/// admission, and late joiners behave exactly as if they had started alone.
#[test]
fn round_boundary_admission_equals_sequential() {
    let (_prof, msa) = generate_family("T", 40, 30, 5);
    let table = Arc::new(KmerTable::build(&msa));
    // distinct draft/target so rejections and corrections actually occur
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);

    let ctxs: [&[u8]; 4] = [
        &[BOS, 5, 9],
        &[BOS, 7],
        &[BOS, 5, 9, 13, 7, 4],
        &[BOS, 11, 3],
    ];
    let cfgs = [
        cfg(3, 5, 3, 48),
        cfg(3, 5, 11, 40),
        cfg(3, 5, 21, 48), // joins two rounds in
        cfg(3, 5, 33, 44), // joins three rounds in
    ];
    // max_len >= 40 with gamma 5 guarantees every sequence runs well past
    // boundary 3, so the late arrivals genuinely join an in-flight group
    let arrivals = [0usize, 1, 2, 3];

    let solo: Vec<_> = ctxs
        .iter()
        .zip(&cfgs)
        .map(|(ctx, cfg)| speculative_generate(&d, &t, Some(&table), ctx, cfg).unwrap())
        .collect();

    let mut hook = Scripted {
        pending: arrivals
            .iter()
            .zip(ctxs.iter().zip(&cfgs))
            .enumerate()
            .map(|(i, (&at, (ctx, cfg)))| {
                let item = AdmitItem {
                    ticket: i as u64,
                    context: ctx.to_vec(),
                    cfg: cfg.clone(),
                    table: Some(table.clone()),
                };
                (at, item)
            })
            .collect(),
        boundary: 0,
        active_at_admission: Vec::new(),
        done: Vec::new(),
    };
    speculative_generate_continuous(&d, &t, LockstepShape::of(&cfgs[0]), &mut hook);

    // the late arrivals must have found residents in flight, or this test
    // never exercised mid-flight admission
    assert_eq!(hook.active_at_admission.len(), 4);
    assert!(
        hook.active_at_admission[1..].iter().any(|&a| a > 0),
        "no admission happened mid-flight: {:?}",
        hook.active_at_admission
    );

    assert_eq!(hook.done.len(), 4, "every admitted request completed");
    hook.done.sort_by_key(|(ticket, _)| *ticket);
    for (b, ((_, got), want)) in hook.done.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("admitted item failed");
        assert_eq!(got.tokens, want.tokens, "seq {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "seq {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "seq {b}: rejected");
        assert_eq!(got.bonus, want.bonus, "seq {b}: bonus");
        assert_eq!(got.rounds, want.rounds, "seq {b}: rounds");
        assert_eq!(got.draft_calls, want.draft_calls, "seq {b}: draft calls");
        assert_eq!(got.target_calls, want.target_calls, "seq {b}: target calls");
    }
}

type Store = Rc<RefCell<PrefixStore>>;

/// Prefix-store pair (draft, target) with `cap` bytes each, plus the
/// [`PrefixParams`] handing them to the continuous driver.
fn prefix_params(cap: usize, chunk: usize) -> (PrefixParams, Store, Store) {
    let ds = Rc::new(RefCell::new(PrefixStore::new(cap)));
    let ts = Rc::new(RefCell::new(PrefixStore::new(cap)));
    let params = PrefixParams {
        draft_store: Some(Rc::clone(&ds)),
        target_store: Some(Rc::clone(&ts)),
        prefill_chunk: chunk,
    };
    (params, ds, ts)
}

fn admit_at(
    at: usize,
    ticket: u64,
    ctx: &[u8],
    cfg: &GenConfig,
    table: &Arc<KmerTable>,
) -> (usize, AdmitItem) {
    let item = AdmitItem {
        ticket,
        context: ctx.to_vec(),
        cfg: cfg.clone(),
        table: Some(table.clone()),
    };
    (at, item)
}

/// Prefix-cache pin 1: a warm admission — the second request with the same
/// family context attaches the first one's cached KV copy-on-write instead
/// of recomputing prefill — must be bitwise identical to a cold solo run,
/// and the savings must show up in `prefill_tokens`.
#[test]
fn prefix_cache_hit_admission_matches_cold_solo() {
    let (_prof, msa) = generate_family("T", 40, 30, 5);
    let table = Arc::new(KmerTable::build(&msa));
    // distinct draft/target so rejections and corrections actually occur
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);
    let ctx: &[u8] = &[BOS, 5, 9, 13, 7];
    let cfgs = [cfg(3, 5, 3, 40), cfg(3, 5, 11, 36)];
    let solo: Vec<_> = cfgs
        .iter()
        .map(|c| speculative_generate(&d, &t, Some(&table), ctx, c).unwrap())
        .collect();

    let (params, ds, ts) = prefix_params(1 << 20, 0);
    let mut hook = Scripted {
        pending: cfgs
            .iter()
            .enumerate()
            .map(|(i, c)| admit_at(i, i as u64, ctx, c, &table))
            .collect(),
        boundary: 0,
        active_at_admission: Vec::new(),
        done: Vec::new(),
    };
    let shape = LockstepShape::of(&cfgs[0]);
    speculative_generate_continuous_with(&d, &t, shape, &mut hook, params);

    for st in [&ds, &ts] {
        let s = st.borrow().stats();
        assert_eq!((s.hits, s.misses), (1, 1), "cold miss then warm hit per store");
    }
    assert_eq!(hook.done.len(), 2);
    hook.done.sort_by_key(|(ticket, _)| *ticket);
    let n_feed = ctx.len() as u64 - 1;
    for (b, ((_, got), want)) in hook.done.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("prefix-cache item failed");
        assert_eq!(got.tokens, want.tokens, "seq {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "seq {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "seq {b}: rejected");
        assert_eq!(got.rounds, want.rounds, "seq {b}: rounds");
        // cold admission prefilled both models; the warm one computed nothing
        let want_prefill = if b == 0 { 2 * n_feed } else { 0 };
        assert_eq!(got.prefill_tokens, want_prefill, "seq {b}: prefill_tokens");
    }
}

/// Prefix-cache pin 2: a cold long context admitted with `prefill_chunk`
/// set is prefilled in slices across round boundaries — and the resulting
/// stream must be bitwise identical to a one-shot solo prefill (row-count
/// independence of the kernels, RNG untouched until activation).
#[test]
fn prefix_chunked_prefill_matches_one_shot_solo() {
    let (_prof, msa) = generate_family("T", 40, 30, 5);
    let table = Arc::new(KmerTable::build(&msa));
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);
    let ctx: Vec<u8> = vec![BOS, 5, 9, 13, 4, 8, 15, 6, 10, 3, 12, 7];
    let cfgs = [cfg(3, 5, 3, 44), cfg(3, 5, 11, 40)];
    let solo: Vec<_> = cfgs
        .iter()
        .map(|c| speculative_generate(&d, &t, Some(&table), &ctx, c).unwrap())
        .collect();

    // chunk 3 over n_feed 11: the cold admission spans four round
    // boundaries before activating; the second request (boundary 4) then
    // hits the snapshot the chunked prefill published
    let (params, ds, ts) = prefix_params(1 << 20, 3);
    let mut hook = Scripted {
        pending: vec![
            admit_at(0, 0, &ctx, &cfgs[0], &table),
            admit_at(4, 1, &ctx, &cfgs[1], &table),
        ],
        boundary: 0,
        active_at_admission: Vec::new(),
        done: Vec::new(),
    };
    let shape = LockstepShape::of(&cfgs[0]);
    speculative_generate_continuous_with(&d, &t, shape, &mut hook, params);

    for st in [&ds, &ts] {
        let s = st.borrow().stats();
        assert_eq!((s.hits, s.misses), (1, 1), "chunked prefill still publishes");
    }
    assert_eq!(hook.done.len(), 2);
    hook.done.sort_by_key(|(ticket, _)| *ticket);
    for (b, ((_, got), want)) in hook.done.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("chunk-admitted item failed");
        assert_eq!(got.tokens, want.tokens, "seq {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "seq {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "seq {b}: rejected");
        assert_eq!(got.rounds, want.rounds, "seq {b}: rounds");
    }
}

/// Prefix-cache pin 3: evicting an entry while a sequence decodes from its
/// copy-on-write attachment must not perturb that sequence — the snapshot
/// `Arc` stays alive through the attachment, eviction only drops the
/// store's reference.
#[test]
fn prefix_eviction_mid_stream_leaves_attached_sequences_intact() {
    let (_prof, msa) = generate_family("T", 40, 30, 5);
    let table = Arc::new(KmerTable::build(&msa));
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);
    let ctx_a: &[u8] = &[BOS, 5, 9, 13, 7];
    let ctx_b: &[u8] = &[BOS, 11, 3, 6];
    // ticket 1 (warm, attached) runs longest: the ctx_b admission at
    // boundary 3 inserts a second entry and evicts ctx_a mid-stream
    let cfgs = [cfg(3, 5, 3, 36), cfg(3, 5, 11, 48), cfg(3, 5, 33, 32)];
    let ctxs: [&[u8]; 3] = [ctx_a, ctx_a, ctx_b];
    let solo: Vec<_> = ctxs
        .iter()
        .zip(&cfgs)
        .map(|(ctx, c)| speculative_generate(&d, &t, Some(&table), ctx, c).unwrap())
        .collect();

    // capacity fits exactly one snapshot per store (synthetic dims: 2
    // layers x 2 x 2 heads x 96 positions x 8 dims x 4 bytes = 24576), so
    // the second insert must evict the first
    let (params, ds, ts) = prefix_params(25_000, 0);
    let mut hook = Scripted {
        pending: vec![
            admit_at(0, 0, ctx_a, &cfgs[0], &table),
            admit_at(1, 1, ctx_a, &cfgs[1], &table),
            admit_at(3, 2, ctx_b, &cfgs[2], &table),
        ],
        boundary: 0,
        active_at_admission: Vec::new(),
        done: Vec::new(),
    };
    let shape = LockstepShape::of(&cfgs[0]);
    speculative_generate_continuous_with(&d, &t, shape, &mut hook, params);

    for st in [&ds, &ts] {
        let s = st.borrow().stats();
        assert_eq!(s.evictions, 1, "ctx_b's insert must evict ctx_a");
        assert_eq!((s.hits, s.misses), (1, 2));
        assert_eq!(s.entries, 1, "only ctx_b remains resident");
    }
    assert_eq!(hook.done.len(), 3);
    hook.done.sort_by_key(|(ticket, _)| *ticket);
    for (b, ((_, got), want)) in hook.done.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("eviction-scenario item failed");
        assert_eq!(got.tokens, want.tokens, "seq {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "seq {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "seq {b}: rejected");
        assert_eq!(got.rounds, want.rounds, "seq {b}: rounds");
    }
}

/// Engine-level check over the full coordinator path: a worker-style batch
/// through `GenEngine::generate_batch` equals per-request `generate` calls
/// for every method, including the grouping of lockstep-incompatible
/// configs.
#[test]
fn engine_batch_matches_serial_for_all_methods() {
    let eng = synthetic_engine(3);
    for method in [Method::TargetOnly, Method::Speculative, Method::SpecMer] {
        let mut cfgs: Vec<GenConfig> = (0..4u64)
            .map(|seed| GenConfig { max_len: 26, gamma: 5, c: 3, seed, ..Default::default() })
            .collect();
        cfgs[1].gamma = 4; // forces two lockstep groups
        cfgs[3].max_len = 20;
        let specs: Vec<_> =
            cfgs.iter().map(|cfg| eng.spec("SynB", method, cfg).unwrap()).collect();
        let batch = eng.generate_batch(&specs);
        for (i, (got, spec)) in batch.iter().zip(&specs).enumerate() {
            let want = eng.generate(spec).unwrap();
            let got = got.as_ref().expect("batch request failed");
            assert_eq!(got.tokens, want.tokens, "{method:?} req {i} diverged");
        }
    }
}

/// The cross-key acceptance criterion (SeqSpec redesign): a B=4 lockstep
/// group mixing two protein families (each sequence scoring against its
/// *own* family's k-mer table), mixed `kset`s, and a different protein
/// admitted mid-flight must produce token streams bitwise-identical to
/// solo decodes of the same requests.
#[test]
fn mixed_protein_mixed_kset_group_equals_solo_decodes() {
    let (_pa, msa_a) = generate_family("FamA", 40, 30, 5);
    let (_pb, msa_b) = generate_family("FamB", 44, 30, 9);
    let table_a = Arc::new(KmerTable::build(&msa_a));
    let table_b = Arc::new(KmerTable::build(&msa_b));
    // distinct draft/target so rejections and corrections actually occur
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);

    let ctxs: [&[u8]; 4] = [
        &[BOS, 5, 9],         // FamA
        &[BOS, 7, 11, 4],     // FamB — different family, same round 0
        &[BOS, 5, 9, 13],     // FamA
        &[BOS, 6, 3],         // FamB — admitted mid-flight (boundary 2)
    ];
    let tables = [
        Some(table_a.clone()),
        Some(table_b.clone()),
        Some(table_a.clone()),
        Some(table_b.clone()),
    ];
    let mut cfgs = [
        cfg(3, 5, 3, 48),
        cfg(3, 5, 11, 44),
        cfg(3, 5, 21, 48),
        cfg(3, 5, 33, 40),
    ];
    cfgs[1].kset = KmerSet::new(true, false, false); // per-sequence ksets
    cfgs[2].kmer_boundary = true;
    let arrivals = [0usize, 0, 1, 2];

    let solo: Vec<_> = ctxs
        .iter()
        .zip(&cfgs)
        .zip(&tables)
        .map(|((ctx, cfg), table)| {
            speculative_generate(&d, &t, table.as_deref(), ctx, cfg).unwrap()
        })
        .collect();

    // batch entry point: all four in one call, two families in one group
    let items: Vec<SpecBatchItem<'_>> = ctxs
        .iter()
        .zip(&cfgs)
        .zip(&tables)
        .map(|((ctx, cfg), table)| SpecBatchItem { context: ctx, cfg, table: table.clone() })
        .collect();
    let batch = speculative_generate_batch(&d, &t, &items);
    for (b, (got, want)) in batch.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("mixed-family item failed");
        assert_eq!(got.tokens, want.tokens, "batch seq {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "batch seq {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "batch seq {b}: rejected");
        assert_eq!(got.bonus, want.bonus, "batch seq {b}: bonus");
        assert_eq!(got.rounds, want.rounds, "batch seq {b}: rounds");
    }

    // continuous entry point: the FamB request at arrival 2 joins an
    // in-flight group already mixing FamA and FamB sequences
    let mut hook = Scripted {
        pending: arrivals
            .iter()
            .zip(ctxs.iter().zip(&cfgs).zip(&tables))
            .enumerate()
            .map(|(i, (&at, ((ctx, cfg), table)))| {
                let item = AdmitItem {
                    ticket: i as u64,
                    context: ctx.to_vec(),
                    cfg: cfg.clone(),
                    table: table.clone(),
                };
                (at, item)
            })
            .collect(),
        boundary: 0,
        active_at_admission: Vec::new(),
        done: Vec::new(),
    };
    speculative_generate_continuous(&d, &t, LockstepShape::of(&cfgs[0]), &mut hook);
    assert!(
        hook.active_at_admission[2..].iter().all(|&a| a > 0),
        "late arrivals must join an in-flight group: {:?}",
        hook.active_at_admission
    );
    assert_eq!(hook.done.len(), 4, "every admitted request completed");
    hook.done.sort_by_key(|(ticket, _)| *ticket);
    for (b, ((_, got), want)) in hook.done.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("admitted item failed");
        assert_eq!(got.tokens, want.tokens, "admitted seq {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "admitted seq {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "admitted seq {b}: rejected");
        assert_eq!(got.rounds, want.rounds, "admitted seq {b}: rounds");
    }
}
