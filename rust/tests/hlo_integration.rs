//! Integration tests over the AOT artifacts: HLO programs loaded through
//! PJRT must agree with the pure-Rust reference model and compose into
//! working decode engines.
//!
//! These tests need `make artifacts` to have run; they are skipped (pass
//! trivially with a notice) when artifacts are missing so `cargo test`
//! stays green on a fresh checkout.

use std::path::PathBuf;
use std::sync::Arc;

use specmer::config::Method;
use specmer::coordinator::{load_families, Engine, GenEngine};
use specmer::decode::{speculative_generate, target_only_generate, GenConfig};
use specmer::kmer::KmerSet;
use specmer::params;
use specmer::runtime::{CpuModel, HloKmerScorer, HloModel, ModelBackend, Runtime};
use specmer::tokenizer::BOS;

fn artifacts() -> Option<PathBuf> {
    let dir = std::env::var("SPECMER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../artifacts"));
    if dir.join("manifest.json").exists() && dir.join("hlo").is_dir() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

fn load(name: &str, dir: &PathBuf) -> (Arc<Runtime>, HloModel, CpuModel) {
    let rt = Arc::new(Runtime::new(dir).expect("runtime"));
    let manifest = params::load_manifest(dir).unwrap();
    let hlo = HloModel::load(Arc::clone(&rt), dir, name).expect("hlo model");
    let mp = params::load_model(dir, name).unwrap();
    let cpu = CpuModel::from_params(&mp, manifest.vocab).unwrap();
    (rt, hlo, cpu)
}

fn ctx() -> Vec<u8> {
    let mut c = vec![BOS];
    c.extend(specmer::tokenizer::encode("MKTAYIAKQR"));
    c
}

#[test]
fn hlo_score_matches_cpu_ref() {
    let Some(dir) = artifacts() else { return };
    let (_rt, hlo, cpu) = load("target", &dir);
    let toks = ctx();
    let a = hlo.score(&toks).unwrap();
    let b = cpu.score(&toks).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(
            (x - y).abs() < 2e-3 * (1.0 + y.abs()),
            "nll mismatch at {i}: hlo={x} cpu={y}"
        );
    }
}

#[test]
fn hlo_verify_matches_cpu_ref() {
    let Some(dir) = artifacts() else { return };
    let (_rt, hlo, cpu) = load("target", &dir);
    let toks = ctx();
    let mut hc = hlo.prefill(&toks).unwrap();
    let mut cc = cpu.prefill(&toks).unwrap();
    let block: Vec<u8> = {
        let mut v = vec![*toks.last().unwrap()];
        v.extend(specmer::tokenizer::encode("VLLKA"));
        v
    };
    let hv = hlo.verify(&mut hc, &block, toks.len() - 1, 1.0, 0.95).unwrap();
    let cv = cpu.verify(&mut cc, &block, toks.len() - 1, 1.0, 0.95).unwrap();
    assert_eq!(hv.dists.len(), cv.dists.len());
    for (i, (dh, dc)) in hv.dists.iter().zip(&cv.dists).enumerate() {
        for (t, (x, y)) in dh.iter().zip(dc).enumerate() {
            assert!((x - y).abs() < 5e-3, "pos {i} tok {t}: hlo={x} cpu={y}");
        }
    }
}

#[test]
fn hlo_generate_matches_cpu_ref_tokens() {
    let Some(dir) = artifacts() else { return };
    let (_rt, hlo, cpu) = load("draft", &dir);
    let toks = ctx();
    let mut hc = hlo.prefill(&toks).unwrap();
    let mut cc = cpu.prefill(&toks).unwrap();
    let u: Vec<f32> = (0..3 * 5).map(|i| ((i * 37 + 11) % 100) as f32 / 100.0).collect();
    let feed = vec![*toks.last().unwrap()];
    let hb = hlo
        .generate(&mut hc, &feed, toks.len() - 1, 3, 5, &u, 1.0, 0.95)
        .unwrap();
    let cb = cpu
        .generate(&mut cc, &feed, toks.len() - 1, 3, 5, &u, 1.0, 0.95)
        .unwrap();
    // identical uniforms + (near-)identical dists => identical token paths
    assert_eq!(hb.tokens, cb.tokens, "sampled candidate tokens diverged");
    for (ci, (dh, dc)) in hb.dists.iter().zip(&cb.dists).enumerate() {
        for (gi, (ph, pc)) in dh.iter().zip(dc).enumerate() {
            for t in 0..ph.len() {
                assert!(
                    (ph[t] - pc[t]).abs() < 5e-3,
                    "cand {ci} step {gi} tok {t}: {} vs {}",
                    ph[t],
                    pc[t]
                );
            }
        }
    }
}

#[test]
fn hlo_kmer_kernel_matches_rust_scorer() {
    let Some(dir) = artifacts() else { return };
    let rt = Arc::new(Runtime::new(&dir).unwrap());
    let fams = load_families(&dir).unwrap();
    let table = &*fams[0].table;
    let scorer = HloKmerScorer::new(rt);
    let cands: Vec<Vec<u8>> = vec![
        specmer::tokenizer::encode("MKTAY"),
        specmer::tokenizer::encode("AAAAA"),
        specmer::tokenizer::encode("VLKGE"),
    ];
    let ks = KmerSet::new(true, true, true);
    let hlo_scores = scorer.score(table, &cands, 5, ks).unwrap();
    for (i, cand) in cands.iter().enumerate() {
        let rust = specmer::kmer::score_block(table, cand, ks);
        assert!(
            (hlo_scores[i] - rust).abs() < 1e-5,
            "cand {i}: pallas={} rust={rust}",
            hlo_scores[i]
        );
    }
}

#[test]
fn end_to_end_speculative_decode_on_hlo() {
    let Some(dir) = artifacts() else { return };
    let rt = Arc::new(Runtime::new(&dir).unwrap());
    let draft = HloModel::load(Arc::clone(&rt), &dir, "draft").unwrap();
    let target = HloModel::load(rt, &dir, "target").unwrap();
    let fams = load_families(&dir).unwrap();
    let fam = &fams[0];
    let cfg = GenConfig { gamma: 5, c: 3, max_len: 60, seed: 7, ..Default::default() };
    let out = speculative_generate(&draft, &target, Some(&*fam.table), &fam.context, &cfg).unwrap();
    assert!(out.tokens.len() > fam.context.len());
    assert!(out.accepted > 0, "trained draft/target should agree sometimes: {out:?}");
    let alpha = out.acceptance_ratio();
    assert!(alpha > 0.3, "suspiciously low acceptance {alpha}");
    // accounting invariant
    assert_eq!(
        (out.tokens.len() - out.context_len) as u64,
        out.accepted + out.rejected + out.bonus
    );
}

#[test]
fn end_to_end_target_only_on_hlo() {
    let Some(dir) = artifacts() else { return };
    let rt = Arc::new(Runtime::new(&dir).unwrap());
    let target = HloModel::load(rt, &dir, "target").unwrap();
    let cfg = GenConfig { max_len: 50, seed: 3, ..Default::default() };
    let out = target_only_generate(&target, &ctx(), &cfg).unwrap();
    assert!(out.tokens.len() > 11);
    assert_eq!(out.rejected, 0);
}

#[test]
fn full_engine_all_methods_on_artifacts() {
    let Some(dir) = artifacts() else { return };
    let rt = Arc::new(Runtime::new(&dir).unwrap());
    let draft = HloModel::load(Arc::clone(&rt), &dir, "draft").unwrap();
    let target = HloModel::load(rt, &dir, "target").unwrap();
    let fams = load_families(&dir).unwrap();
    let engine = Engine::new(draft, target, fams);
    let cfg = GenConfig { gamma: 5, c: 3, max_len: 50, seed: 1, ..Default::default() };
    for m in [Method::TargetOnly, Method::DraftOnly, Method::Speculative, Method::SpecMer] {
        let protein = engine.families()[0].meta.name.clone();
        let out = engine.generate_for(&protein, m, &cfg).unwrap();
        assert!(out.tokens.len() > out.context_len, "{m:?}");
    }
}

#[test]
fn cross_protein_tables_change_specmer_nll() {
    // App. C sanity at integration level: using another family's k-mer
    // table must not crash and (weak check) changes candidate selection.
    let Some(dir) = artifacts() else { return };
    let rt = Arc::new(Runtime::new(&dir).unwrap());
    let draft = HloModel::load(Arc::clone(&rt), &dir, "draft").unwrap();
    let target = HloModel::load(rt, &dir, "target").unwrap();
    let fams = load_families(&dir).unwrap();
    assert!(fams.len() >= 2);
    let fam = &fams[0];
    let other = fams[1].table.clone();
    let cfg = GenConfig { gamma: 5, c: 5, max_len: 50, seed: 21, ..Default::default() };
    let a = speculative_generate(&draft, &target, Some(&*fam.table), &fam.context, &cfg).unwrap();
    let b = speculative_generate(&draft, &target, Some(&*other), &fam.context, &cfg).unwrap();
    assert!(a.tokens.len() > 2 && b.tokens.len() > 2);
}
