//! Per-sequence sampling params in lockstep groups (ROADMAP item, ISSUE 4
//! satellite): `temp`/`top_p` only gate each sequence's own `adjust_dist`
//! rows, so requests differing in them now share one lockstep group — the
//! compatibility key shrank to `(c, gamma)` — and every sequence must
//! still reproduce its solo token stream exactly, both through the batch
//! entry point and through continuous round-boundary admission.

use specmer::config::Method;
use specmer::coordinator::engine::synthetic_engine;
use specmer::coordinator::GenEngine;
use specmer::decode::{
    speculative_generate, speculative_generate_batch, speculative_generate_continuous,
    AdmissionHook, AdmitItem, GenConfig, GenOutput, LockstepShape, SpecBatchItem,
};
use specmer::kmer::{KmerSet, KmerTable};
use specmer::msa::simulate::generate_family;
use specmer::runtime::cpu_ref::CpuModel;
use specmer::tokenizer::BOS;

fn cfg(seed: u64, temp: f32, top_p: f32) -> GenConfig {
    GenConfig {
        c: 3,
        gamma: 5,
        seed,
        temp,
        top_p,
        max_len: 40,
        kset: KmerSet::new(true, true, true),
        ..Default::default()
    }
}

#[test]
fn mixed_sampling_params_share_a_lockstep_batch() {
    let (_prof, msa) = generate_family("T", 40, 30, 5);
    let table = std::sync::Arc::new(KmerTable::build(&msa));
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);
    let ctxs: [&[u8]; 4] = [&[BOS, 5, 9], &[BOS, 7], &[BOS, 5, 9, 13], &[BOS, 11, 3]];
    let cfgs = [
        cfg(3, 1.0, 1.0),
        cfg(11, 0.8, 0.95),
        cfg(21, 0.6, 0.9),
        cfg(33, 1.2, 0.85),
    ];

    let solo: Vec<GenOutput> = ctxs
        .iter()
        .zip(&cfgs)
        .map(|(ctx, cfg)| speculative_generate(&d, &t, Some(&table), ctx, cfg).unwrap())
        .collect();
    let items: Vec<SpecBatchItem<'_>> = ctxs
        .iter()
        .zip(&cfgs)
        .map(|(ctx, cfg)| SpecBatchItem { context: ctx, cfg, table: Some(table.clone()) })
        .collect();
    let batch = speculative_generate_batch(&d, &t, &items);

    for (b, (got, want)) in batch.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("mixed-sampling item failed");
        assert_eq!(got.tokens, want.tokens, "seq {b}: token stream diverged");
        assert_eq!(got.accepted, want.accepted, "seq {b}: accepted");
        assert_eq!(got.rejected, want.rejected, "seq {b}: rejected");
        assert_eq!(got.bonus, want.bonus, "seq {b}: bonus");
        assert_eq!(got.rounds, want.rounds, "seq {b}: rounds");
    }
}

/// Scripted admission source: each item joins once its boundary arrives.
struct Scripted {
    pending: Vec<(usize, AdmitItem)>,
    boundary: usize,
    active_at_admission: Vec<usize>,
    done: Vec<(u64, anyhow::Result<GenOutput>)>,
}

impl AdmissionHook for Scripted {
    fn admit(&mut self, active: usize) -> Vec<AdmitItem> {
        let b = self.boundary;
        self.boundary += 1;
        let (now, later): (Vec<_>, Vec<_>) = self.pending.drain(..).partition(|(at, _)| *at <= b);
        self.pending = later;
        for _ in &now {
            self.active_at_admission.push(active);
        }
        now.into_iter().map(|(_, item)| item).collect()
    }
    fn complete(&mut self, ticket: u64, result: anyhow::Result<GenOutput>) {
        self.done.push((ticket, result));
    }
}

/// Continuous admission with mixed temp/top_p: late joiners with different
/// sampling params used to be refused as shape mismatches; now they splice
/// into the in-flight group and still match their solo runs bitwise.
#[test]
fn continuous_admission_accepts_mixed_sampling_params() {
    let d = CpuModel::synthetic(2, 16, 2, 96, 17);
    let t = CpuModel::synthetic(2, 16, 2, 96, 18);
    let ctx: &[u8] = &[BOS, 5, 9];
    let cfgs = [cfg(3, 1.0, 1.0), cfg(17, 0.7, 0.9), cfg(29, 0.9, 0.95)];
    let arrivals = [0usize, 1, 2];

    let solo: Vec<GenOutput> = cfgs
        .iter()
        .map(|c| speculative_generate(&d, &t, None, ctx, c).unwrap())
        .collect();

    let mut hook = Scripted {
        pending: arrivals
            .iter()
            .zip(&cfgs)
            .enumerate()
            .map(|(i, (&at, c))| {
                let item = AdmitItem {
                    ticket: i as u64,
                    context: ctx.to_vec(),
                    cfg: c.clone(),
                    table: None,
                };
                (at, item)
            })
            .collect(),
        boundary: 0,
        active_at_admission: Vec::new(),
        done: Vec::new(),
    };
    speculative_generate_continuous(&d, &t, LockstepShape::of(&cfgs[0]), &mut hook);

    assert!(
        hook.active_at_admission[1..].iter().any(|&a| a > 0),
        "late arrivals never joined an in-flight group: {:?}",
        hook.active_at_admission
    );
    assert_eq!(hook.done.len(), 3, "every admitted request completed");
    hook.done.sort_by_key(|(ticket, _)| *ticket);
    for (b, ((_, got), want)) in hook.done.iter().zip(&solo).enumerate() {
        let got = got.as_ref().expect("admitted item failed");
        assert_eq!(got.tokens, want.tokens, "seq {b}: token stream diverged");
        assert_eq!(got.rounds, want.rounds, "seq {b}: rounds");
    }
}

/// Engine-level: a worker batch with heterogeneous sampling params decodes
/// as one group and matches per-request serial generation.
#[test]
fn engine_batch_with_mixed_sampling_params_matches_serial() {
    let eng = synthetic_engine(3);
    let mut cfgs: Vec<GenConfig> = (0..4u64)
        .map(|seed| GenConfig { max_len: 26, gamma: 5, c: 3, seed, ..Default::default() })
        .collect();
    cfgs[1].temp = 0.7;
    cfgs[2].top_p = 0.85;
    cfgs[3].temp = 1.1;
    cfgs[3].top_p = 1.0;
    for method in [Method::Speculative, Method::SpecMer] {
        let specs: Vec<_> =
            cfgs.iter().map(|cfg| eng.spec("SynA", method, cfg).unwrap()).collect();
        let batch = eng.generate_batch(&specs);
        for (i, (got, spec)) in batch.iter().zip(&specs).enumerate() {
            let want = eng.generate(spec).unwrap();
            let got = got.as_ref().expect("batch request failed");
            assert_eq!(got.tokens, want.tokens, "{method:?} req {i} diverged");
        }
    }
}
