//! Accuracy bounds for the opt-in `SPECMER_FAST` tier.
//!
//! The fast tier is deliberately *off* the bitwise contract (see the
//! `runtime` module docs): GEMM inner loops may use hardware FMA and
//! softmax/GELU use polynomial `exp`/`tanh`. These tests bound the damage
//! instead of pinning bits:
//!
//!   * `exp_fast`/`tanh_fast` stay within a small max-ulp budget of libm
//!     across dense grids of their full input ranges, including the
//!     flush-to-zero / saturation thresholds;
//!   * fast GEMM stays within a tight relative-error bound of the exact
//!     kernel (identical where the host has no FMA);
//!   * end to end, a fast-tier model's verify distributions and per-token
//!     acceptance probabilities stay within tolerance of the exact model
//!     built from the same seed.
//!
//! Everything here passes `fast` explicitly through `synthetic_with` /
//! `matmul_panel_st_with`, so the suite is environment-independent and can
//! run under any `SPECMER_*` setting.

use specmer::params::{Panel, WeightDtype};
use specmer::runtime::gemm;
use specmer::runtime::simd::{exp_fast, tanh_fast, Kernel};
use specmer::runtime::{CpuModel, ModelBackend};
use specmer::util::proptest::check;

/// Distance in representable-float steps between two finite f32 of the
/// same sign (the monotone-bits trick).
fn ulp_dist(a: f32, b: f32) -> u32 {
    assert!(a.is_finite() && b.is_finite(), "{a} vs {b}");
    assert!(
        a == 0.0 || b == 0.0 || a.signum() == b.signum(),
        "sign flip: {a} vs {b}"
    );
    let key = |x: f32| -> i64 {
        let i = x.to_bits() as i32;
        (if i < 0 { i32::MIN.wrapping_sub(i) } else { i }) as i64
    };
    (key(a) - key(b)).unsigned_abs() as u32
}

// ---------------------------------------------------------------------------
// Scalar transcendental bounds
// ---------------------------------------------------------------------------

#[test]
fn exp_fast_max_ulp_on_grid() {
    // dense grid over the finite-result range, denser near zero
    let mut worst = 0u32;
    let mut n = 0u64;
    for i in 0..=35_000i64 {
        let x = (-87.3 + i as f64 * 176.0 / 35_000.0) as f32;
        let got = exp_fast(x);
        let want = x.exp();
        if !want.is_finite() || want == 0.0 {
            continue;
        }
        let d = ulp_dist(got, want);
        worst = worst.max(d);
        n += 1;
        assert!(d <= 32, "exp_fast({x}) = {got}, libm {want}: {d} ulp");
    }
    assert!(n > 30_000, "grid degenerate");
    // tiny-argument sweep: exp(x) ~ 1 + x must not lose accuracy
    for i in -1000i32..=1000 {
        let x = i as f32 * 1e-6;
        let d = ulp_dist(exp_fast(x), x.exp());
        assert!(d <= 4, "exp_fast near zero ({x}): {d} ulp");
    }
    assert_eq!(exp_fast(0.0), 1.0);
}

#[test]
fn exp_fast_flush_and_saturation_thresholds() {
    // below the flush threshold the result is exactly +0
    assert_eq!(exp_fast(-87.34), 0.0);
    assert_eq!(exp_fast(-1.0e4), 0.0);
    assert_eq!(exp_fast(f32::MIN), 0.0);
    // above the overflow threshold the result saturates to +inf, like libm
    assert_eq!(exp_fast(88.73), f32::INFINITY);
    assert_eq!(exp_fast(1.0e4), f32::INFINITY);
    // just inside both thresholds stays finite and nonzero
    assert!(exp_fast(-87.3) > 0.0);
    assert!(exp_fast(88.7).is_finite());
}

#[test]
fn tanh_fast_max_ulp_on_grid() {
    for i in 0..=40_000i64 {
        let x = (-9.5 + i as f64 * 19.0 / 40_000.0) as f32;
        let got = tanh_fast(x);
        let want = x.tanh();
        if want.abs() >= 1.0 {
            // saturated region: both must give exactly ±1
            assert_eq!(got, want, "tanh_fast({x}) saturation");
            continue;
        }
        let d = ulp_dist(got, want);
        assert!(d <= 128, "tanh_fast({x}) = {got}, libm {want}: {d} ulp");
    }
    // the odd-Taylor branch (|x| < 0.25) and the branch seam just above it
    for i in -2600i32..=2600 {
        let x = i as f32 * 1e-4;
        let d = ulp_dist(tanh_fast(x), x.tanh());
        assert!(d <= 64, "tanh_fast small-x ({x}): {d} ulp");
    }
    assert_eq!(tanh_fast(0.0), 0.0);
    assert_eq!(tanh_fast(20.0), 1.0);
    assert_eq!(tanh_fast(-20.0), -1.0);
    assert!(tanh_fast(0.5) > 0.0 && tanh_fast(-0.5) < 0.0, "odd symmetry sign");
    assert_eq!(tanh_fast(0.7).to_bits(), (-tanh_fast(-0.7)).to_bits(), "odd symmetry");
}

// ---------------------------------------------------------------------------
// Fast GEMM bound
// ---------------------------------------------------------------------------

/// With `fast=true` the panel kernels may contract mul+add into FMA, which
/// only ever *removes* an intermediate rounding — each output element still
/// accumulates in the same index order, so it stays within a per-step
/// rounding budget of the exact kernel (and is identical without FMA).
#[test]
fn fast_gemm_relative_error_bounded() {
    check("fast GEMM error bound", 40, |g| {
        let m = g.usize_in(1..5);
        let k = g.usize_in(1..64);
        let n = g.usize_in(1..40);
        let a: Vec<f32> = (0..m * k).map(|_| g.f64_in(-1.0..1.0) as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| g.f64_in(-1.0..1.0) as f32).collect();
        for dtype in [WeightDtype::F32, WeightDtype::Bf16, WeightDtype::Int8] {
            let p = Panel::quantize(&w, k, n, dtype);
            for kernel in [Kernel::Avx2, Kernel::Portable] {
                let mut exact = vec![0.0f32; m * n];
                gemm::matmul_panel_st_with(kernel, &a, p.view(), m, k, n, &mut exact, false, false);
                let mut fast = vec![0.0f32; m * n];
                gemm::matmul_panel_st_with(kernel, &a, p.view(), m, k, n, &mut fast, false, true);
                // FMA only removes intermediate roundings: the divergence is
                // bounded by a per-step rounding budget over the k-loop
                let budget = 4.0 * (k as f32) * f32::EPSILON;
                for (i, (&x, &y)) in exact.iter().zip(&fast).enumerate() {
                    let scale = x.abs().max(1.0);
                    assert!(
                        (x - y).abs() <= budget * scale,
                        "{dtype:?} {kernel:?} ({m},{k},{n}) out[{i}]: {x} vs {y}"
                    );
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// End-to-end bound
// ---------------------------------------------------------------------------

/// A fast-tier model built from the same seed as the exact model must
/// produce verify distributions within a small per-token delta, and the
/// per-drafted-token acceptance probabilities (the `p[token]` a speculative
/// accept test thresholds against) must match within tolerance — the
/// fast tier may not measurably change what gets accepted.
#[test]
fn fast_tier_end_to_end_verify_tolerance() {
    let exact = CpuModel::synthetic_with(2, 32, 2, 64, 29, WeightDtype::F32, false);
    let fast = CpuModel::synthetic_with(2, 32, 2, 64, 29, WeightDtype::F32, true);
    assert!(!exact.fast_tier() && fast.fast_tier());

    let ctx: Vec<u8> = vec![3, 11, 6, 14, 2, 9, 17, 5];
    let pos = ctx.len() - 1;
    let vtoks: Vec<u8> = vec![ctx[pos], 4, 12, 7, 19, 1, 8, 15];

    let mut ce = exact.prefill(&ctx).unwrap();
    let mut cf = fast.prefill(&ctx).unwrap();
    // top_p = 1.0 keeps the map logits → dist continuous (the nucleus cut
    // is a hard threshold that would turn an ulp-level logit delta into a
    // whole-token delta when a candidate sits exactly on the boundary)
    let de = exact.verify(&mut ce, &vtoks, pos, 1.0, 1.0).unwrap();
    let df = fast.verify(&mut cf, &vtoks, pos, 1.0, 1.0).unwrap();
    assert_eq!(de.dists.len(), df.dists.len());

    let mut worst = 0.0f32;
    for (i, (pe, pf)) in de.dists.iter().zip(&df.dists).enumerate() {
        assert_eq!(pe.len(), pf.len());
        for (t, (&x, &y)) in pe.iter().zip(pf).enumerate() {
            let d = (x - y).abs();
            worst = worst.max(d);
            assert!(d <= 1e-3, "pos {i} tok {t}: exact {x} vs fast {y}");
        }
        // acceptance probability for the next drafted token under each tier
        if i + 1 < vtoks.len() {
            let tok = vtoks[i + 1] as usize;
            assert!(
                (pe[tok] - pf[tok]).abs() <= 1e-3,
                "pos {i}: acceptance prob drifted: {} vs {}",
                pe[tok],
                pf[tok]
            );
        }
    }
    // the committed KV writes must also stay close
    for (i, (&x, &y)) in ce.data.iter().zip(&cf.data).enumerate() {
        assert!((x - y).abs() <= 1e-2, "cache slot {i}: {x} vs {y}");
    }
    // sanity: the tiers are close, not suspiciously identical-by-accident —
    // but on hosts without FMA the GEMMs coincide, so only require finite
    assert!(worst.is_finite());
}

/// The resolved-tier accessors must reflect what the constructor was given
/// (the env-resolved defaults are exercised by the running process's own
/// configuration; here we pin the explicit plumbing).
#[test]
fn tier_accessors_reflect_construction() {
    let m = CpuModel::synthetic_with(1, 16, 2, 32, 7, WeightDtype::Bf16, true);
    assert_eq!(m.weight_dtype(), WeightDtype::Bf16);
    assert!(m.fast_tier());
    assert!(m.weight_bytes() > 0);
    let f = CpuModel::synthetic_with(1, 16, 2, 32, 7, WeightDtype::F32, false);
    assert_eq!(f.weight_dtype(), WeightDtype::F32);
    assert!(!f.fast_tier());
    // bf16 halves the GEMM weight traffic relative to f32
    assert!(
        (m.weight_bytes() as f64) < 0.6 * f.weight_bytes() as f64,
        "bf16 {} vs f32 {}",
        m.weight_bytes(),
        f.weight_bytes()
    );
    // a synthetic narrow-dtype model still decodes: distributions normalize
    let ctx: Vec<u8> = vec![1, 5, 9, 2];
    let pos = ctx.len() - 1;
    let mut c = m.prefill(&ctx).unwrap();
    let out = m.verify(&mut c, &[ctx[pos], 3, 8], pos, 1.0, 0.95).unwrap();
    for d in &out.dists {
        let s: f32 = d.iter().sum();
        assert!((s - 1.0).abs() <= 1e-4, "dist sum {s}");
    }
}
