//! Property-based tests on coordinator and decode invariants (run with the
//! in-repo mini-proptest; no artifacts needed — synthetic backends).

use specmer::config::Method;
use specmer::coordinator::engine::synthetic_engine;
use specmer::coordinator::GenEngine;
use specmer::decode::{speculative_generate, target_only_generate, GenConfig};
use specmer::kmer::{score_block, select_best, KmerSet, KmerTable};
use specmer::msa::simulate::generate_family;
use specmer::runtime::cpu_ref::CpuModel;
use specmer::runtime::ModelBackend;
use specmer::sampling;
use specmer::tokenizer::{BOS, EOS};
use specmer::util::proptest::{check, Gen};

fn rand_cfg(g: &mut Gen) -> GenConfig {
    GenConfig {
        gamma: *g.choose(&[2usize, 5, 8]),
        c: *g.choose(&[1usize, 2, 3, 5]),
        temp: *g.choose(&[0.7f32, 1.0, 1.4]),
        top_p: *g.choose(&[0.8f32, 0.95, 1.0]),
        kset: KmerSet::new(g.bool(), g.bool(), true),
        max_len: g.usize_in(16..64),
        seed: g.u64(),
        kmer_boundary: g.bool(),
        probe_rate: 0.0,
        ar_chunk: *g.choose(&[0usize, 1, 4]),
    }
}

/// Token accounting holds for every configuration: committed tokens =
/// accepted + rejected + bonus, and the context is preserved verbatim.
#[test]
fn prop_spec_decode_accounting() {
    let d = CpuModel::synthetic(2, 16, 2, 96, 71);
    let t = CpuModel::synthetic(2, 16, 2, 96, 72);
    let (_p, msa) = generate_family("P", 40, 20, 5);
    let table = KmerTable::build(&msa);
    check("spec decode accounting", 25, |g| {
        let cfg = rand_cfg(g);
        let ctx = vec![BOS, 5, 9, 13];
        let out = speculative_generate(&d, &t, Some(&table), &ctx, &cfg).unwrap();
        assert_eq!(&out.tokens[..4], &ctx[..]);
        assert_eq!(
            (out.tokens.len() - 4) as u64,
            out.accepted + out.rejected + out.bonus
        );
        assert!(out.tokens.len() <= cfg.max_len.min(96 - cfg.gamma));
        // EOS, if present, terminates the sequence
        if let Some(p) = out.tokens.iter().position(|&x| x == EOS) {
            assert_eq!(p, out.tokens.len() - 1);
        }
        // at most one rejection per round
        assert!(out.rejected <= out.rounds);
        // draft/target dispatch accounting
        assert_eq!(out.draft_calls, out.rounds);
        assert_eq!(out.target_calls, out.rounds);
    });
}

/// Every committed token lies in the target's adjusted support — the
/// correctness core of maximal coupling (accepted, corrected and bonus
/// tokens are all target-nucleus members).
#[test]
fn prop_committed_tokens_in_target_support() {
    let d = CpuModel::synthetic(2, 16, 2, 96, 81);
    let t = CpuModel::synthetic(2, 16, 2, 96, 82);
    check("tokens in target nucleus", 12, |g| {
        let cfg = rand_cfg(g);
        let ctx = vec![BOS, 7, 11];
        let out = speculative_generate(&d, &t, None, &ctx, &cfg).unwrap();
        let logits = t.forward_logits(&out.tokens);
        for i in ctx.len()..out.tokens.len() {
            let dist = sampling::adjust_dist(&logits[i - 1], cfg.temp, cfg.top_p);
            assert!(
                dist[out.tokens[i] as usize] > 0.0,
                "position {i} token outside nucleus (T={} p={})",
                cfg.temp,
                cfg.top_p
            );
        }
    });
}

/// Target-only generation always accepts and never calls a draft.
#[test]
fn prop_target_only_pure() {
    let t = CpuModel::synthetic(2, 16, 2, 96, 91);
    check("target-only accepts everything", 20, |g| {
        let cfg = rand_cfg(g);
        let out = target_only_generate(&t, &[BOS, 5], &cfg).unwrap();
        assert_eq!(out.rejected, 0);
        assert_eq!(out.acceptance_ratio(), 1.0);
        assert!(out.tokens.len() <= cfg.max_len.max(2));
    });
}

/// select_best is consistent with score_block and invariant to candidate
/// duplication (first index wins ties).
#[test]
fn prop_selection_consistent() {
    check("selection argmax consistent", 30, |g| {
        let (_p, msa) = generate_family("P", 30, 10, g.u64());
        let table = KmerTable::build(&msa);
        let ks = KmerSet::new(g.bool(), g.bool(), g.bool());
        let ks = if !(ks.k1 || ks.k3 || ks.k5) { KmerSet::new(true, false, false) } else { ks };
        let n = g.usize_in(1..6);
        let cands: Vec<Vec<u8>> = (0..n)
            .map(|_| {
                (0..g.usize_in(1..10))
                    .map(|_| 3 + g.rng().below(20) as u8)
                    .collect()
            })
            .collect();
        let sel = select_best(&table, &cands, ks);
        let best = score_block(&table, &cands[sel], ks);
        for c in &cands {
            assert!(score_block(&table, c, ks) <= best + 1e-6);
        }
        // duplicating the winner later must not change the selection
        let mut dup = cands.clone();
        dup.push(cands[sel].clone());
        assert_eq!(select_best(&table, &dup, ks), sel);
    });
}

/// The engine's generate is deterministic in seed for every method, and
/// different seeds explore (at least sometimes) different sequences.
#[test]
fn prop_engine_determinism() {
    let eng = synthetic_engine(33);
    check("engine determinism", 8, |g| {
        let cfg = rand_cfg(g);
        for m in [Method::TargetOnly, Method::Speculative, Method::SpecMer] {
            let a = eng.generate_for("SynA", m, &cfg).unwrap();
            let b = eng.generate_for("SynA", m, &cfg).unwrap();
            assert_eq!(a.tokens, b.tokens, "{m:?} nondeterministic");
        }
    });
}

/// Prefill cache adapter: memoized prefill must be bit-identical for
/// arbitrary contexts.
#[test]
fn prop_prefill_memo_exact() {
    use specmer::runtime::prefill_cache::PrefillCached;
    let m = PrefillCached::new(CpuModel::synthetic(2, 16, 2, 64, 44));
    check("prefill memo exact", 20, |g| {
        let n = g.usize_in(2..20);
        let ctx: Vec<u8> = std::iter::once(BOS)
            .chain((0..n).map(|_| 3 + g.rng().below(20) as u8))
            .collect();
        let a = m.prefill(&ctx).unwrap();
        let b = m.prefill(&ctx).unwrap();
        assert_eq!(a.data, b.data);
    });
}

/// Acceptance ratio responds to model agreement: a draft equal to the
/// target accepts everything; an independent draft accepts less.
#[test]
fn prop_alpha_orders_with_agreement() {
    let t = CpuModel::synthetic(2, 16, 2, 96, 55);
    let same = CpuModel::synthetic(2, 16, 2, 96, 55);
    let other = CpuModel::synthetic(2, 16, 2, 96, 56);
    let mut same_acc = 0.0;
    let mut other_acc = 0.0;
    for seed in 0..6 {
        let cfg = GenConfig { gamma: 5, c: 1, max_len: 60, seed, ..Default::default() };
        same_acc += speculative_generate(&same, &t, None, &[BOS, 5], &cfg)
            .unwrap()
            .acceptance_ratio();
        other_acc += speculative_generate(&other, &t, None, &[BOS, 5], &cfg)
            .unwrap()
            .acceptance_ratio();
    }
    assert!(same_acc > other_acc, "agreement must raise acceptance");
    assert!((same_acc / 6.0) > 0.999);
}
