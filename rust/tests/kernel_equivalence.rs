//! Equivalence suite for the SIMD compute tiers (ISSUE 4 tentpole): the
//! AVX2 arm, the portable chunked-lane arm, and the row-parallel path must
//! all be **bitwise-identical** to the seed scalar kernels — across
//! randomized shapes, non-multiple-of-lane widths, exact-zero inputs (the
//! seed mat-vec's skip edge), and the prepacked logits-head panel.
//!
//! The kernels only reorder work across independent output elements; per
//! element the accumulation runs over `k` in strict index order with a
//! single accumulator and separate mul/add (no FMA), so IEEE-754 makes the
//! arms bit-equal. These tests pin that argument.

use specmer::params::PackedWeights;
use specmer::runtime::cpu_ref::{reference, CpuModel};
use specmer::runtime::{gemm, simd};
use specmer::util::proptest::{check, Gen};

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Random matrix; `sparse` salts in exact zeros to exercise the skip edge.
fn randmat(g: &mut Gen, len: usize, sparse: bool) -> Vec<f32> {
    (0..len)
        .map(|_| {
            if sparse && g.f64_in(0.0..1.0) < 0.3 {
                0.0
            } else {
                g.f64_in(-2.0..2.0) as f32
            }
        })
        .collect()
}

#[test]
fn matmul_arms_bitwise_equal_across_random_shapes() {
    check("matmul simd == portable == scalar", 80, |g| {
        // shapes deliberately cross the 8-lane and 16-column tile widths
        // and the 4-row micro-kernel block boundary
        let m = g.usize_in(1..11);
        let k = g.usize_in(1..50);
        let n = g.usize_in(1..70);
        let a = randmat(g, m * k, true);
        let b = randmat(g, k * n, false);

        let mut scalar = vec![0.0f32; m * n];
        gemm::matmul_scalar(&a, &b, m, k, n, &mut scalar);
        for kernel in [simd::Kernel::Avx2, simd::Kernel::Portable] {
            let mut got = vec![0.0f32; m * n];
            gemm::matmul_st_with(kernel, &a, &b, m, k, n, &mut got);
            assert!(bits_eq(&got, &scalar), "{kernel:?} skip arm ({m},{k},{n})");
        }
        // the public auto-parallel entry point (below the FLOP threshold at
        // these shapes it runs single-threaded, but must agree regardless)
        let mut auto = vec![0.0f32; m * n];
        gemm::matmul(&a, &b, m, k, n, &mut auto);
        assert!(bits_eq(&auto, &scalar), "auto entry ({m},{k},{n})");
    });
}

#[test]
fn dense_arms_bitwise_equal_across_random_shapes() {
    check("matmul_dense simd == portable == scalar", 80, |g| {
        let m = g.usize_in(1..11);
        let k = g.usize_in(1..50);
        let n = g.usize_in(1..70);
        // zeros too: dense must NOT skip them (it matches the seed head)
        let a = randmat(g, m * k, true);
        let b = randmat(g, k * n, false);

        let mut scalar = vec![0.0f32; m * n];
        gemm::matmul_dense_scalar(&a, &b, m, k, n, &mut scalar);
        for kernel in [simd::Kernel::Avx2, simd::Kernel::Portable] {
            let mut got = vec![0.0f32; m * n];
            gemm::matmul_dense_st_with(kernel, &a, &b, m, k, n, &mut got);
            assert!(bits_eq(&got, &scalar), "{kernel:?} dense arm ({m},{k},{n})");
        }
    });
}

/// The prepacked `[D, V_pad]` head must reproduce the seed `matmul_nt`
/// logits head bit for bit — including when the vocab is not a multiple of
/// the lane width and the panel carries zero padding columns.
#[test]
fn prepacked_logits_head_bitwise_equals_seed_nt_head() {
    check("packed head == matmul_nt", 60, |g| {
        let rows = g.usize_in(1..7);
        let d = g.usize_in(1..40);
        let vocab = g.usize_in(1..45); // frequently not lane-aligned
        let h = randmat(g, rows * d, true);
        let emb = randmat(g, vocab * d, false); // [V, D]

        let mut want = vec![0.0f32; rows * vocab];
        gemm::matmul_nt(&h, &emb, rows, d, vocab, &mut want);

        let packed = PackedWeights::pack(&emb, vocab, d, simd::LANES);
        let vp = packed.v_pad;
        let mut padded = vec![0.0f32; rows * vp];
        gemm::matmul_dense(&h, &packed.emb_t, rows, d, vp, &mut padded);
        for r in 0..rows {
            let got = &padded[r * vp..r * vp + vocab];
            let exp = &want[r * vocab..(r + 1) * vocab];
            assert!(bits_eq(got, exp), "row {r} (rows={rows}, d={d}, v={vocab})");
            // padding columns multiply zero weights: exactly zero
            for (j, &z) in padded[r * vp + vocab..(r + 1) * vp].iter().enumerate() {
                assert_eq!(z.to_bits(), 0.0f32.to_bits(), "pad col {j} leaked");
            }
        }
    });
}

/// Attention / LN / residual lane helpers against their scalar loops, at
/// model level: the full SIMD forward must still match the seed scalar
/// reference implementation within the suite's established tolerance (the
/// per-kernel bitwise pins live in `runtime::simd` / `runtime::gemm` unit
/// tests; this closes the loop end to end on randomized tiny models).
#[test]
fn randomized_models_match_scalar_reference_forward() {
    check("simd forward == reference forward", 6, |g| {
        let n_layer = g.usize_in(1..3);
        let n_head = *g.choose(&[1usize, 2]);
        let d_model = n_head * 8;
        let maxlen = 32;
        let seed = g.u64();
        let m = CpuModel::synthetic(n_layer, d_model, n_head, maxlen, seed);
        let seq: Vec<u8> = (0..maxlen / 2).map(|i| 3 + ((i * 7) % 20) as u8).collect();
        let batched = m.forward_logits(&seq);
        let scalar = reference::forward_logits(&m, &seq);
        for (i, (ba, sa)) in batched.iter().zip(&scalar).enumerate() {
            for (t, (x, y)) in ba.iter().zip(sa).enumerate() {
                assert!((x - y).abs() <= 1e-4, "pos {i} tok {t}: {x} vs {y}");
            }
        }
    });
}

/// The row-parallel path (persistent pool) must not change bits vs the
/// single-threaded kernel on a shape large enough to engage it.
#[test]
fn pool_parallel_gemm_bitwise_equals_single_thread() {
    let mut g = Gen::new(0xC0FFEE);
    let (m, k, n) = (24, 200, 512); // 2*m*k*n ≈ 4.9M > the 4.2M threshold
    let a = randmat(&mut g, m * k, true);
    let b = randmat(&mut g, k * n, false);
    let mut par = vec![0.0f32; m * n];
    gemm::matmul(&a, &b, m, k, n, &mut par);
    let mut st = vec![0.0f32; m * n];
    gemm::matmul_st(&a, &b, m, k, n, &mut st);
    assert!(bits_eq(&par, &st), "pool partitioning changed bits");
}
