//! Tree-structured speculation suite (ISSUE 6 tentpole): shared-prefix
//! candidate trees from the arena to the scorer.
//!
//! Three layers of assurance:
//!   * a property test that [`TokenTree::ancestor_mask`]'s incremental
//!     row-copy construction agrees with brute-force per-path
//!     recomputation on randomly grown forests;
//!   * the degenerate guarantee — `branch == 1` chain-shaped trees drive
//!     the whole tree code path (`draft_tree`, path scoring,
//!     `verify_tree`, trunk re-feeding) and must be **bitwise identical**
//!     to the flat-chain driver;
//!   * the branching guarantee — with genuine branching the committed
//!     token stream is no longer bitwise-comparable (the RNG draws one
//!     uniform per *node*, and a forest has more nodes than c chains),
//!     but speculative coupling keeps it exactly target-distributed, so a
//!     seeded two-sample test over hundreds of generations must find the
//!     same unigram token distribution and the same mean target NLL.

use specmer::decode::{speculative_generate, GenConfig, TreePolicy};
use specmer::kmer::KmerSet;
use specmer::runtime::cpu_ref::CpuModel;
use specmer::runtime::{ModelBackend, TokenTree};
use specmer::tokenizer::BOS;
use specmer::util::proptest::{check, Gen};

fn cfg(c: usize, gamma: usize, seed: u64, max_len: usize) -> GenConfig {
    GenConfig {
        c,
        gamma,
        seed,
        max_len,
        kset: KmerSet::new(true, true, true),
        ..Default::default()
    }
}

/// Grow a random forest the way any driver would: node ids in DFS path
/// order, every parent preceding its children.
fn random_tree(g: &mut Gen) -> TokenTree {
    fn grow(parents: &mut Vec<Option<usize>>, g: &mut Gen, parent: Option<usize>, depth: usize) {
        let id = parents.len();
        parents.push(parent);
        if depth >= 4 || parents.len() >= 24 {
            return;
        }
        let kids = g.usize_in(0..3);
        for _ in 0..kids {
            grow(parents, g, Some(id), depth + 1);
        }
    }
    let mut parents = Vec::new();
    let roots = g.usize_in(1..4);
    for _ in 0..roots {
        grow(&mut parents, g, None, 0);
    }
    let tokens = (0..parents.len()).map(|i| (i % 29) as u8).collect();
    TokenTree { parents, tokens }
}

#[test]
fn ancestor_mask_matches_per_path_recomputation() {
    check("ancestor mask == per-path brute force", 300, |g| {
        let tree = random_tree(g);
        tree.validate().unwrap();
        let n = tree.len();
        let mask = tree.ancestor_mask();
        // brute force: walk every root-to-leaf path; the mask row of the
        // node at path position i must be exactly {path[0..=i]}
        let mut covered = vec![false; n];
        for path in tree.paths() {
            for (i, &q) in path.iter().enumerate() {
                covered[q] = true;
                let visible: Vec<usize> =
                    (0..n).filter(|&a| mask[q * n + a]).collect();
                assert_eq!(visible, path[..=i].to_vec(), "node {q} on path {path:?}");
            }
        }
        // every node lies on at least one root-to-leaf path
        assert!(covered.iter().all(|&c| c), "paths() missed a node");
    });
}

#[test]
fn chain_policy_is_bitwise_identical_to_flat() {
    // the tree driver with branch == 1 runs chain-shaped forests through
    // draft_tree/verify_tree + trunk re-feeding and must reproduce the
    // flat path bit for bit, across seeds and shapes
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);
    let ctx: &[u8] = &[BOS, 5, 9];
    for (c, gamma, mask, seed) in
        [(1usize, 5usize, 0b10u16, 3u64), (2, 5, 0b100, 17), (3, 5, 0b1010, 41), (2, 8, 0b1000, 9)]
    {
        let flat = cfg(c, gamma, seed, 48);
        let mut chain = flat.clone();
        chain.tree = TreePolicy { branch: 1, split_mask: mask };
        let a = speculative_generate(&d, &t, None, ctx, &flat).unwrap();
        let b = speculative_generate(&d, &t, None, ctx, &chain).unwrap();
        assert_eq!(a.tokens, b.tokens, "c={c} gamma={gamma} seed={seed} diverged");
        assert_eq!(a.accepted, b.accepted, "c={c} gamma={gamma} seed={seed}");
        assert_eq!(a.rejected, b.rejected, "c={c} gamma={gamma} seed={seed}");
        assert_eq!(a.bonus, b.bonus, "c={c} gamma={gamma} seed={seed}");
        assert_eq!(a.rounds, b.rounds, "c={c} gamma={gamma} seed={seed}");
    }
}

/// Mean per-token NLL of the committed tokens under the raw target model.
fn mean_nll(t: &CpuModel, tokens: &[u8], context_len: usize) -> f64 {
    let nll = t.score(tokens).unwrap();
    let tail = &nll[context_len.max(1)..];
    if tail.is_empty() {
        return 0.0;
    }
    tail.iter().map(|&x| x as f64).sum::<f64>() / tail.len() as f64
}

#[test]
fn branching_is_distribution_identical_to_flat() {
    // speculative coupling is lossless for *any* drafting policy: with no
    // k-mer table both arms walk candidate/path 0, so flat chains and
    // branched trees must sample the same target distribution even though
    // their RNG streams (one uniform per node) diverge immediately.
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);
    let ctx: &[u8] = &[BOS, 5, 9];
    const RUNS: u64 = 250;
    const VOCAB: usize = 32;

    let mut counts = [[0u64; VOCAB]; 2];
    let mut totals = [0u64; 2];
    let mut nll_sum = [0.0f64; 2];
    let mut first = [[0u64; VOCAB]; 2];
    for seed in 0..RUNS {
        for arm in 0..2 {
            let mut cfg = cfg(2, 5, 0xBEEF ^ seed, 28);
            if arm == 1 {
                // 2 roots, split at depth 2: 16 nodes, 4 root-to-leaf paths
                cfg.tree = TreePolicy { branch: 2, split_mask: 0b100 };
            }
            let out = speculative_generate(&d, &t, None, ctx, &cfg).unwrap();
            assert_eq!(
                (out.tokens.len() - out.context_len) as u64,
                out.accepted + out.rejected + out.bonus,
                "arm {arm} accounting"
            );
            for &tok in &out.tokens[out.context_len..] {
                counts[arm][tok as usize] += 1;
                totals[arm] += 1;
            }
            if out.tokens.len() > out.context_len {
                first[arm][out.tokens[out.context_len] as usize] += 1;
            }
            nll_sum[arm] += mean_nll(&t, &out.tokens, out.context_len);
        }
    }

    // unigram total-variation distance over all committed tokens: both
    // arms pool thousands of samples, so sampling noise sits well under
    // the 0.1 gate while any systematic drafting bias would blow past it
    let tv = |a: &[u64; VOCAB], b: &[u64; VOCAB], na: f64, nb: f64| {
        (0..VOCAB)
            .map(|k| (a[k] as f64 / na - b[k] as f64 / nb).abs())
            .sum::<f64>()
            / 2.0
    };
    let tv_all = tv(&counts[0], &counts[1], totals[0] as f64, totals[1] as f64);
    assert!(tv_all < 0.1, "unigram TV distance {tv_all:.4} (flat vs tree)");
    let tv_first = tv(&first[0], &first[1], RUNS as f64, RUNS as f64);
    assert!(tv_first < 0.2, "first-token TV distance {tv_first:.4}");

    let mean = [nll_sum[0] / RUNS as f64, nll_sum[1] / RUNS as f64];
    assert!(
        (mean[0] - mean[1]).abs() < 0.12,
        "mean target NLL diverged: flat {:.4} vs tree {:.4}",
        mean[0],
        mean[1]
    );
}

#[test]
fn branching_widens_the_drafted_forest() {
    // sanity on the accounting surface the /metrics gauges read: the same
    // (c, gamma) drafts more nodes per round once splits are enabled
    let d = CpuModel::synthetic(2, 16, 2, 96, 7);
    let t = CpuModel::synthetic(2, 16, 2, 96, 8);
    let ctx: &[u8] = &[BOS, 5, 9];
    let flat = cfg(2, 5, 77, 40);
    let mut tree = flat.clone();
    tree.tree = TreePolicy { branch: 2, split_mask: 0b100 };
    let a = speculative_generate(&d, &t, None, ctx, &flat).unwrap();
    let b = speculative_generate(&d, &t, None, ctx, &tree).unwrap();
    assert_eq!(a.tree_nodes, a.rounds * 10, "flat: c*gamma nodes per round");
    assert_eq!(b.tree_nodes, b.rounds * 16, "tree: 16-node forest per round");
}
