//! Equivalence suite for the batched CPU runtime (ISSUE 1 tentpole): the
//! GEMM-batched forward and branched-cache drafting must reproduce the seed
//! per-position scalar implementation, which is preserved verbatim as
//! `runtime::cpu_ref::reference`.
//!
//! Contracts checked here:
//!   * batched forward logits match the scalar path to ≤ 1e-4 (they are
//!     designed to be bitwise-equal; the tolerance only allows for exotic
//!     platform codegen),
//!   * `c = 1` drafting is byte-identical to the seed path for the same
//!     uniforms (and deterministic across runs),
//!   * multi-candidate drafting, verify, and prefill agree with the seed
//!     path as well.

use specmer::runtime::cpu_ref::{reference, CpuModel};
use specmer::runtime::ModelBackend;

fn seq_for(model_maxlen: usize) -> Vec<u8> {
    (0..model_maxlen / 2).map(|i| 3 + ((i * 7) % 20) as u8).collect()
}

#[test]
fn batched_forward_matches_scalar_reference_logits() {
    for &(nl, d, nh, s, seed) in &[
        (2usize, 16usize, 2usize, 32usize, 42u64),
        (3, 24, 4, 48, 7),
        (1, 8, 1, 16, 9),
    ] {
        let m = CpuModel::synthetic(nl, d, nh, s, seed);
        let seq = seq_for(s);
        let batched = m.forward_logits(&seq);
        let scalar = reference::forward_logits(&m, &seq);
        assert_eq!(batched.len(), scalar.len());
        for (i, (ba, sa)) in batched.iter().zip(&scalar).enumerate() {
            for (t, (x, y)) in ba.iter().zip(sa).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-4,
                    "L{nl} d{d}: pos {i} tok {t}: batched {x} vs scalar {y}"
                );
            }
        }
    }
}

#[test]
fn prefill_cache_matches_reference() {
    let m = CpuModel::synthetic(2, 16, 2, 48, 13);
    let ctx: Vec<u8> = vec![1, 5, 9, 13, 7, 4, 20, 11];
    let a = m.prefill(&ctx).unwrap();
    let mut b = m.empty_cache();
    reference::cached_forward(&m, &mut b, &ctx[..ctx.len() - 1], 0);
    assert_eq!(a.data.len(), b.data.len());
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert!((x - y).abs() <= 1e-6, "cache slot {i}: {x} vs {y}");
    }
}

#[test]
fn c1_draft_is_byte_identical_to_reference() {
    let m = CpuModel::synthetic(2, 16, 2, 64, 11);
    let ctx: Vec<u8> = vec![1, 5, 9, 13, 7];
    let pos = ctx.len() - 1;
    let feed = vec![ctx[pos]];
    let u: Vec<f32> = (0..8).map(|i| (i as f32 * 0.213) % 1.0).collect();
    let mut c1 = m.prefill(&ctx).unwrap();
    let mut c2 = m.prefill(&ctx).unwrap();
    let a = m.generate(&mut c1, &feed, pos, 1, 8, &u, 0.9, 0.95).unwrap();
    let b = reference::generate(&m, &mut c2, &feed, pos, 1, 8, &u, 0.9, 0.95);
    assert_eq!(a.tokens, b.tokens, "c=1 token stream must be byte-identical");
    for (gi, (da, db)) in a.dists[0].iter().zip(&b.dists[0]).enumerate() {
        for (x, y) in da.iter().zip(db) {
            assert!((x - y).abs() <= 1e-6, "step {gi}: {x} vs {y}");
        }
    }
    // determinism of the batched path across runs with the same uniforms
    let mut c3 = m.prefill(&ctx).unwrap();
    let c = m.generate(&mut c3, &feed, pos, 1, 8, &u, 0.9, 0.95).unwrap();
    assert_eq!(a.tokens, c.tokens);
}

#[test]
fn multi_candidate_draft_matches_reference_across_shapes() {
    for &(nl, d, nh, s, seed) in &[(2usize, 16usize, 2usize, 64usize, 3u64), (1, 8, 2, 48, 5)] {
        let m = CpuModel::synthetic(nl, d, nh, s, seed);
        let ctx: Vec<u8> = vec![1, 5, 9, 13];
        let pos = ctx.len() - 1;
        let feed = vec![ctx[pos]];
        let (c, gamma) = (3usize, 5usize);
        let u: Vec<f32> = (0..c * gamma).map(|i| (i as f32 * 0.171) % 1.0).collect();
        let mut c1 = m.prefill(&ctx).unwrap();
        let mut c2 = m.prefill(&ctx).unwrap();
        let a = m.generate(&mut c1, &feed, pos, c, gamma, &u, 1.0, 0.95).unwrap();
        let b = reference::generate(&m, &mut c2, &feed, pos, c, gamma, &u, 1.0, 0.95);
        assert_eq!(a.tokens, b.tokens, "L{nl} d{d}: candidate tokens diverged");
        for (ci, (da, db)) in a.dists.iter().zip(&b.dists).enumerate() {
            for (gi, (pa, pb)) in da.iter().zip(db).enumerate() {
                for (t, (x, y)) in pa.iter().zip(pb).enumerate() {
                    assert!(
                        (x - y).abs() <= 1e-5,
                        "L{nl} d{d}: cand {ci} step {gi} tok {t}: {x} vs {y}"
                    );
                }
            }
        }
    }
}

#[test]
fn verify_matches_reference() {
    let m = CpuModel::synthetic(2, 16, 2, 48, 21);
    let ctx: Vec<u8> = vec![1, 5, 9, 13, 7];
    let pos = ctx.len() - 1;
    let vtoks: Vec<u8> = vec![ctx[pos], 4, 7, 9, 12, 15];
    let mut c1 = m.prefill(&ctx).unwrap();
    let mut c2 = m.prefill(&ctx).unwrap();
    let a = m.verify(&mut c1, &vtoks, pos, 1.0, 0.95).unwrap();
    let b = reference::verify(&m, &mut c2, &vtoks, pos, 1.0, 0.95);
    assert_eq!(a.dists.len(), b.dists.len());
    for (i, (da, db)) in a.dists.iter().zip(&b.dists).enumerate() {
        for (t, (x, y)) in da.iter().zip(db).enumerate() {
            assert!((x - y).abs() <= 1e-6, "pos {i} tok {t}: {x} vs {y}");
        }
    }
    // the caches must also agree afterwards (same committed KV writes)
    for (i, (x, y)) in c1.data.iter().zip(&c2.data).enumerate() {
        assert!((x - y).abs() <= 1e-6, "cache slot {i}: {x} vs {y}");
    }
}

/// Drafting must not disturb the committed cache: a verify after a draft
/// round sees exactly the same KV state whether candidates were drafted
/// through the branched cache or not at all.
#[test]
fn drafting_leaves_committed_cache_untouched() {
    let m = CpuModel::synthetic(2, 16, 2, 64, 17);
    let ctx: Vec<u8> = vec![1, 5, 9, 13, 7];
    let pos = ctx.len() - 1;
    let feed = vec![ctx[pos]];
    let u: Vec<f32> = (0..3 * 5).map(|i| (i as f32 * 0.31) % 1.0).collect();

    let mut with_draft = m.prefill(&ctx).unwrap();
    let _ = m.generate(&mut with_draft, &feed, pos, 3, 5, &u, 1.0, 0.95).unwrap();

    let mut feed_only = m.prefill(&ctx).unwrap();
    let _ = m.verify(&mut feed_only, &feed, pos, 1.0, 1.0).unwrap();

    // compare only the committed slots (0..=pos): draft tails must not leak
    let dims = &m.dims;
    let (nl, nh, dh, sm) = (dims.n_layer, dims.n_head, dims.d_head(), dims.maxlen());
    for l in 0..nl {
        for kv in 0..2 {
            for hh in 0..nh {
                for s in 0..=pos {
                    let base = (((l * 2 + kv) * nh + hh) * sm + s) * dh;
                    for j in 0..dh {
                        let x = with_draft.data[base + j];
                        let y = feed_only.data[base + j];
                        assert!(
                            (x - y).abs() <= 1e-6,
                            "l{l} kv{kv} h{hh} s{s}: committed KV diverged {x} vs {y}"
                        );
                    }
                }
            }
        }
    }
}
