//! Minimal, API-compatible shim for the subset of the `anyhow` crate that
//! specmer uses (the build image has no crates.io access — see DESIGN.md §3).
//!
//! Matches real-anyhow semantics where it matters:
//!   * `Error` is a cheap opaque error value built from any
//!     `std::error::Error` (capturing its source chain) or a message.
//!   * `Error` deliberately does NOT implement `std::error::Error`, so the
//!     blanket `From<E: std::error::Error>` conversion used by `?` cannot
//!     conflict with the reflexive `From<Error> for Error`.
//!   * `{e}` displays the outermost message; `{e:#}` appends the cause
//!     chain (`outer: cause: root`), like anyhow's alternate formatting.
//!   * `downcast_ref::<E>()` recovers the typed root error when the value
//!     was built from a concrete `std::error::Error` (via `?` or `From`),
//!     so callers can branch on error variants (e.g. the serving stack's
//!     overload/deadline responses) instead of matching message strings.
//!     Context layers keep the payload; `anyhow!`-style message errors
//!     carry none.

use std::any::Any;
use std::fmt;

/// Opaque error: an outermost message plus its cause chain.
pub struct Error {
    /// `chain[0]` is the outermost context, the last entry the root cause.
    chain: Vec<String>,
    /// The typed root error, when built from a concrete `std::error::Error`
    /// — what `downcast_ref` recovers. Message errors carry `None`.
    payload: Option<Box<dyn Any + Send + Sync>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Wrap with an additional layer of context (used by [`Context`]).
    /// The typed payload (if any) survives context layering, like anyhow.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Recover the typed root error, if this value was built from a concrete
    /// `std::error::Error` (via `?`/`From`). Message errors return `None`.
    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        self.payload.as_ref()?.downcast_ref::<T>()
    }

    /// Whether the root error is of type `T` (shorthand over `downcast_ref`).
    pub fn is<T: Any>(&self) -> bool {
        self.downcast_ref::<T>().is_some()
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, payload: Some(Box::new(e)) }
    }
}

/// `.context(..)` / `.with_context(..)` on fallible results.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// `anyhow!("...")` — construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// `bail!("...")` — early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "boom");
    }

    #[test]
    fn context_layers_and_alternate_format() {
        let e: Result<(), std::io::Error> = Err(io_err());
        let e = e.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: boom");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(7);
        let v = ok
            .with_context(|| -> String { panic!("must not run") })
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn downcast_recovers_typed_root_through_context() {
        let e: Result<(), std::io::Error> = Err(io_err());
        let e = e.context("outer").unwrap_err();
        assert!(e.is::<std::io::Error>());
        let io = e.downcast_ref::<std::io::Error>().unwrap();
        assert_eq!(io.kind(), std::io::ErrorKind::Other);
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // message errors carry no payload
        assert!(!Error::msg("plain").is::<std::io::Error>());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let x = 3;
        let e = anyhow!("val {x} and {}", 4);
        assert_eq!(format!("{e}"), "val 3 and 4");
        fn f() -> Result<()> {
            bail!("stop {}", 9)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "stop 9");
    }
}
