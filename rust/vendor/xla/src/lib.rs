//! Offline stub of the `xla` PJRT bindings used by `specmer::runtime`.
//!
//! The build image has no crates.io access and no PJRT plugin, so this crate
//! keeps the HLO code paths compiling and type-checked while making the
//! runtime behavior explicit:
//!
//!   * [`Literal`] is fully functional on the host (typed storage + dims) —
//!     cache snapshots, literal builders and round-trip tests work.
//!   * [`PjRtClient::cpu`] returns an error, so `Runtime::new` fails
//!     gracefully and every caller falls back to the pure-Rust backend
//!     (`--cpu-ref` / `CpuModel`); device execution is never reached.
//!
//! Swapping in the real `xla` crate requires no source changes elsewhere.

use std::fmt;

/// Stub error type (implements `std::error::Error` so `?` conversions into
/// `anyhow::Error` work unchanged).
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "xla stub: PJRT is unavailable in this offline build (run with --cpu-ref)";

/// Typed host storage backing a [`Literal`].
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn store(v: Vec<Self>) -> Storage;
    #[doc(hidden)]
    fn unpack(s: &Storage) -> Option<Vec<Self>>;
    #[doc(hidden)]
    fn type_name() -> &'static str;
}

impl NativeType for f32 {
    fn store(v: Vec<f32>) -> Storage {
        Storage::F32(v)
    }
    fn unpack(s: &Storage) -> Option<Vec<f32>> {
        match s {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "f32"
    }
}

impl NativeType for i32 {
    fn store(v: Vec<i32>) -> Storage {
        Storage::I32(v)
    }
    fn unpack(s: &Storage) -> Option<Vec<i32>> {
        match s {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "i32"
    }
}

/// A host tensor literal: typed flat storage plus dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], storage: T::store(data.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: Vec::new(), storage: T::store(vec![v]) }
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(v) => v.len(),
        }
    }

    /// Same storage, new dims (must preserve the element count).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape: {} elements into dims {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Copy the flat contents out as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unpack(&self.storage)
            .ok_or_else(|| Error::new(format!("to_vec: literal is not {}", T::type_name())))
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(v) => Ok(v),
            _ => Err(Error::new("to_tuple: literal is not a tuple")),
        }
    }
}

/// Stub device handle (never constructed).
pub struct PjRtDevice;

/// Stub device buffer (never constructed: the client cannot be created).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Stub PJRT client: construction always fails in the offline build.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Stub loaded executable (never constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Stub HLO module proto: text parsing is unavailable offline.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error::new(format!(
            "xla stub: cannot parse HLO text {} (PJRT unavailable offline)",
            path.as_ref().display()
        )))
    }
}

/// Stub XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple_errors() {
        let s = Literal::scalar(5i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![5]);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn client_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/tmp/none.hlo.txt").is_err());
    }
}
