//! Regenerates the paper's table5 (cargo bench target, harness = false).
//! Env: SPECMER_BENCH_N (seqs/cell), SPECMER_BENCH_FULL (paper grid),
//! SPECMER_BENCH_PROTEINS (subset). Output: results/table5.{md,csv}.
fn main() {
    specmer::experiments::bench_main(&["table5"]);
}
