//! Regenerates the paper's bounds (cargo bench target, harness = false).
//! Env: SPECMER_BENCH_N (seqs/cell), SPECMER_BENCH_FULL (paper grid),
//! SPECMER_BENCH_PROTEINS (subset). Output: results/bounds.{md,csv}.
fn main() {
    specmer::experiments::bench_main(&["bounds"]);
}
