//! Open-loop load harness for the hardened serving stack (criterion is
//! unavailable offline; this is a plain `fn main()` bench like its
//! siblings). It drives the real Router → Scheduler → worker pipeline —
//! synthetic engines, no artifacts — with **Poisson arrivals** at a fixed
//! offered rate, the defining property of an open-loop benchmark: arrivals
//! do not wait for completions, so overload shows up as shed/deadline-miss
//! counts instead of silently stretching a closed loop's think time.
//!
//! Traffic is a deterministic seeded mix over both synthetic families
//! (SynA/SynB), methods (SpecMER, vanilla speculative, draft-only),
//! lengths, and tree policies (flat vs branch-2 split@3), each request
//! carrying a completion deadline. Two phases:
//!
//! 1. **Calibration** — a burst of requests run to completion measures the
//!    sustainable completion rate of this machine's stack.
//! 2. **Measured run** — open-loop arrivals at `2x` the sustainable rate
//!    (full mode), so the stack must shed: bounded queues answer 429-style
//!    typed `Overloaded`, expired requests answer `DeadlineExceeded`, and
//!    memory stays flat (`queue_depth_peak` reports the high-water mark
//!    against the configured capacity).
//!
//! Results go to `results/bench_serve.json`: p50/p95/p99 TTFT (the stack
//! answers whole sequences, so time-to-first-token equals completion
//! latency), per-token latency percentiles, shed rate, deadline-miss rate,
//! tokens/s, and the queue-depth high-water mark.
//!
//! A third phase drives the **shared-prefix KV cache**: staggered
//! same-family long-generation arrivals, where every admission after the
//! first finds the family context warm in the worker's prefix store
//! (copy-on-write attach) and cold contexts prefill in chunks across
//! round boundaries. The phase must complete with **zero deadline
//! misses** — warm admissions never stall the in-flight group.
//!
//! `SPECMER_BENCH_SMOKE=1` (CI: `make bench-serve-smoke`) runs a short
//! fixed-seed pass at trivial load instead, asserts that *nothing* was
//! shed and *no* deadline was missed, and re-parses the written JSON to
//! pin the schema.

use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use specmer::config::Method;
use specmer::coordinator::{
    synthetic_engine, synthetic_families, EngineFactory, FamilyRegistry, GenEngine, GenError,
    Metrics, Router, Scheduler, SchedulerOpts,
};
use specmer::decode::{GenConfig, TreePolicy};
use specmer::kmer::KmerSet;
use specmer::util::json::Json;
use specmer::util::rng::Pcg64;
use specmer::util::stats::percentile;

/// One request of the traffic mix, derived deterministically from its index.
fn mix_request(i: usize) -> (&'static str, Method, GenConfig) {
    let protein = ["SynA", "SynB"][i % 2];
    let method =
        [Method::SpecMer, Method::Speculative, Method::SpecMer, Method::DraftOnly][i % 4];
    let max_len = [24usize, 32, 48][i % 3];
    // every other SpecMER request drafts a branch-2 tree split at depth 3
    let tree = if method == Method::SpecMer && i % 8 == 0 {
        TreePolicy { branch: 2, split_mask: 0b1000 }
    } else {
        TreePolicy::default()
    };
    let cfg = GenConfig {
        c: 3,
        gamma: 5,
        max_len,
        seed: i as u64 * 13 + 5,
        kset: KmerSet::new(true, true, true),
        tree,
        ..Default::default()
    };
    (protein, method, cfg)
}

fn pct(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        percentile(xs, q)
    }
}

struct RunStats {
    offered: usize,
    completed: usize,
    shed: usize,
    deadline_missed: usize,
    other_errors: usize,
    ttft_ms: Vec<f64>,
    per_token_ms: Vec<f64>,
    tokens: usize,
    elapsed_s: f64,
    queue_depth_peak: u64,
}

impl RunStats {
    fn new(offered: usize) -> RunStats {
        RunStats {
            offered,
            completed: 0,
            shed: 0,
            deadline_missed: 0,
            other_errors: 0,
            ttft_ms: Vec::new(),
            per_token_ms: Vec::new(),
            tokens: 0,
            elapsed_s: 0.0,
            queue_depth_peak: 0,
        }
    }
}

/// Collect `n` responses (the hardened stack answers every request) into
/// the stat buckets.
fn drain_responses(
    rx: &std::sync::mpsc::Receiver<specmer::coordinator::GenResponse>,
    n: usize,
    s: &mut RunStats,
) {
    for _ in 0..n {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("hardened stack must answer every request");
        match &resp.result {
            Ok(out) => {
                s.completed += 1;
                s.tokens += out.new_tokens();
                s.ttft_ms.push(resp.latency * 1e3);
                if out.new_tokens() > 0 {
                    s.per_token_ms.push(resp.latency * 1e3 / out.new_tokens() as f64);
                }
            }
            Err(e) => match GenError::of(e) {
                Some(GenError::Overloaded { .. }) => s.shed += 1,
                Some(GenError::DeadlineExceeded) => s.deadline_missed += 1,
                None => s.other_errors += 1,
            },
        }
    }
}

/// Open-loop run: `n` mixed requests with exponential inter-arrival times
/// at `rate_rps`, each carrying a `timeout` deadline. Returns once every
/// request has been answered (shed and expired requests answer too — the
/// hardened stack never leaves a client hanging).
fn run_open_loop(
    router: &Router,
    metrics: &Metrics,
    n: usize,
    rate_rps: f64,
    timeout: Duration,
    arrival_seed: u64,
) -> RunStats {
    let mut rng = Pcg64::new(arrival_seed);
    let (tx, rx) = channel();
    let t0 = Instant::now();
    let mut queue_depth_peak = 0u64;
    for i in 0..n {
        let (protein, method, cfg) = mix_request(i);
        let deadline = Some(Instant::now() + timeout);
        router.submit_with_deadline(protein, method, cfg, deadline, tx.clone());
        queue_depth_peak = queue_depth_peak.max(metrics.queue_depth.load(Ordering::Relaxed));
        // exponential inter-arrival: open loop, independent of completions
        let dt = -(1.0 - rng.next_f64()).ln() / rate_rps;
        std::thread::sleep(Duration::from_secs_f64(dt.min(1.0)));
    }
    drop(tx);

    let mut s = RunStats::new(n);
    s.queue_depth_peak = queue_depth_peak;
    drain_responses(&rx, n, &mut s);
    s.elapsed_s = t0.elapsed().as_secs_f64();
    s
}

/// Staggered same-family arrivals (phase 3): `n` long-generation SynA
/// requests submitted one every `gap`, each carrying a `timeout` deadline.
/// The first admission prefills SynA's context cold (chunked when
/// `prefill_chunk` is set) and publishes the snapshot; every later
/// admission attaches it copy-on-write — so none of them may stall the
/// in-flight group long enough to miss a deadline.
fn run_staggered(
    router: &Router,
    n: usize,
    gap: Duration,
    max_len: usize,
    timeout: Duration,
) -> RunStats {
    let (tx, rx) = channel();
    let t0 = Instant::now();
    for i in 0..n {
        let cfg = GenConfig {
            c: 3,
            gamma: 5,
            max_len,
            seed: 1000 + i as u64 * 7,
            kset: KmerSet::new(true, true, true),
            ..Default::default()
        };
        let deadline = Some(Instant::now() + timeout);
        router.submit_with_deadline("SynA", Method::SpecMer, cfg, deadline, tx.clone());
        std::thread::sleep(gap);
    }
    drop(tx);
    let mut s = RunStats::new(n);
    drain_responses(&rx, n, &mut s);
    s.elapsed_s = t0.elapsed().as_secs_f64();
    s
}

fn main() {
    let smoke = std::env::var("SPECMER_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);

    let registry = Arc::new(FamilyRegistry::new(synthetic_families(7)));
    let factory: EngineFactory =
        Arc::new(|| Ok(Box::new(synthetic_engine(7)) as Box<dyn GenEngine>));
    let opts = SchedulerOpts {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        // small queues: the overload run must hit the admission bound and
        // shed, not absorb the backlog in memory
        queue_capacity: if smoke { 256 } else { 32 },
        fault: None,
        prefix_cache_mb: 32,
        // SynA/SynB contexts feed 6 positions: chunk 4 makes every cold
        // admission take the chunked-prefill path (2 round boundaries)
        prefill_chunk: 4,
    };
    let metrics = Arc::new(Metrics::new());
    let sched = Arc::new(Scheduler::start_with(2, opts, factory, Arc::clone(&metrics)));
    let router = Router::new(Arc::clone(&sched), registry);

    // ---- phase 1: calibration — sustainable completion rate --------------
    // A burst run to completion (deadline far away, rate high enough that
    // the queues, not the arrival process, pace the workers).
    let (cal_n, cal_rate) = if smoke { (8, 200.0) } else { (64, 2000.0) };
    let cal = run_open_loop(&router, &metrics, cal_n, cal_rate, Duration::from_secs(60), 11);
    let sustainable_rps = cal.completed as f64 / cal.elapsed_s.max(1e-9);
    println!(
        "[bench_serve] calibration: {} reqs in {:.2}s -> sustainable {:.1} req/s",
        cal.completed, cal.elapsed_s, sustainable_rps
    );

    // ---- phase 2: measured open-loop run ---------------------------------
    // Smoke: trivial load (half the sustainable rate, generous deadline) —
    // nothing may be shed or expire. Full: 2x sustainable with a deadline
    // around the calibrated service time — the stack must shed gracefully.
    let (n, rate_rps, timeout) = if smoke {
        (8usize, (sustainable_rps * 0.5).max(1.0), Duration::from_secs(30))
    } else {
        (400usize, sustainable_rps * 2.0, Duration::from_millis(2000))
    };
    println!("[bench_serve] open loop: {n} reqs at {rate_rps:.1} req/s, deadline {timeout:?}");
    let s = run_open_loop(&router, &metrics, n, rate_rps, timeout, 23);

    let shed_rate = s.shed as f64 / s.offered as f64;
    let miss_rate = s.deadline_missed as f64 / s.offered as f64;
    println!(
        "[bench_serve] offered {} completed {} shed {} ({:.1}%) missed {} ({:.1}%) other {}",
        s.offered,
        s.completed,
        s.shed,
        shed_rate * 100.0,
        s.deadline_missed,
        miss_rate * 100.0,
        s.other_errors
    );
    println!(
        "[bench_serve] ttft p50/p95/p99 = {:.1}/{:.1}/{:.1} ms, queue depth peak {}",
        pct(&s.ttft_ms, 50.0),
        pct(&s.ttft_ms, 95.0),
        pct(&s.ttft_ms, 99.0),
        s.queue_depth_peak
    );

    // ---- phase 3: staggered same-family long-context arrivals ------------
    // Every admission after the first finds SynA's context warm in the
    // worker's prefix store; the acceptance bar is zero deadline misses.
    let (st_n, st_gap, st_max_len) = if smoke {
        (6usize, Duration::from_millis(30), 48usize)
    } else {
        (24usize, Duration::from_millis(20), 64usize)
    };
    let st = run_staggered(&router, st_n, st_gap, st_max_len, Duration::from_secs(30));
    // per-worker prefix gauges refresh when a dispatch *returns*, which can
    // trail the last response by a beat — poll briefly before reading
    let mut px = metrics.prefix_totals();
    for _ in 0..100 {
        if px.hits >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
        px = metrics.prefix_totals();
    }
    println!(
        "[bench_serve] staggered: {} reqs (max_len {st_max_len}) completed {} missed {} \
         — prefix cache {} hits / {} misses",
        st.offered, st.completed, st.deadline_missed, px.hits, px.misses
    );

    let json = Json::obj(vec![
        ("workers", Json::num(2.0)),
        ("sustainable_rps", Json::num(sustainable_rps)),
        ("rate_rps", Json::num(rate_rps)),
        ("deadline_ms", Json::num(timeout.as_secs_f64() * 1e3)),
        ("offered", Json::num(s.offered as f64)),
        ("completed", Json::num(s.completed as f64)),
        ("shed", Json::num(s.shed as f64)),
        ("deadline_missed", Json::num(s.deadline_missed as f64)),
        ("other_errors", Json::num(s.other_errors as f64)),
        ("shed_rate", Json::num(shed_rate)),
        ("deadline_miss_rate", Json::num(miss_rate)),
        ("ttft_ms_p50", Json::num(pct(&s.ttft_ms, 50.0))),
        ("ttft_ms_p95", Json::num(pct(&s.ttft_ms, 95.0))),
        ("ttft_ms_p99", Json::num(pct(&s.ttft_ms, 99.0))),
        ("per_token_ms_p50", Json::num(pct(&s.per_token_ms, 50.0))),
        ("per_token_ms_p95", Json::num(pct(&s.per_token_ms, 95.0))),
        ("per_token_ms_p99", Json::num(pct(&s.per_token_ms, 99.0))),
        ("tokens", Json::num(s.tokens as f64)),
        ("tokens_per_sec", Json::num(s.tokens as f64 / s.elapsed_s.max(1e-9))),
        ("queue_depth_peak", Json::num(s.queue_depth_peak as f64)),
        ("staggered_offered", Json::num(st.offered as f64)),
        ("staggered_completed", Json::num(st.completed as f64)),
        ("staggered_deadline_missed", Json::num(st.deadline_missed as f64)),
        ("staggered_ttft_ms_p50", Json::num(pct(&st.ttft_ms, 50.0))),
        ("staggered_ttft_ms_p99", Json::num(pct(&st.ttft_ms, 99.0))),
        ("prefix_cache_hits", Json::num(px.hits as f64)),
        ("prefix_cache_misses", Json::num(px.misses as f64)),
        ("smoke", Json::Bool(smoke)),
    ]);
    std::fs::create_dir_all("results").ok();
    let path = "results/bench_serve.json";
    std::fs::write(path, format!("{json}\n")).expect("write results/bench_serve.json");
    println!("[bench_serve] wrote {path}");

    if smoke {
        // schema pin: the written artifact must round-trip and carry every
        // field downstream dashboards key on
        let text = std::fs::read_to_string(path).expect("re-read bench_serve.json");
        let parsed = Json::parse(&text).expect("bench_serve.json must be valid JSON");
        for key in [
            "sustainable_rps",
            "rate_rps",
            "offered",
            "completed",
            "shed",
            "deadline_missed",
            "shed_rate",
            "deadline_miss_rate",
            "ttft_ms_p50",
            "ttft_ms_p95",
            "ttft_ms_p99",
            "per_token_ms_p50",
            "tokens_per_sec",
            "queue_depth_peak",
            "staggered_offered",
            "staggered_deadline_missed",
            "staggered_ttft_ms_p50",
            "prefix_cache_hits",
            "prefix_cache_misses",
            "smoke",
        ] {
            assert!(parsed.get(key).is_some(), "bench_serve.json missing key '{key}'");
        }
        assert_eq!(s.shed, 0, "trivial load must not shed");
        assert_eq!(s.deadline_missed, 0, "trivial load must not miss deadlines");
        assert_eq!(s.other_errors, 0, "trivial load must not error");
        assert_eq!(s.completed, s.offered, "every request answered Ok at trivial load");
        assert_eq!(
            st.deadline_missed, 0,
            "staggered long-context arrivals must not miss deadlines"
        );
        assert_eq!(st.completed, st.offered, "every staggered request answered Ok");
        assert!(
            px.hits >= 1,
            "staggered same-family arrivals should warm the prefix cache (got {} hits)",
            px.hits
        );
        println!("[bench_serve] smoke assertions passed");
    }
}
