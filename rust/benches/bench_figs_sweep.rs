//! Regenerates the paper's figs_sweep (cargo bench target, harness = false).
//! Env: SPECMER_BENCH_N (seqs/cell), SPECMER_BENCH_FULL (paper grid),
//! SPECMER_BENCH_PROTEINS (subset). Output: results/figs_sweep.{md,csv}.
fn main() {
    specmer::experiments::bench_main(&["figs_sweep"]);
}
