//! Micro-benchmarks of the L3 hot paths (criterion is unavailable offline;
//! this is a minimal warmup+measure harness with median-of-runs output).
//! These feed EXPERIMENTS.md §Perf.
//!
//! Besides the scalar kernels, this bench measures the **compute-kernel
//! floor** (seed scalar GEMM vs the SIMD dispatch, the seed `matmul_nt`
//! logits head vs the prepacked `[D, V]` panel, the attention weighted-V
//! lane helper, and single-thread vs persistent-pool row parallelism), a
//! full **draft round** (`generate` at c=3, γ=5) and a **verify round** on
//! a synthetic model — both for the batched branched-cache runtime and for
//! the seed clone-per-candidate implementation (`cpu_ref::reference`) —
//! plus the worker-level question — four full generations dispatched as
//! **lockstep batched rounds vs a serial request loop** — plus the
//! serving-path questions under **streaming arrivals** (B=4 staggered
//! submits): measured occupancy of continuous round-boundary admission vs
//! run-to-completion dispatch, and — for mixed-family traffic (B=4
//! staggered across 2 families) — **shape-keyed vs (protein, method)-keyed
//! admission**, the SeqSpec redesign's cross-tenant occupancy lever —
//! plus the tentpole question of the tree refactor: **tree-vs-flat
//! speculation at equal draft FLOPs** (acceptance rate and tokens/s of a
//! 14-node shared-prefix forest against 15 nodes of independent chains) —
//! plus the weight-traffic question of the quantized panels: **per-dtype
//! decode rounds** (f32 vs bf16 vs int8, default vs `SPECMER_FAST`) on a
//! memory-bound shape, reporting tokens/s, weight bytes per token and
//! effective GB/s — plus the admission-path question of the shared-prefix
//! KV cache: **cold one-shot prefill vs warm copy-on-write attach** (a
//! prefix-store lookup + `prefill_into`, which must be strictly cheaper
//! than the full-context forward). All numbers are emitted
//! machine-readably to `results/bench_micro.json`, tagged with the
//! resolved kernel dispatch, weight dtype and fast-tier flag so perf
//! trajectories are attributable to the configuration that produced them.
//! Set `SPECMER_BENCH_SMOKE=1` for a fast CI smoke run.

use std::sync::Arc;
use std::time::Instant;

use specmer::decode::{
    speculative_generate, speculative_generate_batch, speculative_generate_continuous,
    AdmissionHook, AdmitItem, GenConfig, GenOutput, LockstepShape, SpecBatchItem, TreePolicy,
};
use specmer::kmer::{score_block, KmerSet, KmerTable};
use specmer::msa::simulate::generate_family;
use specmer::params::{PackedWeights, WeightDtype};
use specmer::runtime::cpu_ref::{reference, CpuModel};
use specmer::runtime::{gemm, simd, ModelBackend, PrefixStore};
use specmer::sampling;
use specmer::util::json::Json;
use specmer::util::rng::Pcg64;
use specmer::util::threadpool::compute_threads;

/// Median ns/iter over 5 measured runs (after warmup).
fn bench_ns<F: FnMut()>(iters: u64, mut f: F) -> f64 {
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let mut runs = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        runs.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    runs[2]
}

fn bench<F: FnMut()>(name: &str, iters: u64, f: F) -> f64 {
    let ns = bench_ns(iters, f);
    println!("{name:<44} {ns:>12.1} ns/iter (median of 5)");
    ns
}

fn main() {
    let smoke = std::env::var("SPECMER_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let scale: u64 = if smoke { 100 } else { 1 };

    let (_prof, msa) = generate_family("bench", 120, 200, 1);
    let table = Arc::new(KmerTable::build(&msa));
    let mut rng = Pcg64::new(7);
    let block5: Vec<u8> = (0..5).map(|_| 3 + rng.below(20) as u8).collect();
    let block15: Vec<u8> = (0..15).map(|_| 3 + rng.below(20) as u8).collect();
    let ks = KmerSet::new(true, true, true);

    println!("== L3 hot-path micro-benchmarks ==");
    bench("kmer score_block gamma=5 k=1,3,5", 200_000 / scale, || {
        std::hint::black_box(score_block(&table, &block5, ks));
    });
    bench("kmer score_block gamma=15 k=1,3,5", 200_000 / scale, || {
        std::hint::black_box(score_block(&table, &block15, ks));
    });

    let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
    bench("adjust_dist (softmax+nucleus) V=32", 100_000 / scale, || {
        std::hint::black_box(sampling::adjust_dist(&logits, 0.9, 0.95));
    });

    let p = sampling::adjust_dist(&logits, 1.0, 1.0);
    let q = sampling::adjust_dist(&logits, 0.8, 0.95);
    let mut crng = Pcg64::new(3);
    bench("maximal coupling step", 100_000 / scale, || {
        let x = sampling::sample(&p, crng.next_f32());
        std::hint::black_box(sampling::couple(&p, &q, x, &mut crng));
    });

    bench("residual distribution V=32", 100_000 / scale, || {
        std::hint::black_box(sampling::residual(&p, &q));
    });

    let mut trng = Pcg64::new(9);
    bench("pcg64 next_f32", 1_000_000 / scale, || {
        std::hint::black_box(trng.next_f32());
    });

    bench("kmer table build (120x200 MSA)", (20 / scale).max(2), || {
        std::hint::black_box(KmerTable::build(&msa));
    });

    // ---- compute-kernel benches: scalar reference vs SIMD dispatch -------
    // The per-kernel floor every round bench above is built on. The scalar
    // reference is the seed mat-vec (kept verbatim in gemm); the vectorized
    // numbers run whatever arm the dispatcher selected on this machine.
    println!(
        "== compute-kernel benches (dispatch: {}, threads: {}) ==",
        simd::active().name(),
        compute_threads()
    );
    let mut krng = Pcg64::new(77);
    let mut randf = |n: usize| -> Vec<f32> {
        (0..n).map(|_| (krng.gaussian() * 0.5) as f32).collect()
    };
    let kernel_iters: u64 = if smoke { 20 } else { 400 };

    // single-thread GEMM: a draft-round-like projection shape
    let (gm, gk, gn) = (8usize, 256usize, 256usize);
    let ga = randf(gm * gk);
    let gb = randf(gk * gn);
    let mut gout = vec![0.0f32; gm * gn];
    let gemm_scalar_ns = bench("gemm 8x256x256 st (seed scalar ref)", kernel_iters, || {
        gemm::matmul_scalar(&ga, &gb, gm, gk, gn, &mut gout);
        std::hint::black_box(&gout);
    });
    let gemm_simd_ns = bench("gemm 8x256x256 st (vectorized)", kernel_iters, || {
        gemm::matmul_st(&ga, &gb, gm, gk, gn, &mut gout);
        std::hint::black_box(&gout);
    });
    let gemm_st_speedup = gemm_scalar_ns / gemm_simd_ns;
    println!("single-thread GEMM speedup vs scalar ref: {gemm_st_speedup:.2}x");

    // multi-thread GEMM: a shape past the parallel threshold
    let (mm, mk, mn) = (64usize, 256usize, 512usize);
    let ma = randf(mm * mk);
    let mb = randf(mk * mn);
    let mut mout = vec![0.0f32; mm * mn];
    let mt_iters: u64 = if smoke { 5 } else { 60 };
    let gemm_mt_single_ns = bench("gemm 64x256x512 (single-thread)", mt_iters, || {
        gemm::matmul_st(&ma, &mb, mm, mk, mn, &mut mout);
        std::hint::black_box(&mout);
    });
    let gemm_mt_pool_ns = bench("gemm 64x256x512 (persistent pool)", mt_iters, || {
        gemm::matmul(&ma, &mb, mm, mk, mn, &mut mout);
        std::hint::black_box(&mout);
    });
    let gemm_mt_speedup = gemm_mt_single_ns / gemm_mt_pool_ns;
    println!("pool-parallel GEMM speedup vs single-thread: {gemm_mt_speedup:.2}x");

    // logits head: seed transposed-dot head vs the prepacked [D, V] panel
    let (hr, hd, hv) = (8usize, 64usize, 32usize);
    let hh = randf(hr * hd);
    let hemb = randf(hv * hd); // [V, D]
    let hpacked = PackedWeights::pack(&hemb, hv, hd, simd::LANES);
    let mut hout = vec![0.0f32; hr * hpacked.v_pad];
    let head_seed_ns = bench("logits head r8 d64 V32 (seed matmul_nt)", kernel_iters, || {
        gemm::matmul_nt(&hh, &hemb, hr, hd, hv, &mut hout[..hr * hv]);
        std::hint::black_box(&hout);
    });
    let head_packed_ns = bench("logits head r8 d64 V32 (prepacked dense)", kernel_iters, || {
        gemm::matmul_dense_st(&hh, &hpacked.emb_t, hr, hd, hpacked.v_pad, &mut hout);
        std::hint::black_box(&hout);
    });
    let head_speedup = head_seed_ns / head_packed_ns;
    println!("prepacked logits-head speedup vs seed: {head_speedup:.2}x");

    // attention weighted-V accumulation: scalar loop vs the lane helper
    let (adh, aseq) = (64usize, 256usize);
    let avals = randf(aseq * adh);
    let aws = randf(aseq);
    let mut aout = vec![0.0f32; adh];
    let att_iters: u64 = if smoke { 200 } else { 20_000 };
    let att_scalar_ns = bench("attention V-accum S=256 dh=64 (scalar)", att_iters, || {
        aout.fill(0.0);
        for s in 0..aseq {
            let w = aws[s];
            let vv = &avals[s * adh..(s + 1) * adh];
            for (o, &x) in aout.iter_mut().zip(vv) {
                *o += w * x;
            }
        }
        std::hint::black_box(&aout);
    });
    let att_simd_ns = bench("attention V-accum S=256 dh=64 (lanes)", att_iters, || {
        aout.fill(0.0);
        for s in 0..aseq {
            simd::axpy(aws[s], &avals[s * adh..(s + 1) * adh], &mut aout);
        }
        std::hint::black_box(&aout);
    });
    let att_speedup = att_scalar_ns / att_simd_ns;
    println!("attention V-accum speedup vs scalar: {att_speedup:.2}x");

    // ---- draft / verify round benches: batched vs seed implementation ----
    // Synthetic but non-trivial model: 4 layers, d=64, 4 heads, S=256. The
    // seed path clones the full [L,2,H,S,Dh] cache (512 KiB) per candidate
    // per round and runs scalar mat-vecs; the batched path branches the
    // cache and runs blocked GEMMs.
    println!("== draft/verify round benches (c=3, γ=5, synthetic d=64) ==");
    let m = CpuModel::synthetic(4, 64, 4, 256, 42);
    let ctx: Vec<u8> = {
        let mut v = vec![1u8];
        v.extend((0..40).map(|i| 3 + ((i * 11) % 20) as u8));
        v
    };
    let pos = ctx.len() - 1;
    let feed = vec![ctx[pos]];
    let (c, gamma) = (3usize, 5usize);
    let u: Vec<f32> = (0..c * gamma).map(|i| (i as f32 * 0.137) % 1.0).collect();
    let round_iters: u64 = if smoke { 3 } else { 30 };

    let mut cache_new = m.prefill(&ctx).unwrap();
    let draft_new = bench("draft round c=3 γ=5 (batched/branched)", round_iters, || {
        std::hint::black_box(
            m.generate(&mut cache_new, &feed, pos, c, gamma, &u, 1.0, 0.95).unwrap(),
        );
    });

    let mut cache_ref = m.prefill(&ctx).unwrap();
    let draft_seed = bench("draft round c=3 γ=5 (seed clone-per-cand)", round_iters, || {
        std::hint::black_box(reference::generate(
            &m, &mut cache_ref, &feed, pos, c, gamma, &u, 1.0, 0.95,
        ));
    });

    let vtoks: Vec<u8> = vec![ctx[pos], 4, 7, 9, 12, 15];
    let mut cache_v = m.prefill(&ctx).unwrap();
    let verify_new = bench("verify round γ=5 (batched)", round_iters, || {
        std::hint::black_box(m.verify(&mut cache_v, &vtoks, pos, 1.0, 0.95).unwrap());
    });

    let mut cache_vr = m.prefill(&ctx).unwrap();
    let verify_seed = bench("verify round γ=5 (seed per-position)", round_iters, || {
        std::hint::black_box(reference::verify(&m, &mut cache_vr, &vtoks, pos, 1.0, 0.95));
    });

    let draft_speedup = draft_seed / draft_new;
    let verify_speedup = verify_seed / verify_new;
    println!("draft-round speedup vs seed:  {draft_speedup:.2}x");
    println!("verify-round speedup vs seed: {verify_speedup:.2}x");

    // ---- cross-request batching: B=4 lockstep decode vs the serial loop --
    // Full generations (all rounds to max_len/EOS) for four requests with
    // different seeds — the worker-level question: does dispatching the
    // batch through shared decode rounds beat iterating it?
    println!("== cross-request decode benches (B=4, c=3, γ=5) ==");
    let bd = CpuModel::synthetic(4, 64, 4, 256, 41);
    let bt = CpuModel::synthetic(4, 64, 4, 256, 43);
    let bcfgs: Vec<GenConfig> = (0..4u64)
        .map(|seed| GenConfig {
            c: 3,
            gamma: 5,
            max_len: 72,
            seed: seed * 7 + 1,
            kset: KmerSet::new(true, true, true),
            ..Default::default()
        })
        .collect();
    let bctx: Vec<u8> = ctx.clone();
    let gen_iters: u64 = if smoke { 1 } else { 5 };

    // committed tokens are identical across both paths (the equivalence
    // tests pin it), so count them once up front — this pass doubles as
    // warmup — and reuse the sum for both throughput numbers
    let new_tokens: usize = bcfgs
        .iter()
        .map(|cfg| {
            speculative_generate(&bd, &bt, Some(&table), &bctx, cfg).unwrap().new_tokens()
        })
        .sum();

    let serial_ns = bench("decode B=4 (serial request loop)", gen_iters, || {
        for cfg in &bcfgs {
            std::hint::black_box(
                speculative_generate(&bd, &bt, Some(&table), &bctx, cfg).unwrap(),
            );
        }
    });
    let batched_ns = bench("decode B=4 (lockstep batched rounds)", gen_iters, || {
        let items: Vec<SpecBatchItem<'_>> = bcfgs
            .iter()
            .map(|cfg| SpecBatchItem { context: &bctx, cfg, table: Some(table.clone()) })
            .collect();
        for out in speculative_generate_batch(&bd, &bt, &items) {
            std::hint::black_box(out.unwrap());
        }
    });
    let serial_tps = new_tokens as f64 / (serial_ns / 1e9);
    let batched_tps = new_tokens as f64 / (batched_ns / 1e9);
    let batch_speedup = serial_ns / batched_ns;
    println!("serial  B=4 throughput: {serial_tps:.1} tok/s");
    println!("batched B=4 throughput: {batched_tps:.1} tok/s");
    println!("batched-vs-serial decode speedup: {batch_speedup:.2}x");

    // ---- streaming arrivals: continuous batching vs run-to-completion ----
    // The same four requests now *arrive staggered* (a few decode rounds
    // apart). Continuous batching admits each at the next round boundary
    // of the in-flight group; run-to-completion dispatches whatever has
    // arrived whenever the worker goes idle and never looks at the queue
    // mid-decode. Occupancy is measured in sequence-rounds per worker
    // round, idle rounds included — the time-weighted fullness of the
    // `[B·c, D]` dispatches.
    println!("== streaming-arrival occupancy (B=4, staggered submits) ==");
    let arrivals: Vec<usize> = vec![0, 2, 3, 5];

    struct StreamHook {
        pending: Vec<(usize, AdmitItem)>,
        boundary: usize,
        seq_rounds: u64,
        busy_rounds: u64,
        idle_rounds: u64,
        completed: usize,
    }

    impl AdmissionHook for StreamHook {
        fn admit(&mut self, active: usize) -> Vec<AdmitItem> {
            let mut b = self.boundary;
            // worker idle: fast-forward to the next arrival, counting the
            // idle rounds against occupancy
            if active == 0 && !self.pending.is_empty() {
                let next = self.pending.iter().map(|(at, _)| *at).min().unwrap();
                if next > b {
                    self.idle_rounds += (next - b) as u64;
                    b = next;
                }
            }
            self.boundary = b + 1;
            let (now, later): (Vec<_>, Vec<_>) =
                self.pending.drain(..).partition(|(at, _)| *at <= b);
            self.pending = later;
            let will_run = active + now.len();
            if will_run > 0 {
                self.busy_rounds += 1;
                self.seq_rounds += will_run as u64;
            }
            now.into_iter().map(|(_, item)| item).collect()
        }
        fn complete(&mut self, _ticket: u64, result: anyhow::Result<GenOutput>) {
            result.unwrap();
            self.completed += 1;
        }
    }

    let mut hook = StreamHook {
        pending: bcfgs
            .iter()
            .enumerate()
            .map(|(i, cfg)| {
                let item = AdmitItem {
                    ticket: i as u64,
                    context: bctx.clone(),
                    cfg: cfg.clone(),
                    table: Some(table.clone()),
                };
                (arrivals[i], item)
            })
            .collect(),
        boundary: 0,
        seq_rounds: 0,
        busy_rounds: 0,
        idle_rounds: 0,
        completed: 0,
    };
    speculative_generate_continuous(&bd, &bt, LockstepShape::of(&bcfgs[0]), &mut hook);
    assert_eq!(hook.completed, 4, "continuous schedule must answer all 4");
    let occ_cont =
        hook.seq_rounds as f64 / (hook.busy_rounds + hook.idle_rounds).max(1) as f64;

    // run-to-completion: a worker-round clock; each dispatch takes the max
    // of its members' round counts (lockstep), arrivals during a decode
    // wait for the next idle point
    let (mut clock, mut qi) = (0usize, 0usize);
    let (mut rtc_seq_rounds, mut rtc_busy, mut rtc_idle) = (0u64, 0u64, 0u64);
    while qi < arrivals.len() {
        if arrivals[qi] > clock {
            rtc_idle += (arrivals[qi] - clock) as u64;
            clock = arrivals[qi];
        }
        let mut take = 0;
        while qi + take < arrivals.len() && arrivals[qi + take] <= clock {
            take += 1;
        }
        let items: Vec<SpecBatchItem<'_>> = bcfgs[qi..qi + take]
            .iter()
            .map(|cfg| SpecBatchItem { context: &bctx, cfg, table: Some(table.clone()) })
            .collect();
        let outs = speculative_generate_batch(&bd, &bt, &items);
        let rounds: Vec<u64> = outs.iter().map(|o| o.as_ref().unwrap().rounds).collect();
        let rmax = *rounds.iter().max().unwrap();
        rtc_seq_rounds += rounds.iter().sum::<u64>();
        rtc_busy += rmax;
        clock += rmax as usize;
        qi += take;
    }
    let occ_rtc = rtc_seq_rounds as f64 / (rtc_busy + rtc_idle).max(1) as f64;
    println!("occupancy continuous (admit at round boundaries): {occ_cont:.3}");
    println!("occupancy run-to-completion (idle-point dispatch): {occ_rtc:.3}");
    assert!(
        occ_cont > occ_rtc,
        "continuous batching must beat run-to-completion under streaming \
         arrivals: {occ_cont:.3} vs {occ_rtc:.3}"
    );

    // ---- mixed-family streaming: shape-keyed vs (protein, method)-keyed --
    // The SeqSpec redesign's occupancy lever: the same four requests now
    // alternate between *two protein families* (each scoring against its
    // own k-mer table). Shape-keyed admission splices every arrival into
    // the one in-flight group; the old (protein, method) key forces the
    // worker to decode family-partitioned groups back to back. Occupancy
    // is sequence-rounds per worker round, idle rounds included — the
    // per-request round counts are identical under both policies (the
    // equivalence suite pins admission-independence), so the denominator
    // is the whole story.
    println!("== mixed-family streaming occupancy (B=4, 2 families, staggered) ==");
    let (_prof2, msa2) = generate_family("bench2", 120, 200, 2);
    let table2 = Arc::new(KmerTable::build(&msa2));
    let fam_tables = [table.clone(), table2.clone()];
    let fam_of = [0usize, 1, 0, 1]; // request i -> family
    let mix_arrivals = [0usize, 2, 3, 5];

    struct MixHook {
        /// (arrival boundary, family, item)
        pending: Vec<(usize, usize, AdmitItem)>,
        /// `Some(f)` = old (protein, method)-keyed run: only family `f`
        /// may join this group; `None` = shape-keyed (anything joins).
        filter: Option<usize>,
        clock: usize,
        seq_rounds: u64,
        busy_rounds: u64,
        idle_rounds: u64,
        completed: usize,
    }

    impl AdmissionHook for MixHook {
        fn admit(&mut self, active: usize) -> Vec<AdmitItem> {
            let admissible = |f: usize, filter: Option<usize>| match filter {
                None => true,
                Some(k) => k == f,
            };
            if active == 0 {
                let next = self
                    .pending
                    .iter()
                    .filter(|(_, f, _)| admissible(*f, self.filter))
                    .map(|(at, _, _)| *at)
                    .min();
                match next {
                    // nothing left for this run's key: end the run
                    None => return Vec::new(),
                    Some(at) if at > self.clock => {
                        // a *foreign-key* request already waiting must be
                        // served first under keyed dispatch: end the run
                        // rather than idling past it
                        if self
                            .pending
                            .iter()
                            .any(|(a, f, _)| !admissible(*f, self.filter) && *a <= self.clock)
                        {
                            return Vec::new();
                        }
                        self.idle_rounds += (at - self.clock) as u64;
                        self.clock = at;
                    }
                    _ => {}
                }
            }
            let (now, later): (Vec<_>, Vec<_>) = self
                .pending
                .drain(..)
                .partition(|(at, f, _)| *at <= self.clock && admissible(*f, self.filter));
            self.pending = later;
            let will_run = active + now.len();
            if will_run > 0 {
                self.busy_rounds += 1;
                self.seq_rounds += will_run as u64;
                self.clock += 1;
            }
            now.into_iter().map(|(_, _, item)| item).collect()
        }
        fn complete(&mut self, _ticket: u64, result: anyhow::Result<GenOutput>) {
            result.unwrap();
            self.completed += 1;
        }
    }

    let run_policy = |family_keyed: bool| -> f64 {
        let build_pending = || -> Vec<(usize, usize, AdmitItem)> {
            bcfgs
                .iter()
                .enumerate()
                .map(|(i, cfg)| {
                    let item = AdmitItem {
                        ticket: i as u64,
                        context: bctx.clone(),
                        cfg: cfg.clone(),
                        table: Some(fam_tables[fam_of[i]].clone()),
                    };
                    (mix_arrivals[i], fam_of[i], item)
                })
                .collect()
        };
        let mut pending = build_pending();
        let (mut seq_rounds, mut busy, mut idle) = (0u64, 0u64, 0u64);
        let mut clock = 0usize;
        let mut completed = 0usize;
        // single worker: each iteration is one popped group; under family
        // keying the group anchor is the oldest pending request's family
        while !pending.is_empty() {
            let anchor =
                pending.iter().min_by_key(|(at, _, _)| *at).map(|(_, f, _)| *f).unwrap();
            let mut hook = MixHook {
                pending: std::mem::take(&mut pending),
                filter: family_keyed.then_some(anchor),
                clock,
                seq_rounds: 0,
                busy_rounds: 0,
                idle_rounds: 0,
                completed: 0,
            };
            speculative_generate_continuous(&bd, &bt, LockstepShape::of(&bcfgs[0]), &mut hook);
            pending = hook.pending;
            clock = hook.clock;
            seq_rounds += hook.seq_rounds;
            busy += hook.busy_rounds;
            idle += hook.idle_rounds;
            completed += hook.completed;
        }
        assert_eq!(completed, 4, "policy sim must answer all 4 requests");
        seq_rounds as f64 / (busy + idle).max(1) as f64
    };

    let occ_shape_keyed = run_policy(false);
    let occ_protein_keyed = run_policy(true);
    println!("occupancy shape-keyed admission (cross-family groups):   {occ_shape_keyed:.3}");
    println!("occupancy (protein, method)-keyed (family-partitioned): {occ_protein_keyed:.3}");
    assert!(
        occ_shape_keyed > occ_protein_keyed,
        "shape-keyed admission must beat (protein, method)-keyed occupancy \
         under mixed-family staggered arrivals: {occ_shape_keyed:.3} vs \
         {occ_protein_keyed:.3}"
    );

    // ---- per-dtype decode rounds: quantized weight panels ----------------
    // The weight-traffic question of the quantized-panel work: one verify
    // round (γ=5 → 6 teacher-forced rows) on a deliberately memory-bound
    // shape — L4 d256 h4 keeps ~12.6 MiB of weight matrices against a
    // six-row activation block, so the round streams weights from memory.
    // Models are built per (dtype, fast) pair via `synthetic_with`, so one
    // bench process covers every tier regardless of the environment.
    // bytes/token divides the full panel footprint by the 6 committed rows;
    // effective GB/s divides it by the measured round time.
    println!("== per-dtype decode rounds (L4 d256 h4, verify γ=5, memory-bound) ==");
    let dt_iters: u64 = if smoke { 2 } else { 20 };
    let dt_toks = vtoks.len() as f64;
    let mut dtype_rows: Vec<Json> = Vec::new();
    let mut dt_summary: Vec<(String, f64)> = Vec::new();
    for (dname, dtype) in
        [("f32", WeightDtype::F32), ("bf16", WeightDtype::Bf16), ("int8", WeightDtype::Int8)]
    {
        for fast in [false, true] {
            let md = CpuModel::synthetic_with(4, 256, 4, 256, 42, dtype, fast);
            let mut cache_d = md.prefill(&ctx).unwrap();
            let tier = if fast { "+fast" } else { "" };
            let label = format!("verify round d256 {dname}{tier}");
            let ns = bench(&label, dt_iters, || {
                std::hint::black_box(md.verify(&mut cache_d, &vtoks, pos, 1.0, 0.95).unwrap());
            });
            let wbytes = md.weight_bytes() as f64;
            let tps = dt_toks / (ns / 1e9);
            let bytes_per_tok = wbytes / dt_toks;
            let gbps = wbytes / (ns / 1e9) / 1e9;
            dtype_rows.push(Json::obj(vec![
                ("dtype", Json::str(dname)),
                ("fast", Json::Bool(fast)),
                ("round_ns", Json::num(ns)),
                ("tokens_per_sec", Json::num(tps)),
                ("weight_bytes_per_token", Json::num(bytes_per_tok)),
                ("effective_gbps", Json::num(gbps)),
            ]));
            if !fast {
                dt_summary.push((dname.to_string(), bytes_per_tok));
                dt_summary.push((format!("{dname}_tps"), tps));
            }
        }
    }
    let dt_lookup = |key: &str| -> f64 {
        dt_summary.iter().find(|(k, _)| k == key).map(|(_, v)| *v).unwrap_or(0.0)
    };
    let (bpt_f32, bpt_bf16, bpt_int8) = (dt_lookup("f32"), dt_lookup("bf16"), dt_lookup("int8"));
    let (tps_f32, tps_bf16) = (dt_lookup("f32_tps"), dt_lookup("bf16_tps"));
    println!(
        "weight bytes/token: f32 {bpt_f32:.0}, bf16 {bpt_bf16:.0} \
         ({:.1}% cut), int8 {bpt_int8:.0} ({:.1}% cut)",
        (1.0 - bpt_bf16 / bpt_f32) * 100.0,
        (1.0 - bpt_int8 / bpt_f32) * 100.0
    );
    println!(
        "decode tokens/s: f32 {tps_f32:.1}, bf16 {tps_bf16:.1} ({:.2}x)",
        tps_bf16 / tps_f32
    );
    // storage cut is a property of the formats, not the machine: safe to pin
    assert!(
        bpt_bf16 <= 0.55 * bpt_f32,
        "bf16 panels must cut weight bytes/token by >=45% vs f32: \
         {bpt_bf16:.0} vs {bpt_f32:.0}"
    );

    // ---- tree-vs-flat speculation: acceptance at equal draft FLOPs ------
    // The tentpole question of the tree refactor: does spending the same
    // per-round draft budget on a shared-prefix forest — more root-to-leaf
    // paths for the k-mer scorer to choose between — buy a higher
    // acceptance rate than independent chains? Flat drafts c=3 chains of
    // γ=5 (15 nodes/round, 3 scoreable candidates); the tree arm drafts
    // c=2 roots with a 2-way split at depth 3 (1+1+1+2+2 = 7 nodes per
    // root → 14 nodes/round, 4 scoreable paths). Both score against the
    // same family k-mer table; acceptance is pooled over seeds.
    println!("== tree-vs-flat speculation (equal draft FLOPs: 15 vs 14 nodes/round) ==");
    let tree_seeds: u64 = if smoke { 3 } else { 10 };
    let run_arm = |label: &str, c: usize, tree: TreePolicy| -> (f64, f64, f64) {
        let (mut acc, mut rej, mut rounds, mut nodes) = (0u64, 0u64, 0u64, 0u64);
        let mut toks = 0usize;
        let t0 = Instant::now();
        for s in 0..tree_seeds {
            let cfg = GenConfig {
                c,
                gamma: 5,
                max_len: 72,
                seed: s * 13 + 5,
                kset: KmerSet::new(true, true, true),
                tree,
                ..Default::default()
            };
            let out = speculative_generate(&bd, &bt, Some(&table), &bctx, &cfg).unwrap();
            acc += out.accepted;
            rej += out.rejected;
            rounds += out.rounds;
            nodes += out.tree_nodes;
            toks += out.new_tokens();
        }
        let secs = t0.elapsed().as_secs_f64();
        let alpha = acc as f64 / (acc + rej).max(1) as f64;
        let tps = toks as f64 / secs;
        let npr = nodes as f64 / rounds.max(1) as f64;
        println!("{label:<44} alpha {alpha:.3}  {tps:>9.1} tok/s  {npr:>5.1} nodes/round");
        (alpha, tps, npr)
    };
    let (alpha_flat, tps_flat, npr_flat) =
        run_arm("spec decode flat c=3 γ=5", 3, TreePolicy::default());
    let (alpha_tree, tps_tree, npr_tree) = run_arm(
        "spec decode tree c=2 γ=5 branch=2 split@3",
        2,
        TreePolicy { branch: 2, split_mask: 0b1000 },
    );
    println!(
        "tree-vs-flat acceptance at equal draft FLOPs: {alpha_tree:.3} (tree, \
         {npr_tree:.0} nodes) vs {alpha_flat:.3} (flat, {npr_flat:.0} nodes)"
    );

    // ---- admission latency: cold one-shot prefill vs warm CoW attach -----
    // The admission-path question of the prefix-cache work: what does a
    // warm admission actually cost? Cold runs the full-context forward
    // pass (`prefill`, 40 fed positions here); warm runs a prefix-store
    // lookup (fnv1a hash + exact byte compare + LRU touch) plus the
    // copy-on-write attach (`prefill_into`), which shares the cached host
    // snapshot instead of recomputing — or even copying — the KV rows.
    // The warm path must be strictly cheaper; the serving win scales with
    // context length, so even this short context must show it.
    println!("== admission latency: cold prefill vs warm CoW attach ==");
    let adm_iters: u64 = if smoke { 10 } else { 200 };
    let mut adm_store = PrefixStore::new(64 << 20);
    let adm_snap = Arc::new(m.cache_to_host(&m.prefill(&ctx).unwrap()).unwrap());
    adm_store.insert(&ctx, adm_snap);
    let admission_cold_ns = bench("admission cold (one-shot prefill)", adm_iters, || {
        std::hint::black_box(m.prefill(&ctx).unwrap());
    });
    let admission_warm_ns = bench("admission warm (lookup + CoW attach)", adm_iters, || {
        let hit = adm_store.lookup(&ctx).expect("warm admission bench must hit the store");
        std::hint::black_box(m.prefill_into(&hit).unwrap());
    });
    let admission_speedup = admission_cold_ns / admission_warm_ns;
    println!("warm-vs-cold admission speedup: {admission_speedup:.1}x");
    assert!(
        admission_warm_ns < admission_cold_ns,
        "warm CoW attach must be strictly cheaper than cold prefill: \
         {admission_warm_ns:.1} vs {admission_cold_ns:.1} ns"
    );

    let json = Json::obj(vec![
        ("model", Json::str("synthetic L4 d64 h4 S256")),
        ("c", Json::num(c as f64)),
        ("gamma", Json::num(gamma as f64)),
        ("kernel_dispatch", Json::str(simd::active().name())),
        ("kernel_threads", Json::num(compute_threads() as f64)),
        ("weight_dtype", Json::str(simd::weight_dtype().name())),
        ("fast_tier", Json::Bool(simd::fast_tier())),
        ("gemm_st_8x256x256_ns_scalar_ref", Json::num(gemm_scalar_ns)),
        ("gemm_st_8x256x256_ns_vectorized", Json::num(gemm_simd_ns)),
        ("gemm_st_speedup_vs_scalar", Json::num(gemm_st_speedup)),
        ("gemm_mt_64x256x512_ns_single", Json::num(gemm_mt_single_ns)),
        ("gemm_mt_64x256x512_ns_pool", Json::num(gemm_mt_pool_ns)),
        ("gemm_mt_speedup_vs_single", Json::num(gemm_mt_speedup)),
        ("logits_head_r8_d64_v32_ns_seed_nt", Json::num(head_seed_ns)),
        ("logits_head_r8_d64_v32_ns_prepacked", Json::num(head_packed_ns)),
        ("logits_head_speedup_vs_seed", Json::num(head_speedup)),
        ("attention_vaccum_s256_dh64_ns_scalar", Json::num(att_scalar_ns)),
        ("attention_vaccum_s256_dh64_ns_lanes", Json::num(att_simd_ns)),
        ("attention_vaccum_speedup_vs_scalar", Json::num(att_speedup)),
        ("draft_round_ns_batched", Json::num(draft_new)),
        ("draft_round_ns_seed", Json::num(draft_seed)),
        ("draft_round_speedup_c3_g5", Json::num(draft_speedup)),
        ("verify_round_ns_batched", Json::num(verify_new)),
        ("verify_round_ns_seed", Json::num(verify_seed)),
        ("verify_round_speedup_g5", Json::num(verify_speedup)),
        ("batch_decode_b4_ns_serial", Json::num(serial_ns)),
        ("batch_decode_b4_ns_batched", Json::num(batched_ns)),
        ("batch_decode_b4_tokens_per_sec_serial", Json::num(serial_tps)),
        ("batch_decode_b4_tokens_per_sec_batched", Json::num(batched_tps)),
        ("batch_decode_speedup_b4", Json::num(batch_speedup)),
        ("streaming_b4_occupancy_continuous", Json::num(occ_cont)),
        ("streaming_b4_occupancy_run_to_completion", Json::num(occ_rtc)),
        ("streaming_mixed_b4_occupancy_shape_keyed", Json::num(occ_shape_keyed)),
        ("streaming_mixed_b4_occupancy_protein_keyed", Json::num(occ_protein_keyed)),
        ("tree_vs_flat_alpha_flat_c3_g5", Json::num(alpha_flat)),
        ("tree_vs_flat_alpha_tree_c2_b2_split3", Json::num(alpha_tree)),
        ("tree_vs_flat_tokens_per_sec_flat", Json::num(tps_flat)),
        ("tree_vs_flat_tokens_per_sec_tree", Json::num(tps_tree)),
        ("tree_vs_flat_nodes_per_round_flat", Json::num(npr_flat)),
        ("tree_vs_flat_nodes_per_round_tree", Json::num(npr_tree)),
        ("decode_rounds_by_dtype", Json::Arr(dtype_rows)),
        ("decode_round_weight_bytes_per_token_f32", Json::num(bpt_f32)),
        ("decode_round_weight_bytes_per_token_bf16", Json::num(bpt_bf16)),
        ("decode_round_weight_bytes_per_token_int8", Json::num(bpt_int8)),
        ("decode_round_tokens_per_sec_f32", Json::num(tps_f32)),
        ("decode_round_tokens_per_sec_bf16", Json::num(tps_bf16)),
        ("admission_cold_prefill_ns", Json::num(admission_cold_ns)),
        ("admission_warm_attach_ns", Json::num(admission_warm_ns)),
        ("admission_warm_speedup_vs_cold", Json::num(admission_speedup)),
        ("smoke", Json::Bool(smoke)),
    ]);
    std::fs::create_dir_all("results").ok();
    match std::fs::write("results/bench_micro.json", format!("{json}\n")) {
        Ok(()) => println!("[bench_micro] wrote results/bench_micro.json"),
        Err(e) => eprintln!("[bench_micro] could not write results/bench_micro.json: {e}"),
    }
}
