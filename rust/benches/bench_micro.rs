//! Micro-benchmarks of the L3 hot paths (criterion is unavailable offline;
//! this is a minimal warmup+measure harness with median-of-runs output).
//! These feed EXPERIMENTS.md §Perf.
//!
//! Besides the scalar kernels, this bench measures a full **draft round**
//! (`generate` at c=3, γ=5) and a **verify round** on a synthetic model,
//! both for the batched branched-cache runtime and for the seed
//! clone-per-candidate implementation (`cpu_ref::reference`), plus the
//! worker-level question — four full generations dispatched as **lockstep
//! batched rounds vs a serial request loop** — and emits the numbers
//! machine-readably to `results/bench_micro.json`. Set
//! `SPECMER_BENCH_SMOKE=1` for a fast CI smoke run.

use std::time::Instant;

use specmer::decode::{speculative_generate, speculative_generate_batch, GenConfig, SpecBatchItem};
use specmer::kmer::{score_block, KmerSet, KmerTable};
use specmer::msa::simulate::generate_family;
use specmer::runtime::cpu_ref::{reference, CpuModel};
use specmer::runtime::ModelBackend;
use specmer::sampling;
use specmer::util::json::Json;
use specmer::util::rng::Pcg64;

/// Median ns/iter over 5 measured runs (after warmup).
fn bench_ns<F: FnMut()>(iters: u64, mut f: F) -> f64 {
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let mut runs = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        runs.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    runs[2]
}

fn bench<F: FnMut()>(name: &str, iters: u64, f: F) -> f64 {
    let ns = bench_ns(iters, f);
    println!("{name:<44} {ns:>12.1} ns/iter (median of 5)");
    ns
}

fn main() {
    let smoke = std::env::var("SPECMER_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let scale: u64 = if smoke { 100 } else { 1 };

    let (_prof, msa) = generate_family("bench", 120, 200, 1);
    let table = KmerTable::build(&msa);
    let mut rng = Pcg64::new(7);
    let block5: Vec<u8> = (0..5).map(|_| 3 + rng.below(20) as u8).collect();
    let block15: Vec<u8> = (0..15).map(|_| 3 + rng.below(20) as u8).collect();
    let ks = KmerSet::new(true, true, true);

    println!("== L3 hot-path micro-benchmarks ==");
    bench("kmer score_block gamma=5 k=1,3,5", 200_000 / scale, || {
        std::hint::black_box(score_block(&table, &block5, ks));
    });
    bench("kmer score_block gamma=15 k=1,3,5", 200_000 / scale, || {
        std::hint::black_box(score_block(&table, &block15, ks));
    });

    let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
    bench("adjust_dist (softmax+nucleus) V=32", 100_000 / scale, || {
        std::hint::black_box(sampling::adjust_dist(&logits, 0.9, 0.95));
    });

    let p = sampling::adjust_dist(&logits, 1.0, 1.0);
    let q = sampling::adjust_dist(&logits, 0.8, 0.95);
    let mut crng = Pcg64::new(3);
    bench("maximal coupling step", 100_000 / scale, || {
        let x = sampling::sample(&p, crng.next_f32());
        std::hint::black_box(sampling::couple(&p, &q, x, &mut crng));
    });

    bench("residual distribution V=32", 100_000 / scale, || {
        std::hint::black_box(sampling::residual(&p, &q));
    });

    let mut trng = Pcg64::new(9);
    bench("pcg64 next_f32", 1_000_000 / scale, || {
        std::hint::black_box(trng.next_f32());
    });

    bench("kmer table build (120x200 MSA)", (20 / scale).max(2), || {
        std::hint::black_box(KmerTable::build(&msa));
    });

    // ---- draft / verify round benches: batched vs seed implementation ----
    // Synthetic but non-trivial model: 4 layers, d=64, 4 heads, S=256. The
    // seed path clones the full [L,2,H,S,Dh] cache (512 KiB) per candidate
    // per round and runs scalar mat-vecs; the batched path branches the
    // cache and runs blocked GEMMs.
    println!("== draft/verify round benches (c=3, γ=5, synthetic d=64) ==");
    let m = CpuModel::synthetic(4, 64, 4, 256, 42);
    let ctx: Vec<u8> = {
        let mut v = vec![1u8];
        v.extend((0..40).map(|i| 3 + ((i * 11) % 20) as u8));
        v
    };
    let pos = ctx.len() - 1;
    let feed = vec![ctx[pos]];
    let (c, gamma) = (3usize, 5usize);
    let u: Vec<f32> = (0..c * gamma).map(|i| (i as f32 * 0.137) % 1.0).collect();
    let round_iters: u64 = if smoke { 3 } else { 30 };

    let mut cache_new = m.prefill(&ctx).unwrap();
    let draft_new = bench("draft round c=3 γ=5 (batched/branched)", round_iters, || {
        std::hint::black_box(
            m.generate(&mut cache_new, &feed, pos, c, gamma, &u, 1.0, 0.95).unwrap(),
        );
    });

    let mut cache_ref = m.prefill(&ctx).unwrap();
    let draft_seed = bench("draft round c=3 γ=5 (seed clone-per-cand)", round_iters, || {
        std::hint::black_box(reference::generate(
            &m, &mut cache_ref, &feed, pos, c, gamma, &u, 1.0, 0.95,
        ));
    });

    let vtoks: Vec<u8> = vec![ctx[pos], 4, 7, 9, 12, 15];
    let mut cache_v = m.prefill(&ctx).unwrap();
    let verify_new = bench("verify round γ=5 (batched)", round_iters, || {
        std::hint::black_box(m.verify(&mut cache_v, &vtoks, pos, 1.0, 0.95).unwrap());
    });

    let mut cache_vr = m.prefill(&ctx).unwrap();
    let verify_seed = bench("verify round γ=5 (seed per-position)", round_iters, || {
        std::hint::black_box(reference::verify(&m, &mut cache_vr, &vtoks, pos, 1.0, 0.95));
    });

    let draft_speedup = draft_seed / draft_new;
    let verify_speedup = verify_seed / verify_new;
    println!("draft-round speedup vs seed:  {draft_speedup:.2}x");
    println!("verify-round speedup vs seed: {verify_speedup:.2}x");

    // ---- cross-request batching: B=4 lockstep decode vs the serial loop --
    // Full generations (all rounds to max_len/EOS) for four requests with
    // different seeds — the worker-level question: does dispatching the
    // batch through shared decode rounds beat iterating it?
    println!("== cross-request decode benches (B=4, c=3, γ=5) ==");
    let bd = CpuModel::synthetic(4, 64, 4, 256, 41);
    let bt = CpuModel::synthetic(4, 64, 4, 256, 43);
    let bcfgs: Vec<GenConfig> = (0..4u64)
        .map(|seed| GenConfig {
            c: 3,
            gamma: 5,
            max_len: 72,
            seed: seed * 7 + 1,
            kset: KmerSet::new(true, true, true),
            ..Default::default()
        })
        .collect();
    let bctx: Vec<u8> = ctx.clone();
    let gen_iters: u64 = if smoke { 1 } else { 5 };

    // committed tokens are identical across both paths (the equivalence
    // tests pin it), so count them once up front — this pass doubles as
    // warmup — and reuse the sum for both throughput numbers
    let new_tokens: usize = bcfgs
        .iter()
        .map(|cfg| {
            speculative_generate(&bd, &bt, Some(&table), &bctx, cfg).unwrap().new_tokens()
        })
        .sum();

    let serial_ns = bench("decode B=4 (serial request loop)", gen_iters, || {
        for cfg in &bcfgs {
            std::hint::black_box(
                speculative_generate(&bd, &bt, Some(&table), &bctx, cfg).unwrap(),
            );
        }
    });
    let batched_ns = bench("decode B=4 (lockstep batched rounds)", gen_iters, || {
        let items: Vec<SpecBatchItem<'_>> =
            bcfgs.iter().map(|cfg| SpecBatchItem { context: &bctx, cfg }).collect();
        for out in speculative_generate_batch(&bd, &bt, Some(&table), &items) {
            std::hint::black_box(out.unwrap());
        }
    });
    let serial_tps = new_tokens as f64 / (serial_ns / 1e9);
    let batched_tps = new_tokens as f64 / (batched_ns / 1e9);
    let batch_speedup = serial_ns / batched_ns;
    println!("serial  B=4 throughput: {serial_tps:.1} tok/s");
    println!("batched B=4 throughput: {batched_tps:.1} tok/s");
    println!("batched-vs-serial decode speedup: {batch_speedup:.2}x");

    let json = Json::obj(vec![
        ("model", Json::str("synthetic L4 d64 h4 S256")),
        ("c", Json::num(c as f64)),
        ("gamma", Json::num(gamma as f64)),
        ("draft_round_ns_batched", Json::num(draft_new)),
        ("draft_round_ns_seed", Json::num(draft_seed)),
        ("draft_round_speedup_c3_g5", Json::num(draft_speedup)),
        ("verify_round_ns_batched", Json::num(verify_new)),
        ("verify_round_ns_seed", Json::num(verify_seed)),
        ("verify_round_speedup_g5", Json::num(verify_speedup)),
        ("batch_decode_b4_ns_serial", Json::num(serial_ns)),
        ("batch_decode_b4_ns_batched", Json::num(batched_ns)),
        ("batch_decode_b4_tokens_per_sec_serial", Json::num(serial_tps)),
        ("batch_decode_b4_tokens_per_sec_batched", Json::num(batched_tps)),
        ("batch_decode_speedup_b4", Json::num(batch_speedup)),
        ("smoke", Json::Bool(smoke)),
    ]);
    std::fs::create_dir_all("results").ok();
    match std::fs::write("results/bench_micro.json", format!("{json}\n")) {
        Ok(()) => println!("[bench_micro] wrote results/bench_micro.json"),
        Err(e) => eprintln!("[bench_micro] could not write results/bench_micro.json: {e}"),
    }
}
