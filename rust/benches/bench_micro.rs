//! Micro-benchmarks of the L3 hot paths (criterion is unavailable offline;
//! this is a minimal warmup+measure harness with median-of-runs output).
//! These feed EXPERIMENTS.md §Perf.

use std::time::Instant;

use specmer::kmer::{score_block, KmerSet, KmerTable};
use specmer::msa::simulate::generate_family;
use specmer::sampling;
use specmer::util::rng::Pcg64;

fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let mut runs = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        runs.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("{name:<40} {:>12.1} ns/iter (median of 5)", runs[2]);
}

fn main() {
    let (_prof, msa) = generate_family("bench", 120, 200, 1);
    let table = KmerTable::build(&msa);
    let mut rng = Pcg64::new(7);
    let block5: Vec<u8> = (0..5).map(|_| 3 + rng.below(20) as u8).collect();
    let block15: Vec<u8> = (0..15).map(|_| 3 + rng.below(20) as u8).collect();
    let ks = KmerSet::new(true, true, true);

    println!("== L3 hot-path micro-benchmarks ==");
    bench("kmer score_block gamma=5 k=1,3,5", 200_000, || {
        std::hint::black_box(score_block(&table, &block5, ks));
    });
    bench("kmer score_block gamma=15 k=1,3,5", 200_000, || {
        std::hint::black_box(score_block(&table, &block15, ks));
    });

    let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
    bench("adjust_dist (softmax+nucleus) V=32", 100_000, || {
        std::hint::black_box(sampling::adjust_dist(&logits, 0.9, 0.95));
    });

    let p = sampling::adjust_dist(&logits, 1.0, 1.0);
    let q = sampling::adjust_dist(&logits, 0.8, 0.95);
    let mut crng = Pcg64::new(3);
    bench("maximal coupling step", 100_000, || {
        let x = sampling::sample(&p, crng.next_f32());
        std::hint::black_box(sampling::couple(&p, &q, x, &mut crng));
    });

    bench("residual distribution V=32", 100_000, || {
        std::hint::black_box(sampling::residual(&p, &q));
    });

    let mut trng = Pcg64::new(9);
    bench("pcg64 next_f32", 1_000_000, || {
        std::hint::black_box(trng.next_f32());
    });

    bench("kmer table build (120x200 MSA)", 20, || {
        std::hint::black_box(KmerTable::build(&msa));
    });
}
