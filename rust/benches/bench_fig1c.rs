//! Regenerates the paper's fig1c (cargo bench target, harness = false).
//! Env: SPECMER_BENCH_N (seqs/cell), SPECMER_BENCH_FULL (paper grid),
//! SPECMER_BENCH_PROTEINS (subset). Output: results/fig1c.{md,csv}.
fn main() {
    specmer::experiments::bench_main(&["fig1c"]);
}
