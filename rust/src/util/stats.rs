//! Descriptive statistics helpers used by metrics and the experiment harness.

/// Mean of a slice (0.0 if empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (n-1 denominator; 0.0 for n < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// `mean ± std` string with fixed precision, as in the paper's tables.
pub fn pm(xs: &[f64], prec: usize) -> String {
    format!("{:.p$} ± {:.p$}", mean(xs), std(xs), p = prec)
}

/// Linear-interpolated percentile, q in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Mean of the k smallest values (paper's "top-k NLL": lower is better).
pub fn mean_smallest(xs: &[f64], k: usize) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s.truncate(k.max(1).min(s.len()));
    mean(&s)
}

/// Mean of the k largest values (paper's "top-k pLDDT": higher is better).
pub fn mean_largest(xs: &[f64], k: usize) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| b.partial_cmp(a).unwrap());
    s.truncate(k.max(1).min(s.len()));
    mean(&s)
}

/// Std of the k smallest values.
pub fn std_smallest(xs: &[f64], k: usize) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s.truncate(k.max(1).min(s.len()));
    std(&s)
}

/// Std of the k largest values.
pub fn std_largest(xs: &[f64], k: usize) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| b.partial_cmp(a).unwrap());
    s.truncate(k.max(1).min(s.len()));
    std(&s)
}

/// Fixed-bin histogram over [lo, hi]; values outside clamp to edge bins.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if xs.is_empty() || hi <= lo {
        return h;
    }
    for &x in xs {
        let t = ((x - lo) / (hi - lo) * bins as f64).floor();
        let b = (t.max(0.0) as usize).min(bins - 1);
        h[b] += 1;
    }
    h
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..n {
        num += (xs[i] - mx) * (ys[i] - my);
        dx += (xs[i] - mx).powi(2);
        dy += (ys[i] - my).powi(2);
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

/// Online mean/std accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn topk_selectors() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert!((mean_smallest(&xs, 2) - 1.5).abs() < 1e-12);
        assert!((mean_largest(&xs, 2) - 4.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps() {
        let h = histogram(&[-1.0, 0.1, 0.5, 0.9, 2.0], 0.0, 1.0, 2);
        // -1 clamps into bin 0; 0.5 lands exactly on the boundary -> bin 1;
        // 2.0 clamps into bin 1.
        assert_eq!(h, vec![2, 3]);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.5, -1.0, 0.25];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }
}
