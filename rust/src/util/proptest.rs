//! Miniature property-based testing framework (proptest is unavailable
//! offline). Provides seeded generators and a `check` runner with
//! linear-search shrinking for the common case (Vec inputs shrink by
//! halving, scalars shrink toward zero).
//!
//! Usage:
//! ```ignore
//! use specmer::util::proptest::{check, Gen};
//! check("sum is commutative", 200, |g| {
//!     let a = g.vec_f64(0..50, -1e3..1e3);
//!     let b = g.vec_f64(0..50, -1e3..1e3);
//!     prop_assert(..);
//! });
//! ```

use super::rng::Pcg64;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Generator handed to property closures.
pub struct Gen {
    rng: Pcg64,
    /// shrink factor in (0,1]; 1.0 = full-size cases.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: Pcg64::new(seed), size: 1.0 }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        if r.is_empty() {
            return r.start;
        }
        let span = ((r.end - r.start) as f64 * self.size).ceil().max(1.0) as usize;
        r.start + self.rng.below(span.min(r.end - r.start))
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        r.start + self.rng.next_f64() * (r.end - r.start)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f64(&mut self, len: Range<usize>, vals: Range<f64>) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f64_in(vals.clone())).collect()
    }

    pub fn vec_usize(&mut self, len: Range<usize>, vals: Range<usize>) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.usize_in(vals.clone())).collect()
    }

    /// Probability vector of the given length (sums to 1, all >= 0).
    pub fn dist(&mut self, len: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..len).map(|_| self.rng.next_f64() + 1e-9).collect();
        let s: f64 = v.iter().sum();
        v.iter_mut().for_each(|x| *x /= s);
        v
    }

    /// Sparse probability vector: some entries exactly zero (top-p-like).
    pub fn sparse_dist(&mut self, len: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..len)
            .map(|_| if self.rng.next_f64() < 0.4 { 0.0 } else { self.rng.next_f64() })
            .collect();
        if v.iter().all(|&x| x == 0.0) {
            v[self.rng.below(len)] = 1.0;
        }
        let s: f64 = v.iter().sum();
        v.iter_mut().for_each(|x| *x /= s);
        v
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. On failure, retries the failing seed
/// at smaller sizes to report a (roughly) minimal case, then panics with the
/// seed so the case can be replayed.
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: u64, prop: F) {
    let base = 0x5EC_4E5u64;
    for i in 0..cases {
        let seed = base ^ (i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let failed = {
            let mut g = Gen::new(seed);
            catch_unwind(AssertUnwindSafe(|| prop(&mut g))).is_err()
        };
        if failed {
            // try to shrink: replay same seed with smaller size factors
            let mut min_size = 1.0;
            for &s in &[0.5, 0.25, 0.1, 0.05] {
                let mut g = Gen::new(seed);
                g.size = s;
                if catch_unwind(AssertUnwindSafe(|| prop(&mut g))).is_err() {
                    min_size = s;
                }
            }
            // run once more un-caught so the original assertion surfaces
            let mut g = Gen::new(seed);
            g.size = min_size;
            eprintln!("property '{name}' failed: seed={seed:#x} size={min_size}");
            prop(&mut g);
            unreachable!("property must fail when replayed");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("abs is non-negative", 50, |g| {
            let x = g.f64_in(-100.0..100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic]
    fn fails_false_property() {
        check("all vecs shorter than 3", 200, |g| {
            let v = g.vec_f64(0..10, 0.0..1.0);
            assert!(v.len() < 3);
        });
    }

    #[test]
    fn dist_sums_to_one() {
        check("dist normalized", 100, |g| {
            let d = g.dist(32);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    fn sparse_dist_valid() {
        check("sparse dist normalized", 100, |g| {
            let d = g.sparse_dist(16);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        });
    }
}
