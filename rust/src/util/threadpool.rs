//! Fixed-size thread pool (tokio is unavailable offline; the serving layer
//! runs on blocking threads + channels, which at our request rates is
//! indistinguishable from an async runtime and much simpler to reason about).
//!
//! Two pools live here so every form of parallelism in the crate is in one
//! place:
//!
//!   * [`ThreadPool`] — the classic shared-queue pool for `'static` jobs
//!     (serving workers, `parallel_map`).
//!   * [`ScopedPool`] — a **persistent** pool for borrowed data-parallel
//!     compute. The GEMM row-parallel path used to spawn fresh OS threads
//!     via `thread::scope` on every large-shape call, paying thread-spawn
//!     latency every decode round; [`compute_pool`] keeps one set of
//!     workers alive for the whole process and hands them task indices
//!     through an atomic claim counter instead.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A classic shared-queue thread pool with graceful shutdown on drop.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inf = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                inf.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Submit a job; never blocks.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until the queue drains. Test/benchmark helper.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parse a `SPECMER_THREADS` value: a positive thread count, or an error
/// naming what was wrong (so the resolver can warn instead of silently
/// ignoring a typo'd override).
pub(crate) fn parse_threads(raw: &str) -> Result<usize, String> {
    match raw.trim().parse::<usize>() {
        Ok(0) => Err("thread count must be positive".into()),
        Ok(n) => Ok(n),
        Err(e) => Err(e.to_string()),
    }
}

/// Process-wide compute thread budget, resolved **once** (the GEMM entry
/// points used to re-query `available_parallelism()` on every call): the
/// `SPECMER_THREADS` env override (for reproducible benching) wins,
/// otherwise `available_parallelism`. An unparsable override warns once —
/// resolution is cached in the `OnceLock` — naming the fallback taken.
pub fn compute_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        let auto = || thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
        match std::env::var("SPECMER_THREADS") {
            Ok(raw) => parse_threads(&raw).unwrap_or_else(|why| {
                let n = auto();
                eprintln!(
                    "[specmer] SPECMER_THREADS={raw:?} ignored ({why}); \
                     falling back to available_parallelism = {n}"
                );
                n
            }),
            Err(_) => auto(),
        }
    })
}

/// The process-wide persistent [`ScopedPool`] the compute kernels run on,
/// spawned lazily with [`compute_threads`] participants (the submitting
/// thread counts as one, so `compute_threads() - 1` workers are spawned).
pub fn compute_pool() -> &'static ScopedPool {
    static POOL: OnceLock<ScopedPool> = OnceLock::new();
    POOL.get_or_init(|| ScopedPool::new(compute_threads()))
}

/// Borrowed task closure published to the pool workers. The submitter does
/// not return from [`ScopedPool::run`] until every claimed task finished,
/// so the `'static` lifetime is a loan the workers never outlive.
struct TaskFn(&'static (dyn Fn(usize) + Sync));

/// One published parallel job: a task closure plus claim/finish counters.
struct JobInner {
    f: TaskFn,
    /// Next unclaimed task index (claimed with `fetch_add`).
    next: AtomicUsize,
    /// Tasks that finished running (the submitter joins on this).
    done: AtomicUsize,
    total: usize,
    /// Set when any task panicked; the submitter re-panics after the join.
    panicked: AtomicBool,
}

struct Slot {
    job: Option<Arc<JobInner>>,
    /// Set by `Drop`: workers exit their wait loop instead of parking.
    stop: bool,
}

struct PoolShared {
    slot: Mutex<Slot>,
    /// Workers wait here for a job with unclaimed tasks.
    work: Condvar,
    /// Submitters wait here for task completion / the slot to free up.
    done: Condvar,
}

/// Persistent scoped worker pool for borrowed data-parallel compute.
///
/// Unlike [`ThreadPool`], jobs may borrow caller data: `run` publishes the
/// closure, the workers (and the submitting thread itself) claim task
/// indices from a shared atomic counter, and `run` only returns once every
/// task finished — so the borrow outlives every dereference. One job runs
/// at a time; concurrent submitters (one engine worker per serving thread)
/// queue on the slot, which matches the old `thread::scope` behaviour of
/// sharing the machine's cores between concurrent large GEMMs.
pub struct ScopedPool {
    shared: Arc<PoolShared>,
    workers: usize,
    handles: Vec<thread::JoinHandle<()>>,
}

fn run_tasks(job: &JobInner) {
    loop {
        let i = job.next.fetch_add(1, Ordering::SeqCst);
        if i >= job.total {
            break;
        }
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (job.f.0)(i))).is_ok();
        if !ok {
            job.panicked.store(true, Ordering::SeqCst);
        }
        job.done.fetch_add(1, Ordering::SeqCst);
    }
}

fn scoped_worker(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.stop {
                    return;
                }
                match slot.job.as_ref() {
                    Some(j) if j.next.load(Ordering::SeqCst) < j.total => break Arc::clone(j),
                    _ => slot = shared.work.wait(slot).unwrap(),
                }
            }
        };
        run_tasks(&job);
        // we may have just finished the job's last task: wake the submitter
        // (taking the lock orders the wake after its `done` re-check)
        let _guard = shared.slot.lock().unwrap();
        shared.done.notify_all();
    }
}

impl ScopedPool {
    /// Pool with `threads` total participants; spawns `threads - 1`
    /// persistent workers (the submitting thread executes tasks too).
    pub fn new(threads: usize) -> ScopedPool {
        let shared = Arc::new(PoolShared {
            slot: Mutex::new(Slot { job: None, stop: false }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = threads.saturating_sub(1);
        let handles = (0..workers)
            .map(|i| {
                let s = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("compute-{i}"))
                    .spawn(move || scoped_worker(s))
                    .expect("spawn compute worker")
            })
            .collect();
        ScopedPool { shared, workers, handles }
    }

    /// Worker threads backing this pool (0 = `run` always inlines).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(0..total)` across the pool, returning when every task
    /// finished. Tasks must not submit nested `run` calls (the compute
    /// kernels never do); a panicking task is caught, the remaining tasks
    /// still run, and `run` re-panics on the submitting thread.
    pub fn run(&self, total: usize, f: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        if self.workers == 0 || total == 1 {
            for i in 0..total {
                f(i);
            }
            return;
        }
        // SAFETY: this transmute only extends the *lifetime* argument of the
        // reference (`&'a dyn Fn(usize) + Sync` → `&'static dyn Fn(usize) +
        // Sync`); the pointee type and fat-pointer layout are unchanged. The
        // forged 'static is never acted on: the reference is lent to the
        // workers only for the duration of this call — the wait loop below
        // blocks until `done == total`, i.e. every claimed task has finished
        // running `f`, before `run` returns and the true lifetime 'a ends —
        // and the job slot is cleared under the lock before the borrow
        // expires, so no unclaimed copy of the reference survives either.
        // Workers can observe the Arc'd `JobInner` after that, but its
        // `TaskFn` is never invoked again once `next >= total`.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let job = Arc::new(JobInner {
            f: TaskFn(f_static),
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            total,
            panicked: AtomicBool::new(false),
        });
        {
            let mut slot = self.shared.slot.lock().unwrap();
            while slot.job.is_some() {
                // another thread's kernel call owns the pool: queue up
                slot = self.shared.done.wait(slot).unwrap();
            }
            slot.job = Some(Arc::clone(&job));
            self.shared.work.notify_all();
        }
        // the submitter works too: claim tasks until none remain
        run_tasks(&job);
        let mut slot = self.shared.slot.lock().unwrap();
        while job.done.load(Ordering::SeqCst) < total {
            slot = self.shared.done.wait(slot).unwrap();
        }
        slot.job = None;
        drop(slot);
        // wake submitters queued on the now-free slot
        self.shared.done.notify_all();
        if job.panicked.load(Ordering::SeqCst) {
            panic!("scoped pool task panicked");
        }
    }
}

impl Drop for ScopedPool {
    fn drop(&mut self) {
        // `run` borrows the pool, so no job can be in flight here; flag the
        // workers out of their wait loop and join them (the process-global
        // `compute_pool` lives in a static and is never dropped)
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.stop = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw base pointer smuggled into the chunk tasks; soundness is argued at
/// the single construction site in [`parallel_chunks_mut`].
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: SendPtr crosses threads only so that each pool task can carve out
// its own disjoint `&mut [T]` chunk, which is moving `T` values to another
// thread in all but name — hence the `T: Send` bound (a bare `T` would let
// e.g. `Rc` migrate). The pointer itself is never dereferenced without the
// per-task disjointness argument at the construction site.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: tasks receive SendPtr by copy through a `Fn + Sync` closure, so the
// shared `&SendPtr` must be usable from many threads; all access goes through
// the copied raw pointer into disjoint chunks (same argument as `Send`), and
// `T: Send` is required for the same reason as above.
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Scoped data-parallel helper for the compute kernels (`runtime::gemm`):
/// split `data` into `chunk_len`-sized mutable chunks and run `f(i, chunk)`
/// for each chunk concurrently, returning once all chunks finish. Runs on
/// the persistent [`compute_pool`] instead of spawning threads per call.
///
/// A single chunk (or empty input) runs inline on the caller's thread.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() || chunk_len == 0 {
        return;
    }
    if data.len() <= chunk_len {
        f(0, data);
        return;
    }
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let len = data.len();
    let base = SendPtr(data.as_mut_ptr());
    let task = move |i: usize| {
        let start = i * chunk_len;
        let end = ((i + 1) * chunk_len).min(len);
        // SAFETY: task i covers exactly data[start..end); tasks cover
        // disjoint in-bounds ranges, T is Send, and `run` joins every task
        // before returning, so no chunk outlives the caller's exclusive
        // borrow of `data`.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(i, chunk);
    };
    compute_pool().run(n_chunks, &task);
}

/// Run `f` over `items` with `n` threads, preserving order of results.
pub fn parallel_map<T, R, F>(n: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let items: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let results = Arc::new(Mutex::new(Vec::<(usize, R)>::new()));
    {
        let pool = ThreadPool::new(n.max(1));
        for (i, item) in items {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            pool.execute(move || {
                let r = f(item);
                results.lock().unwrap().push((i, r));
            });
        }
        pool.wait_idle();
    }
    let mut out = Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(4, (0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_chunks_mut_covers_all_chunks() {
        let mut data: Vec<u64> = (0..103).collect();
        parallel_chunks_mut(&mut data, 10, |i, chunk| {
            for x in chunk.iter_mut() {
                *x += (i as u64) * 1000;
            }
        });
        for (j, &x) in data.iter().enumerate() {
            assert_eq!(x, (j / 10) as u64 * 1000 + j as u64);
        }
        // single chunk runs inline
        let mut one = vec![1u64, 2, 3];
        parallel_chunks_mut(&mut one, 8, |i, chunk| {
            assert_eq!(i, 0);
            chunk[0] = 9;
        });
        assert_eq!(one, vec![9, 2, 3]);
    }

    #[test]
    fn in_flight_reaches_zero() {
        let pool = ThreadPool::new(2);
        for _ in 0..10 {
            pool.execute(|| {});
        }
        pool.wait_idle();
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn scoped_pool_runs_every_task_exactly_once() {
        let pool = ScopedPool::new(3);
        let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
        // reuse the same pool across submissions (persistence is the point)
        for _ in 0..5 {
            pool.run(hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
        }
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 5, "task {i}");
        }
    }

    #[test]
    fn scoped_pool_single_participant_runs_inline() {
        let pool = ScopedPool::new(1);
        assert_eq!(pool.workers(), 0);
        let hits = AtomicU64::new(0);
        pool.run(4, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn scoped_pool_concurrent_submitters_all_complete() {
        let pool = Arc::new(ScopedPool::new(2));
        let total = Arc::new(AtomicU64::new(0));
        thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                s.spawn(move || {
                    for _ in 0..10 {
                        pool.run(8, &|_| {
                            total.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 10 * 8);
    }

    #[test]
    fn scoped_pool_drop_joins_workers() {
        let pool = ScopedPool::new(3);
        pool.run(8, &|_| {});
        drop(pool); // must not hang or leak parked workers
    }

    #[test]
    #[should_panic(expected = "scoped pool task panicked")]
    fn scoped_pool_propagates_task_panic() {
        let pool = ScopedPool::new(2);
        pool.run(4, &|i| {
            assert!(i != 2, "boom");
        });
    }

    #[test]
    fn compute_threads_is_stable_and_positive() {
        let a = compute_threads();
        let b = compute_threads();
        assert!(a >= 1);
        assert_eq!(a, b, "resolved once, stable across calls");
    }

    /// The `SPECMER_THREADS` parse path: positive counts accepted (with
    /// whitespace), zero and garbage rejected with a reason (the resolver
    /// warns and falls back instead of silently ignoring the override).
    #[test]
    fn threads_parse_accepts_positive_counts_and_names_failures() {
        assert_eq!(parse_threads("4"), Ok(4));
        assert_eq!(parse_threads(" 16 "), Ok(16));
        assert_eq!(parse_threads("1"), Ok(1));
        assert!(parse_threads("0").is_err(), "zero threads is not a budget");
        assert!(parse_threads("-2").is_err());
        assert!(parse_threads("four").is_err());
        assert!(parse_threads("4.5").is_err());
        assert!(parse_threads("").is_err());
    }
}
