//! Fixed-size thread pool (tokio is unavailable offline; the serving layer
//! runs on blocking threads + channels, which at our request rates is
//! indistinguishable from an async runtime and much simpler to reason about).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A classic shared-queue thread pool with graceful shutdown on drop.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inf = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                inf.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Submit a job; never blocks.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until the queue drains. Test/benchmark helper.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped data-parallel helper for the compute kernels (`runtime::gemm`):
/// split `data` into `chunk_len`-sized mutable chunks and run `f(i, chunk)`
/// for each chunk concurrently, returning once all chunks finish. The
/// shared-queue [`ThreadPool`] requires `'static` jobs, so borrowed-data
/// compute uses this scoped sibling; both primitives live here so every
/// form of parallelism in the crate is in one place.
///
/// A single chunk (or empty input) runs inline on the caller's thread.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() || chunk_len == 0 {
        return;
    }
    if data.len() <= chunk_len {
        f(0, data);
        return;
    }
    thread::scope(|s| {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            let f = &f;
            s.spawn(move || f(i, chunk));
        }
    });
}

/// Run `f` over `items` with `n` threads, preserving order of results.
pub fn parallel_map<T, R, F>(n: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let items: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let results = Arc::new(Mutex::new(Vec::<(usize, R)>::new()));
    {
        let pool = ThreadPool::new(n.max(1));
        for (i, item) in items {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            pool.execute(move || {
                let r = f(item);
                results.lock().unwrap().push((i, r));
            });
        }
        pool.wait_idle();
    }
    let mut out = Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(4, (0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_chunks_mut_covers_all_chunks() {
        let mut data: Vec<u64> = (0..103).collect();
        parallel_chunks_mut(&mut data, 10, |i, chunk| {
            for x in chunk.iter_mut() {
                *x += (i as u64) * 1000;
            }
        });
        for (j, &x) in data.iter().enumerate() {
            assert_eq!(x, (j / 10) as u64 * 1000 + j as u64);
        }
        // single chunk runs inline
        let mut one = vec![1u64, 2, 3];
        parallel_chunks_mut(&mut one, 8, |i, chunk| {
            assert_eq!(i, 0);
            chunk[0] = 9;
        });
        assert_eq!(one, vec![9, 2, 3]);
    }

    #[test]
    fn in_flight_reaches_zero() {
        let pool = ThreadPool::new(2);
        for _ in 0..10 {
            pool.execute(|| {});
        }
        pool.wait_idle();
        assert_eq!(pool.in_flight(), 0);
    }
}
