//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Boolean flags must be declared up front (`KNOWN_FLAGS` or the `flags`
//! argument of [`Args::parse_with_flags`]) so `--fast out.fa` parses as a
//! flag plus a positional rather than `fast=out.fa`.
//! Typed getters parse on access and report readable errors.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue(String, String, &'static str),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(k) => write!(f, "option --{k} expects a value"),
            CliError::BadValue(k, v, ty) => {
                write!(f, "option --{k}: cannot parse '{v}' as {ty}")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Boolean flags recognized by the specmer CLI and benches.
pub const KNOWN_FLAGS: &[&str] = &[
    "fast", "full", "verbose", "quiet", "help", "force", "cpu-ref", "hlo-kmer",
    "no-kv-cache", "boundary", "fused",
];

impl Args {
    /// Parse an iterator of raw arguments (without the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, CliError> {
        Args::parse_with_flags(raw, KNOWN_FLAGS)
    }

    /// Parse with an explicit set of boolean flag names.
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(
        raw: I,
        bool_flags: &[&str],
    ) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "usize")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "u64")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "f64")),
        }
    }

    /// Comma-separated list of usizes, e.g. `--c 1,3,5`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError::BadValue(name.into(), v.into(), "usize list"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of f64.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError::BadValue(name.into(), v.into(), "f64 list"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse("generate --protein GFP --n 20 --fast out.fa");
        assert_eq!(a.positional, vec!["generate", "out.fa"]);
        assert_eq!(a.get("protein"), Some("GFP"));
        assert_eq!(a.usize_or("n", 1).unwrap(), 20);
        assert!(a.flag("fast"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--temp=0.7 --k=1,3,5");
        assert_eq!(a.f64_or("temp", 1.0).unwrap(), 0.7);
        assert_eq!(a.usize_list_or("k", &[]).unwrap(), vec![1, 3, 5]);
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.usize_or("gamma", 5).unwrap(), 5);
        assert_eq!(a.f64_or("p", 0.95).unwrap(), 0.95);
        assert!(!a.flag("full"));
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--verbose");
        assert!(a.flag("verbose"));
    }
}
