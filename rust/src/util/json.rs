//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic escapes (\u surrogate pairs
//! are handled). Used for manifest.json, families.json, server payloads and
//! results output. Numbers are kept as f64 — fine for every use here.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders --------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.pos += 1; // past first escape's last hex digit handled in hex4
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("lone surrogate"));
                                }
                                let lo = self.hex4()?;
                                let comb = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(comb).ok_or_else(|| self.err("bad surrogate"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                            continue; // hex4 already advanced past digits
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.b[self.pos..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    /// Parses the 4 hex digits after `\u` (self.pos is at 'u'), leaves pos
    /// after the last digit.
    fn hex4(&mut self) -> Result<u32, JsonError> {
        self.pos += 1; // past 'u'
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 3; // caller advances past the 4th via `self.pos += 1`
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\ny"],"c":{"d":-2.5}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().idx(2).unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2.5));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_nested_arrays() {
        let v = Json::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(v.idx(1).unwrap().idx(1).unwrap().idx(0).unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn scientific_numbers() {
        let v = Json::parse("[1e3, -2.5E-2, 0.0]").unwrap();
        assert_eq!(v.idx(0).unwrap().as_f64(), Some(1000.0));
        assert_eq!(v.idx(1).unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ∞"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn escape_control_chars() {
        let v = Json::Str("a\u{1}b".into());
        assert_eq!(v.to_string(), "\"a\\u0001b\"");
    }
}
