//! Infrastructure substrates built in-repo (the offline image has no
//! tokio/clap/serde/rand/criterion — see DESIGN.md §3).
//!
//! Unsafe code in this layer (the [`threadpool`] lifetime erasure and
//! `SendPtr`) follows the repo policy in docs/unsafe-policy.md, enforced by
//! `make lint-specmer`.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(1); // 0 quiet, 1 info, 2 debug

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

/// `info!`-style logging without a logger crate.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(1) {
            eprintln!("[specmer] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(2) {
            eprintln!("[specmer:debug] {}", format!($($arg)*));
        }
    };
}
