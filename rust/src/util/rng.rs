//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is not available offline, so we carry our own PCG64
//! (XSL-RR 128/64) plus SplitMix64 for seeding. Every stochastic component
//! in the system (sampling, workload generation, MSA simulation) threads an
//! explicit [`Pcg64`] so runs are bit-reproducible from a single seed.

/// SplitMix64 — used to expand a single `u64` seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG64 XSL-RR generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MUL: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed deterministically from a `u64`.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let i0 = splitmix64(&mut sm);
        let i1 = splitmix64(&mut sm);
        let mut rng = Pcg64 {
            state: ((s0 as u128) << 64) | s1 as u128,
            inc: (((i0 as u128) << 64) | i1 as u128) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Derive an independent stream (for per-worker / per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method without bias correction is fine for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Draw an index from an (unnormalized, non-negative) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from [0, n) (partial shuffle).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg64::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(9);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
