//! Analytic results from the paper: Eq. 1 wall-time speedup, Prop. 4.4
//! batch-and-select acceptance, and the Appendix-A bounds (Eq. 7–12).
//! The `bounds` experiment compares these curves against measured values.

/// Eq. 1: expected wall-time speedup of speculative decoding with draft
/// length γ, acceptance ratio α and cost coefficient c_e = M_p / M_q.
///
///   S(γ) = (1 - α^{γ+1}) / ((1 - α)(γ c_e + 1))
pub fn speedup_eq1(alpha: f64, gamma: usize, c_e: f64) -> f64 {
    if (1.0 - alpha).abs() < 1e-12 {
        // limit α -> 1: numerator -> γ+1
        return (gamma as f64 + 1.0) / (gamma as f64 * c_e + 1.0);
    }
    (1.0 - alpha.powi(gamma as i32 + 1)) / ((1.0 - alpha) * (gamma as f64 * c_e + 1.0))
}

/// Prop. 4.4: expected batch-and-select acceptance
///   E[A*] = 1 - (1-α)^m - ε
pub fn batch_acceptance(alpha: f64, m: usize, epsilon: f64) -> f64 {
    (1.0 - (1.0 - alpha).powi(m as i32) - epsilon).clamp(0.0, 1.0)
}

/// Invert Prop. 4.4: misranking loss ε from measured acceptances.
///   ε = 1 - (1-α)^m - E[A*]
pub fn epsilon_from_acceptance(alpha_vanilla: f64, m: usize, measured: f64) -> f64 {
    1.0 - (1.0 - alpha_vanilla).powi(m as i32) - measured
}

/// Definition A.1 / Eq. 8: batched cost coefficient c_e = ξ·M_p / M_q,
/// with ξ ∈ [1, c) the batch-generation overhead factor.
pub fn cost_coefficient(m_p: f64, m_q: f64, xi: f64) -> f64 {
    xi * m_p / m_q
}

/// Eq. 9 (Prop. A.2): expected batched wall-time speedup
///   S(γ) ≈ (1 - α^{γ+1}) / ((1-α)(c_e + 1))
///
/// NOTE: the c_e here absorbs the whole draft phase (ξ·γ drafting steps +
/// k-mer scoring) relative to one verify; see `c_draft`.
pub fn speedup_eq9(alpha: f64, gamma: usize, c_draft: f64) -> f64 {
    if (1.0 - alpha).abs() < 1e-12 {
        return (gamma as f64 + 1.0) / (c_draft + 1.0);
    }
    (1.0 - alpha.powi(gamma as i32 + 1)) / ((1.0 - alpha) * (c_draft + 1.0))
}

/// Eq. 12 (Cor. A.3): serial-drafting wall-time speedup — candidates drawn
/// one at a time instead of batched:
///   S(γ) ≈ (1 - α^{γ+1}) / ((1-α)((c/ξ)·c_e + 1))
pub fn speedup_eq12(alpha: f64, gamma: usize, c: usize, xi: f64, c_e: f64) -> f64 {
    let denom_cost = (c as f64 / xi) * c_e + 1.0;
    if (1.0 - alpha).abs() < 1e-12 {
        return (gamma as f64 + 1.0) / denom_cost;
    }
    (1.0 - alpha.powi(gamma as i32 + 1)) / ((1.0 - alpha) * denom_cost)
}

/// c_draft(γ) = (ξ·T_p(γ) + T_k) / T_q(γ) — the measured-time form used to
/// evaluate Eq. 9 from profiled per-phase timings.
pub fn c_draft(t_draft_batched: f64, t_kmer: f64, t_verify: f64) -> f64 {
    (t_draft_batched + t_kmer) / t_verify
}

/// Expected committed tokens per round: accepted prefix length + 1
/// (correction or bonus) for i.i.d. per-token acceptance α.
///   E[L'] = (1 - α^{γ+1}) / (1 - α)
pub fn expected_block_progress(alpha: f64, gamma: usize) -> f64 {
    if (1.0 - alpha).abs() < 1e-12 {
        return gamma as f64 + 1.0;
    }
    (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn eq1_known_values() {
        // α=0, draft never helps: S = 1/(γ c_e + 1) < 1
        assert!((speedup_eq1(0.0, 5, 0.2) - 0.5).abs() < 1e-12);
        // α=1 limit: S = (γ+1)/(γ c_e + 1)
        assert!((speedup_eq1(1.0, 5, 0.2) - 3.0).abs() < 1e-9);
        // paper-ish regime: α=0.9, γ=5, c_e=0.2 -> ≈ 2.34x
        let s = speedup_eq1(0.9, 5, 0.2);
        assert!(s > 2.0 && s < 2.5, "{s}");
    }

    #[test]
    fn eq1_monotone_in_alpha() {
        check("S(γ) increasing in α", 100, |g| {
            let a = g.f64_in(0.0..0.99);
            let b = g.f64_in(0.0..0.99);
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            let gamma = g.usize_in(1..16);
            let ce = g.f64_in(0.01..1.0);
            assert!(speedup_eq1(lo, gamma, ce) <= speedup_eq1(hi, gamma, ce) + 1e-12);
        });
    }

    #[test]
    fn prop44_acceptance_increases_with_m() {
        let a1 = batch_acceptance(0.8, 1, 0.0);
        let a3 = batch_acceptance(0.8, 3, 0.0);
        let a5 = batch_acceptance(0.8, 5, 0.0);
        assert!((a1 - 0.8).abs() < 1e-12);
        assert!(a3 > a1 && a5 > a3);
        assert!(a5 <= 1.0);
    }

    #[test]
    fn epsilon_inverts_prop44() {
        check("epsilon roundtrip", 100, |g| {
            let alpha = g.f64_in(0.1..0.95);
            let m = g.usize_in(1..9);
            let eps = g.f64_in(0.0..0.05);
            let measured = 1.0 - (1.0 - alpha).powi(m as i32) - eps;
            let back = epsilon_from_acceptance(alpha, m, measured);
            assert!((back - eps).abs() < 1e-9);
        });
    }

    #[test]
    fn eq9_vs_eq12_serial_is_slower() {
        // serial drafting of c candidates costs more than batched
        for &c in &[2usize, 3, 5] {
            let xi = 1.25;
            let ce = 0.2;
            let batched = speedup_eq9(0.85, 5, c_draft(xi * ce * 5.0, 0.0, 1.0));
            let serial = speedup_eq12(0.85, 5, c, xi, ce * 5.0);
            assert!(batched > serial, "c={c}: batched {batched} serial {serial}");
        }
    }

    #[test]
    fn block_progress_bounds() {
        check("1 <= E[L'] <= γ+1", 100, |g| {
            let alpha = g.f64_in(0.0..1.0);
            let gamma = g.usize_in(1..16);
            let e = expected_block_progress(alpha, gamma);
            assert!(e >= 1.0 - 1e-9 && e <= gamma as f64 + 1.0 + 1e-9, "{e}");
        });
    }

    #[test]
    fn speedup_exceeds_one_in_paper_regime() {
        // the paper's measured α≈0.85–0.94 with c_e≈0.4 (their S:M ratio
        // 74:31 tokens/s) and γ=5..15 must predict >1x
        for &alpha in &[0.85, 0.9, 0.94] {
            for &gamma in &[5usize, 10, 15] {
                assert!(speedup_eq1(alpha, gamma, 0.1) > 1.0);
            }
        }
    }
}
