//! Foldability proxy — the ESMFold-pLDDT stand-in (DESIGN.md §3).
//!
//! The paper uses mean per-residue pLDDT from ESMFold purely as a *ranking*
//! signal: sequences that look like stable family members score high,
//! degenerate or off-family sequences score low. We reproduce that ordering
//! pressure with three ingredients, each normalized to [0,1]:
//!
//!   1. family profile log-odds (positional match to the held-out MSA
//!      column profile — the dominant term, like ESMFold's implicit
//!      evolutionary prior);
//!   2. secondary-structure propensity smoothness: Chou–Fasman helix/sheet
//!      propensities averaged over a window; real folds have contiguous
//!      runs of structure-former residues;
//!   3. degeneracy penalties: single-residue repeats and low-complexity
//!      windows (the classic failure mode of AR protein LMs — paper §1).
//!
//! Calibration anchors: a wild-type scores ≈0.8, uniform-random sequences
//! ≈0.3–0.45 — matching the paper's Table 7 spread.

use crate::msa::Msa;
use crate::tokenizer::{AA_OFFSET, N_AA};

/// Chou–Fasman alpha-helix propensities (order = vocab.AA letters).
const HELIX: [f64; N_AA] = [
    1.42, 0.70, 1.01, 1.51, 1.13, 0.57, 1.00, 1.08, 1.16, 1.21, 1.45, 0.67,
    0.57, 1.11, 0.98, 0.77, 0.83, 1.06, 1.08, 0.69,
];
/// Chou–Fasman beta-sheet propensities.
const SHEET: [f64; N_AA] = [
    0.83, 1.19, 0.54, 0.37, 1.38, 0.75, 0.87, 1.60, 0.74, 1.30, 1.05, 0.89,
    0.55, 1.10, 0.93, 0.75, 1.19, 1.70, 1.37, 1.47,
];

/// Per-column profile with background-relative log-odds, prebuilt from the
/// family MSA (the expensive part; build once, reuse across sequences).
pub struct PlddtScorer {
    profile: Vec<[f64; N_AA]>,
    /// log-odds dynamic range used for normalization
    lo_scale: f64,
}

impl PlddtScorer {
    pub fn from_msa(msa: &Msa) -> PlddtScorer {
        PlddtScorer { profile: msa.column_profile(), lo_scale: 3.0 }
    }

    /// Mean "pLDDT" in [0,1] for a residue-token sequence (specials should
    /// be stripped by the caller; extra/missing length is tolerated —
    /// sequences are scored over the overlapping prefix, with a length-
    /// mismatch penalty, since truncated chains don't fold).
    pub fn score(&self, residues: &[u8]) -> f64 {
        if residues.is_empty() {
            return 0.0;
        }
        let n = residues.len();
        let w = self.per_residue(residues);
        let mean = w.iter().sum::<f64>() / w.len() as f64;
        // length mismatch penalty: fraction of the family length covered
        let cover = (n.min(self.profile.len()) as f64 / self.profile.len() as f64).min(1.0);
        (mean * (0.5 + 0.5 * cover)).clamp(0.0, 1.0)
    }

    /// Per-residue scores (the "per-position pLDDT" analogue).
    pub fn per_residue(&self, residues: &[u8]) -> Vec<f64> {
        let n = residues.len();
        let aa: Vec<Option<usize>> = residues
            .iter()
            .map(|&t| {
                let i = t.wrapping_sub(AA_OFFSET) as usize;
                if i < N_AA {
                    Some(i)
                } else {
                    None
                }
            })
            .collect();

        let bg = &crate::msa::simulate::BACKGROUND;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let Some(a) = aa[i] else {
                out.push(0.0);
                continue;
            };
            // 1. profile log-odds, squashed to [0,1]
            let prof = if i < self.profile.len() {
                let p = self.profile[i][a].max(1e-4);
                let lo = (p / bg[a]).ln();
                (0.5 + lo / (2.0 * self.lo_scale)).clamp(0.0, 1.0)
            } else {
                0.3 // residues beyond the family length are suspicious
            };
            // 2. structure propensity over a +/-3 window: max of mean helix
            //    and mean sheet propensity, mapped so 1.0 propensity -> 0.5
            let lo_w = i.saturating_sub(3);
            let hi_w = (i + 4).min(n);
            let (mut h, mut s, mut cnt) = (0.0, 0.0, 0.0);
            for j in lo_w..hi_w {
                if let Some(b) = aa[j] {
                    h += HELIX[b];
                    s += SHEET[b];
                    cnt += 1.0;
                }
            }
            let prop = if cnt > 0.0 {
                ((h / cnt).max(s / cnt) - 0.5).clamp(0.0, 1.0)
            } else {
                0.0
            };
            // 3. degeneracy: repeats and low window complexity
            let mut penalty: f64 = 0.0;
            if i >= 2 && aa[i] == aa[i - 1] && aa[i - 1] == aa[i - 2] {
                penalty += 0.35;
            }
            let distinct = {
                let mut seen = [false; N_AA];
                let mut c = 0;
                for j in lo_w..hi_w {
                    if let Some(b) = aa[j] {
                        if !seen[b] {
                            seen[b] = true;
                            c += 1;
                        }
                    }
                }
                c as f64 / (hi_w - lo_w) as f64
            };
            if distinct < 0.5 {
                penalty += 0.3 * (0.5 - distinct) * 2.0;
            }
            out.push((0.65 * prof + 0.35 * prop - penalty).clamp(0.0, 1.0));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msa::simulate::generate_family;
    use crate::tokenizer::{encode, AA_OFFSET};
    use crate::util::rng::Pcg64;

    fn setup() -> (PlddtScorer, Vec<u8>, usize) {
        let (_prof, msa) = generate_family("T", 80, 60, 9);
        let wt = encode(&msa.wild_type);
        let n = wt.len();
        (PlddtScorer::from_msa(&msa), wt, n)
    }

    #[test]
    fn wild_type_scores_high() {
        let (sc, wt, _) = setup();
        let s = sc.score(&wt);
        assert!(s > 0.6, "wild-type proxy pLDDT {s}");
    }

    #[test]
    fn random_scores_lower_than_wt() {
        let (sc, wt, n) = setup();
        let mut rng = Pcg64::new(4);
        let mut rand_scores = Vec::new();
        for _ in 0..10 {
            let r: Vec<u8> = (0..n).map(|_| AA_OFFSET + rng.below(20) as u8).collect();
            rand_scores.push(sc.score(&r));
        }
        let rand_mean = rand_scores.iter().sum::<f64>() / 10.0;
        assert!(sc.score(&wt) > rand_mean + 0.1, "wt {} rand {rand_mean}", sc.score(&wt));
    }

    #[test]
    fn homopolymer_penalized() {
        let (sc, _wt, n) = setup();
        let poly: Vec<u8> = vec![AA_OFFSET; n]; // poly-alanine
        let mut rng = Pcg64::new(5);
        let rand: Vec<u8> = (0..n).map(|_| AA_OFFSET + rng.below(20) as u8).collect();
        assert!(sc.score(&poly) < sc.score(&rand), "repeats must rank below diverse junk");
    }

    #[test]
    fn truncation_penalized() {
        let (sc, wt, n) = setup();
        let half = sc.score(&wt[..n / 2]);
        let full = sc.score(&wt);
        assert!(half < full, "half {half} full {full}");
    }

    #[test]
    fn scores_bounded() {
        let (sc, wt, _) = setup();
        for len in [1usize, 5, 40, 80] {
            let s = sc.score(&wt[..len.min(wt.len())]);
            assert!((0.0..=1.0).contains(&s));
        }
        assert_eq!(sc.score(&[]), 0.0);
    }

    #[test]
    fn homolog_beats_shuffled_homolog() {
        let (_p, msa) = generate_family("T", 80, 60, 19);
        let sc = PlddtScorer::from_msa(&msa);
        let mut rng = Pcg64::new(77);
        let mut wins = 0;
        let rows: Vec<_> = msa.tokenized_rows().into_iter().filter(|r| r.len() == 80).take(10).collect();
        for row in &rows {
            let mut shuf = row.clone();
            rng.shuffle(&mut shuf);
            if sc.score(row) > sc.score(&shuf) {
                wins += 1;
            }
        }
        assert!(wins * 10 >= rows.len() * 8, "homolog should usually beat its shuffle");
    }
}
