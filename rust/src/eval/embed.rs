//! Sequence embeddings + PCA (the ESM2-embedding / Fig. 2a stand-in).
//!
//! Embeddings come from the target model's mean-pooled final hidden state
//! (`target_embed.hlo.txt` or the cpu_ref backend); this module owns the
//! PCA used to project MSA and generated-sequence embeddings to 2D. The
//! eigensolver is a cyclic Jacobi on the covariance matrix — dimensions
//! here are <= 128, where Jacobi is simple and robust.

/// PCA model: mean vector + top-k principal axes (rows).
pub struct Pca {
    pub mean: Vec<f64>,
    pub components: Vec<Vec<f64>>,
    pub explained: Vec<f64>,
}

/// Symmetric-matrix eigendecomposition by cyclic Jacobi rotations.
/// Returns (eigenvalues, eigenvectors as rows), sorted descending.
pub fn jacobi_eigh(mut a: Vec<Vec<f64>>, iters: usize) -> (Vec<f64>, Vec<Vec<f64>>) {
    let n = a.len();
    let mut v = vec![vec![0.0; n]; n];
    for (i, row) in v.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    for _sweep in 0..iters {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += a[p][q] * a[p][q];
            }
        }
        if off < 1e-18 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                if a[p][q].abs() < 1e-18 {
                    continue;
                }
                let theta = (a[q][q] - a[p][p]) / (2.0 * a[p][q]);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..n {
                    let akp = a[k][p];
                    let akq = a[k][q];
                    a[k][p] = c * akp - s * akq;
                    a[k][q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p][k];
                    let aqk = a[q][k];
                    a[p][k] = c * apk - s * aqk;
                    a[q][k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k][p];
                    let vkq = v[k][q];
                    v[k][p] = c * vkp - s * vkq;
                    v[k][q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| a[j][j].partial_cmp(&a[i][i]).unwrap());
    let vals: Vec<f64> = idx.iter().map(|&i| a[i][i]).collect();
    let vecs: Vec<Vec<f64>> = idx.iter().map(|&i| (0..n).map(|k| v[k][i]).collect()).collect();
    (vals, vecs)
}

impl Pca {
    /// Fit a k-component PCA on row vectors `data`.
    pub fn fit(data: &[Vec<f32>], k: usize) -> Pca {
        assert!(!data.is_empty());
        let d = data[0].len();
        let n = data.len() as f64;
        let mut mean = vec![0.0f64; d];
        for row in data {
            for (m, &x) in mean.iter_mut().zip(row) {
                *m += x as f64;
            }
        }
        mean.iter_mut().for_each(|m| *m /= n);
        // covariance (upper triangle mirrored)
        let mut cov = vec![vec![0.0f64; d]; d];
        for row in data {
            let c: Vec<f64> = row.iter().zip(&mean).map(|(&x, m)| x as f64 - m).collect();
            for i in 0..d {
                for j in i..d {
                    cov[i][j] += c[i] * c[j];
                }
            }
        }
        let denom = (data.len().max(2) - 1) as f64;
        for i in 0..d {
            for j in i..d {
                cov[i][j] /= denom;
                cov[j][i] = cov[i][j];
            }
        }
        let total: f64 = (0..d).map(|i| cov[i][i]).sum();
        let (vals, vecs) = jacobi_eigh(cov, 30);
        Pca {
            mean,
            components: vecs.into_iter().take(k).collect(),
            explained: vals.iter().take(k).map(|&l| l / total.max(1e-12)).collect(),
        }
    }

    /// Project one vector onto the principal axes.
    pub fn transform(&self, x: &[f32]) -> Vec<f64> {
        self.components
            .iter()
            .map(|axis| {
                x.iter()
                    .zip(&self.mean)
                    .zip(axis)
                    .map(|((&xi, m), a)| (xi as f64 - m) * a)
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn jacobi_diagonalizes_known_matrix() {
        // [[2,1],[1,2]] -> eigenvalues 3, 1
        let (vals, vecs) = jacobi_eigh(vec![vec![2.0, 1.0], vec![1.0, 2.0]], 20);
        assert!((vals[0] - 3.0).abs() < 1e-9);
        assert!((vals[1] - 1.0).abs() < 1e-9);
        // eigenvector for 3 is (1,1)/sqrt2 up to sign
        let v = &vecs[0];
        assert!((v[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-6);
        assert!((v[0] - v[1]).abs() < 1e-6 || (v[0] + v[1]).abs() < 1e-6);
    }

    #[test]
    fn pca_finds_dominant_direction() {
        // points along (1, 2, 0) + small noise
        let mut rng = Pcg64::new(3);
        let data: Vec<Vec<f32>> = (0..200)
            .map(|_| {
                let t = rng.gaussian() as f32 * 5.0;
                vec![
                    t + rng.gaussian() as f32 * 0.05,
                    2.0 * t + rng.gaussian() as f32 * 0.05,
                    rng.gaussian() as f32 * 0.05,
                ]
            })
            .collect();
        let pca = Pca::fit(&data, 2);
        let c = &pca.components[0];
        let norm = (c[0] * c[0] + c[1] * c[1] + c[2] * c[2]).sqrt();
        let dir: Vec<f64> = c.iter().map(|x| x / norm).collect();
        let expect = [1.0 / 5.0f64.sqrt(), 2.0 / 5.0f64.sqrt(), 0.0];
        let dot: f64 = dir.iter().zip(&expect).map(|(a, b)| a * b).sum();
        assert!(dot.abs() > 0.999, "dot {dot}");
        assert!(pca.explained[0] > 0.99);
    }

    #[test]
    fn transform_centers_data() {
        let data = vec![vec![1.0f32, 0.0], vec![3.0, 0.0]];
        let pca = Pca::fit(&data, 1);
        let p1 = pca.transform(&[1.0, 0.0])[0];
        let p2 = pca.transform(&[3.0, 0.0])[0];
        assert!((p1 + p2).abs() < 1e-9, "projections symmetric around mean");
        assert!((p1 - p2).abs() > 1.0);
    }

    #[test]
    fn clustered_families_separate_in_pca() {
        let mut rng = Pcg64::new(8);
        let mut data = Vec::new();
        for fam in 0..2 {
            let center: Vec<f64> = (0..8).map(|i| if i == fam { 10.0 } else { 0.0 }).collect();
            for _ in 0..50 {
                data.push(
                    center
                        .iter()
                        .map(|&c| (c + rng.gaussian() * 0.3) as f32)
                        .collect::<Vec<f32>>(),
                );
            }
        }
        let pca = Pca::fit(&data, 2);
        let a = pca.transform(&data[10]);
        let b = pca.transform(&data[60]);
        let dist = ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)).sqrt();
        assert!(dist > 5.0, "families must separate: {dist}");
    }
}
