//! Sequence-diversity metrics (paper Appendix D.1, Table 9):
//! wild-type Hamming distance and inter-sequence Hamming distance.

/// Hamming distance with length-difference counted as mismatches (the
//  natural extension for unaligned generated sequences).
pub fn hamming(a: &[u8], b: &[u8]) -> usize {
    let n = a.len().min(b.len());
    let mut d = a.len().max(b.len()) - n;
    for i in 0..n {
        if a[i] != b[i] {
            d += 1;
        }
    }
    d
}

/// Mean Hamming distance of each sequence to the wild type.
pub fn wt_distances(wt: &[u8], seqs: &[Vec<u8>]) -> Vec<f64> {
    seqs.iter().map(|s| hamming(wt, s) as f64).collect()
}

/// All-pairs inter-sequence distances (upper triangle), subsampled to at
/// most `max_pairs` for large sets.
pub fn inter_seq_distances(seqs: &[Vec<u8>], max_pairs: usize, seed: u64) -> Vec<f64> {
    let n = seqs.len();
    if n < 2 {
        return vec![];
    }
    let total = n * (n - 1) / 2;
    let mut out = Vec::new();
    if total <= max_pairs {
        for i in 0..n {
            for j in (i + 1)..n {
                out.push(hamming(&seqs[i], &seqs[j]) as f64);
            }
        }
    } else {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        for _ in 0..max_pairs {
            let i = rng.below(n);
            let mut j = rng.below(n);
            while j == i {
                j = rng.below(n);
            }
            out.push(hamming(&seqs[i], &seqs[j]) as f64);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hamming_basics() {
        assert_eq!(hamming(b"ACDE", b"ACDE"), 0);
        assert_eq!(hamming(b"ACDE", b"ACDF"), 1);
        assert_eq!(hamming(b"ACDE", b"AC"), 2); // length diff
        assert_eq!(hamming(b"", b"ACD"), 3);
    }

    #[test]
    fn wt_distance_vector() {
        let d = wt_distances(b"AAAA", &[b"AAAA".to_vec(), b"AAAB".to_vec()]);
        assert_eq!(d, vec![0.0, 1.0]);
    }

    #[test]
    fn inter_seq_full_enumeration() {
        let seqs = vec![b"AA".to_vec(), b"AB".to_vec(), b"BB".to_vec()];
        let mut d = inter_seq_distances(&seqs, 100, 0);
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(d, vec![1.0, 1.0, 2.0]);
    }

    #[test]
    fn inter_seq_subsamples() {
        let seqs: Vec<Vec<u8>> = (0..100).map(|i| vec![i as u8, (i * 7) as u8]).collect();
        let d = inter_seq_distances(&seqs, 50, 1);
        assert_eq!(d.len(), 50);
        let d2 = inter_seq_distances(&seqs, 50, 1);
        assert_eq!(d, d2, "deterministic");
    }

    #[test]
    fn singleton_has_no_pairs() {
        assert!(inter_seq_distances(&[b"AA".to_vec()], 10, 0).is_empty());
    }
}
