//! Evaluation metrics: NLL under the target model, the pLDDT foldability
//! proxy, embeddings + PCA, and sequence-diversity measures.

pub mod diversity;
pub mod embed;
pub mod plddt;

pub use embed::Pca;
pub use plddt::PlddtScorer;

use crate::runtime::ModelBackend;
use anyhow::Result;

/// Length-normalized NLL of a full token sequence under `model` (the
/// paper's post-hoc "NLL" metric: total NLL of tokens[1..] divided by the
/// number of predicted tokens).
pub fn sequence_nll<B: ModelBackend>(model: &B, tokens: &[u8]) -> Result<f64> {
    if tokens.len() < 2 {
        return Ok(0.0);
    }
    let per_pos = model.score(tokens)?;
    let n = (tokens.len() - 1) as f64;
    Ok(per_pos.iter().map(|&x| x as f64).sum::<f64>() / n)
}

/// NLL for many sequences.
pub fn batch_nll<B: ModelBackend>(model: &B, seqs: &[Vec<u8>]) -> Result<Vec<f64>> {
    seqs.iter().map(|s| sequence_nll(model, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::cpu_ref::CpuModel;

    #[test]
    fn nll_positive_and_length_normalized() {
        let m = CpuModel::synthetic(1, 16, 2, 32, 2);
        let short = sequence_nll(&m, &[1, 5, 9]).unwrap();
        let long = sequence_nll(&m, &[1, 5, 9, 5, 9, 5, 9]).unwrap();
        assert!(short > 0.0 && long > 0.0);
        // normalization keeps them on the same scale
        assert!((short - long).abs() < short.max(long));
    }

    #[test]
    fn nll_trivial_sequences() {
        let m = CpuModel::synthetic(1, 16, 2, 32, 2);
        assert_eq!(sequence_nll(&m, &[1]).unwrap(), 0.0);
        assert_eq!(sequence_nll(&m, &[]).unwrap(), 0.0);
    }
}
