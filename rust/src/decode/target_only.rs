//! Target-only autoregressive baseline (the paper's "Target" rows).
//!
//! Implemented with the same `generate` program as drafting, but on the
//! target model with c = 1 and chunked blocks — every sampled token is a
//! committed token, so this is exact nucleus sampling from the target.

use anyhow::Result;

use super::{GenConfig, GenOutput};
use crate::runtime::ModelBackend;
use crate::sampling;
use crate::tokenizer::EOS;
use crate::util::rng::Pcg64;

/// Generate one sequence by plain nucleus sampling from `target`.
pub fn target_only_generate<T: ModelBackend>(
    target: &T,
    context: &[u8],
    cfg: &GenConfig,
) -> Result<GenOutput> {
    let max_len = cfg.max_len.min(target.maxlen());
    if context.is_empty() || context.len() >= max_len {
        anyhow::bail!(
            "target-only: context length {} must be in 1..effective max_len {max_len}",
            context.len()
        );
    }
    let supported = target.supported_gamma();
    // ar_chunk = 1 is the paper-faithful stepwise baseline (one dispatch
    // per token); 0 picks the largest exported scan-fused chunk.
    let chunk = if cfg.ar_chunk > 0 {
        *supported
            .iter()
            .filter(|&&g| g <= cfg.ar_chunk)
            .max()
            .or_else(|| supported.iter().min())
            .expect("backend supports some gamma")
    } else {
        *supported.iter().max().expect("backend supports some gamma")
    };

    let mut rng = Pcg64::new(cfg.seed);
    let mut out = GenOutput {
        tokens: context.to_vec(),
        context_len: context.len(),
        ..Default::default()
    };

    let mut cache = target.prefill(context)?;
    let mut fed = context.len() - 1; // tokens fed so far (prefill feeds n-1)

    // the generate program always samples a full chunk, writing KV through
    // fed + feed + chunk; stop while that still fits in the cache.
    'outer: while out.tokens.len() < max_len && out.tokens.len() + chunk <= target.maxlen() {
        let feed = out.tokens[fed..].to_vec();
        let gamma = chunk.min(max_len - out.tokens.len());
        // the backend's program has fixed gamma; generate a full block and
        // use only what fits.
        let u: Vec<f32> = (0..chunk).map(|_| rng.next_f32()).collect();
        let block = target.generate(&mut cache, &feed, fed, 1, chunk, &u, cfg.temp, cfg.top_p)?;
        out.draft_calls += 1; // cost accounting: one target-model dispatch
        out.target_calls += 1;
        fed += feed.len();
        for g in 0..gamma {
            let tok = block.tokens[0][g];
            out.online_nll_sum += sampling::nll_of(&block.dists[0][g], tok as usize);
            out.tokens.push(tok);
            out.accepted += 1; // every sampled token is committed
            if tok == EOS || out.tokens.len() >= max_len {
                // tokens beyond g were speculatively computed by the block
                // but are simply dropped; the cache frontier convention
                // makes their KV slots unobservable.
                break 'outer;
            }
        }
        // The sampled tokens' KV lives only inside the program's candidate
        // scan — the committed cache holds KV through the *feed* phase
        // only. `fed` therefore advances by feed.len() (done above), and
        // the whole previous chunk is re-fed teacher-forced on the next
        // call (it fits: chunk <= gamma+1 feed slots). Advancing `fed`
        // past unfed tokens would leave silent KV holes.
        out.rounds += 1;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::cpu_ref::CpuModel;
    use crate::tokenizer::BOS;

    fn cfg(max_len: usize, seed: u64) -> GenConfig {
        GenConfig { max_len, seed, ..Default::default() }
    }

    #[test]
    fn generates_up_to_max_len() {
        let m = CpuModel::synthetic(2, 16, 2, 48, 3);
        let ctx = vec![BOS, 5, 9, 13];
        let out = target_only_generate(&m, &ctx, &cfg(24, 1)).unwrap();
        assert!(out.tokens.len() <= 24);
        assert!(out.tokens.len() > 4);
        assert_eq!(&out.tokens[..4], &ctx[..]);
        assert_eq!(out.acceptance_ratio(), 1.0);
    }

    #[test]
    fn deterministic_in_seed() {
        let m = CpuModel::synthetic(2, 16, 2, 48, 3);
        let ctx = vec![BOS, 5, 9];
        let a = target_only_generate(&m, &ctx, &cfg(30, 7)).unwrap();
        let b = target_only_generate(&m, &ctx, &cfg(30, 7)).unwrap();
        let c = target_only_generate(&m, &ctx, &cfg(30, 8)).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert!(a.tokens != c.tokens || a.online_nll_sum == c.online_nll_sum);
    }

    #[test]
    fn stops_at_eos() {
        let m = CpuModel::synthetic(2, 16, 2, 64, 5);
        for seed in 0..8 {
            let out = target_only_generate(&m, &[BOS, 5], &cfg(64, seed)).unwrap();
            if let Some(pos) = out.tokens.iter().position(|&t| t == EOS) {
                assert_eq!(pos, out.tokens.len() - 1, "EOS must terminate");
            }
        }
    }

    /// Regression (missing-KV bug): the full token stream must be exactly
    /// what step-by-step nucleus sampling with fresh full forwards and the
    /// same uniform stream produces. Catches any committed-cache KV hole.
    #[test]
    fn matches_stepwise_manual_sampling_exactly() {
        let m = CpuModel::synthetic(2, 16, 2, 96, 21);
        let ctx = vec![BOS, 5, 9];
        let chunk = 16; // CpuModel supports gamma 1..=16 -> chunk = 16
        for seed in 0..3u64 {
            let cfg = cfg(60, seed);
            let out = target_only_generate(&m, &ctx, &cfg).unwrap();
            // replay: same RNG stream, chunk uniforms drawn per round
            let mut rng = crate::util::rng::Pcg64::new(seed);
            let mut toks = ctx.clone();
            'outer: while toks.len() < 60 && toks.len() + chunk <= 96 {
                let u: Vec<f32> = (0..chunk).map(|_| rng.next_f32()).collect();
                for &ug in u.iter() {
                    let logits = m.forward_logits(&toks);
                    let dist =
                        crate::sampling::adjust_dist(logits.last().unwrap(), cfg.temp, cfg.top_p);
                    let tok = crate::sampling::sample(&dist, ug) as u8;
                    toks.push(tok);
                    if tok == EOS || toks.len() >= 60 {
                        break 'outer;
                    }
                }
            }
            assert_eq!(out.tokens, toks, "seed {seed}: cached path diverged from manual");
        }
    }

    /// Sampled continuation matches a hand-rolled nucleus sampler driven by
    /// the same model — the "is this really sampling from the target" check.
    #[test]
    fn matches_manual_sampling_distributionally() {
        let m = CpuModel::synthetic(1, 16, 2, 32, 11);
        let ctx = vec![BOS, 5, 9];
        let n = 60;
        let mut firsts = std::collections::HashMap::new();
        for seed in 0..n {
            let out = target_only_generate(&m, &ctx, &cfg(5, seed)).unwrap();
            *firsts.entry(out.tokens[3]).or_insert(0usize) += 1;
        }
        // manual distribution of the first generated token
        let logits = m.forward_logits(&ctx);
        let dist = crate::sampling::adjust_dist(logits.last().unwrap(), 1.0, 0.95);
        // every observed token must be inside the nucleus
        for (&tok, _) in firsts.iter() {
            assert!(dist[tok as usize] > 0.0, "token {tok} outside nucleus");
        }
        // and the argmax token should be observed
        let argmax = dist
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u8;
        assert!(firsts.contains_key(&argmax));
    }
}
