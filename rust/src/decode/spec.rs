//! Speculative decoding (Algorithm 1) and SpecMER batch-and-select.
//!
//! One engine implements both: with `c == 1` (or no k-mer table) the
//! candidate-selection step degenerates and this is exactly vanilla
//! speculative decoding; with `c > 1` and a table it is SpecMER (paper
//! §3.1): draft `c` candidate blocks in one batched call, pick the block
//! with the highest Eq.-2 k-mer score, verify only that block with the
//! target, and accept/correct tokens by token-level maximal coupling.
//!
//! A round may draft flat chains or — with a [`TreePolicy`] — a
//! shared-prefix candidate *tree*: `c` roots branched top-k at the policy's
//! split depths, drafted via [`ModelBackend::draft_tree`] (each shared
//! prefix computed once), ranked by k-mer score over *root-to-leaf paths*,
//! and verified in one tree-masked [`ModelBackend::verify_tree`] pass;
//! maximal coupling then walks the selected path. With branching disabled
//! the flat code path runs verbatim (the oracle); a chain-shaped tree
//! (`branch == 1`, mask set) drives the tree path and is pinned bitwise
//! against it. Tree mode re-feeds committed tokens through the next
//! round's trunk (`target_fed`) because node KV is round-scratch.
//!
//! Cross-request serving is built on an explicit [`LockstepGroup`] state
//! machine: B same-shape requests share each round's draft/verify
//! dispatches, finished sequences retire at round boundaries, and — for
//! continuous batching ([`speculative_generate_continuous`]) — an
//! [`AdmissionHook`] may splice newly-arrived compatible requests into the
//! in-flight group at any boundary without perturbing resident sequences'
//! RNG streams. Every per-sequence knob — context, seed, sampling params,
//! and since the SeqSpec redesign the k-mer table itself — rides on the
//! item ([`SpecBatchItem`]/[`AdmitItem`]), so a group may mix protein
//! families and SpecMER/vanilla-speculative methods freely; only the
//! dispatch shape `(c, gamma, tree)` is shared. Tree rounds run their
//! per-sequence draft/verify calls serially inside the round (cross-
//! sequence tree batching is an open ROADMAP item), so a failing call
//! retires only its own sequence instead of poisoning the group.
//!
//! # Admission lifecycle: cached → CoW-attached → chunk-prefilling → active
//!
//! Admission cost is governed by [`PrefixParams`]
//! ([`speculative_generate_continuous_with`]): each model side first
//! consults its worker-resident `runtime::prefix_store` — a **hit**
//! attaches the cached context KV copy-on-write (`prefill_into`, no
//! forward at all); a **miss** with `prefill_chunk > 0` and a long context
//! enters a *prefilling* phase (`PrefillState` in the group's `pending`
//! list) that advances at most `prefill_chunk` context tokens per model
//! per lockstep round boundary — resident sequences never wait on a cold
//! arrival — and publishes the finished snapshot back to the store; short
//! contexts (or backends without `prefill_begin`) prefill one-shot at
//! admission exactly as before. Determinism contract: a sequence admitted
//! through *any* of these paths produces output **bit-identical** to its
//! cold, solo, one-shot-prefill run — attach shares the exact bits a cold
//! prefill would compute, chunked feeding is bitwise equal to one-shot on
//! row-independent kernels, and the per-sequence RNG stream starts only at
//! activation (`tests/batch_decode_equivalence.rs` pins all three).

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;

use super::{GenConfig, GenOutput, TreePolicy};
use crate::kmer::{score, KmerTable};
use crate::runtime::prefix_store::PrefixStore;
use crate::runtime::{DraftSeq, ModelBackend, TokenTree, VerifySeq};
use crate::sampling;
use crate::tokenizer::EOS;
use crate::util::rng::Pcg64;

/// Extra knobs for speculative generation.
#[derive(Clone, Default)]
pub struct SpecOptions {
    /// Use the exported Pallas k-mer kernel instead of the Rust scorer
    /// (requires HLO runtime; for TPU-deployment parity runs). `Arc` (the
    /// runtime is `Mutex`-guarded internally) so `SpecOptions` is `Send`
    /// and may ride into lockstep worker threads.
    pub hlo_kmer: Option<Arc<crate::runtime::Runtime>>,
}

/// Generate one sequence with speculative decoding / SpecMER.
///
/// `table` enables k-mer guidance; pass `None` for pure Algorithm 1.
pub fn speculative_generate<D: ModelBackend, T: ModelBackend>(
    draft: &D,
    target: &T,
    table: Option<&KmerTable>,
    context: &[u8],
    cfg: &GenConfig,
) -> Result<GenOutput> {
    let model_cap = target.maxlen().min(draft.maxlen());
    cfg.validate(context.len(), model_cap)?;
    // tree drafting shares the lockstep driver (a solo run is a group of
    // one); the flat loop below stays the verbatim oracle path
    if cfg.tree.enabled() {
        let mut group = LockstepGroup::new(draft, target, LockstepShape::of(cfg));
        group.admit(AdmitItem {
            ticket: 0,
            context: context.to_vec(),
            cfg: cfg.clone(),
            table: table.map(|t| Arc::new(t.clone())),
        });
        loop {
            if let Some((_, r)) = group.drain_completed().pop() {
                return r;
            }
            group.step_round();
        }
    }
    let max_len = cfg.max_len.min(model_cap);
    let gamma = cfg.gamma;

    let mut rng = Pcg64::new(cfg.seed);
    let mut out = GenOutput {
        tokens: context.to_vec(),
        context_len: context.len(),
        ..Default::default()
    };

    let mut dcache = draft.prefill(context)?;
    let mut tcache = target.prefill(context)?;
    // cold solo run: both models prefill the first n-1 context tokens
    out.prefill_tokens = 2 * (context.len() as u64 - 1);
    let mut draft_fed = context.len() - 1; // draft convention: all committed-but-unfed
    // target convention: exactly one unfed committed token before verify

    // KV slots are written through committed+gamma each round (draft feed +
    // block, verify block); stop while a full block still fits. Cannot
    // underflow: validate() guarantees gamma < model_cap.
    let hard_cap = model_cap - gamma;
    while out.tokens.len() < max_len.min(hard_cap) && *out.tokens.last().unwrap() != EOS {
        out.rounds += 1;
        let committed = out.tokens.len();

        // ---- 1. candidate construction (one batched draft dispatch) -----
        let feed = out.tokens[draft_fed..].to_vec();
        let u: Vec<f32> = (0..cfg.c * gamma).map(|_| rng.next_f32()).collect();
        let block = draft.generate(
            &mut dcache,
            &feed,
            draft_fed,
            cfg.c,
            gamma,
            &u,
            cfg.temp,
            cfg.top_p,
        )?;
        out.draft_calls += 1;
        out.tree_nodes += (cfg.c * gamma) as u64;
        draft_fed = committed;

        // ---- 2. k-mer scoring & selection ------------------------------
        let sel = match (table, cfg.c) {
            (Some(t), c) if c > 1 => {
                if cfg.kmer_boundary {
                    // context tail sized by the largest active k, not a
                    // hardcoded constant
                    let tail_len = cfg.kset.kmax() - 1;
                    let tail = &out.tokens[committed.saturating_sub(tail_len)..];
                    score::select_best_with_context(t, tail, &block.tokens, cfg.kset)
                } else {
                    score::select_best(t, &block.tokens, cfg.kset)
                }
            }
            _ => 0,
        };
        let cand = &block.tokens[sel];
        let p_dists = &block.dists[sel];

        // ---- 3. conditional probability computation (target verify) ----
        let mut vtoks = Vec::with_capacity(gamma + 1);
        vtoks.push(out.tokens[committed - 1]);
        vtoks.extend_from_slice(cand);
        let verify = target.verify(&mut tcache, &vtoks, committed - 1, cfg.temp, cfg.top_p)?;
        out.target_calls += 1;

        // ---- optional misranking probe (Fig. 3's ε) ---------------------
        if cfg.probe_rate > 0.0 && rng.next_f64() < cfg.probe_rate && cfg.c > 1 {
            let probe = probe_misranking(
                target, &mut tcache, &mut out.target_calls, &out.tokens, &block.tokens,
                &block.dists, sel, &verify.dists, cfg, &mut rng,
            )?;
            out.probes.push(probe);
        }

        // ---- 4. draft selection: token-level maximal coupling -----------
        let mut all_accepted = true;
        for i in 0..gamma {
            let x = cand[i] as usize;
            let (acc, tok) = sampling::couple(&p_dists[i], &verify.dists[i], x, &mut rng);
            out.online_nll_sum += sampling::nll_of(&verify.dists[i], tok);
            out.tokens.push(tok as u8);
            if acc {
                out.accepted += 1;
            } else {
                out.rejected += 1;
                all_accepted = false;
            }
            if !acc || tok as u8 == EOS || out.tokens.len() >= max_len {
                // stopping for any reason means no bonus token this round
                all_accepted = false;
                break;
            }
        }

        // ---- bonus token when the whole block was accepted ---------------
        if all_accepted && out.tokens.len() < max_len {
            let bonus_dist = &verify.dists[gamma];
            let tok = sampling::sample(bonus_dist, rng.next_f32());
            out.online_nll_sum += sampling::nll_of(bonus_dist, tok);
            out.tokens.push(tok as u8);
            out.bonus += 1;
        }
    }
    Ok(out)
}

/// One request of a lockstep batch: its context, decoding config, and its
/// *own* k-mer table handle (None for vanilla speculative decoding).
///
/// Within one `speculative_generate_batch` call, `c` and `gamma` must match
/// across items (they fix the dispatch shapes); seed, max_len, context,
/// the k-mer table, the selection knobs, and the sampling params
/// (`temp`/`top_p` only gate each sequence's own `adjust_dist` rows) may
/// differ freely — requests for *different protein families* (and mixed
/// SpecMER / vanilla-speculative methods) share one lockstep group. The
/// coordinator groups requests so the shape constraint always holds.
pub struct SpecBatchItem<'a> {
    pub context: &'a [u8],
    pub cfg: &'a GenConfig,
    /// K-mer guidance table for *this* sequence's family; selection always
    /// scores a candidate block against its own family's statistics.
    pub table: Option<Arc<KmerTable>>,
}

/// Generate B sequences with speculative decoding / SpecMER in lockstep:
/// per round, one batched draft dispatch over all active sequences'
/// candidate rows and one batched verify over their selected blocks.
///
/// Per-sequence RNG and acceptance state make every sequence's token
/// stream identical to a solo [`speculative_generate`] call with the same
/// seed (bitwise, on backends whose batched dispatches are row-independent
/// — `tests/batch_decode_equivalence.rs` pins this for the CPU runtime).
/// Sequences that finish early (EOS / max_len) drop out of the batch while
/// the rest continue. Items with `probe_rate > 0` interleave extra probe
/// dispatches into a round and are routed through the sequential engine;
/// their results are spliced back in order.
///
/// Results are per-item, preserving the serial worker loop's failure
/// isolation: a bad config, a failed prefill or a probe item's error fails
/// only that request. Only a *shared* dispatch error (the batched
/// draft/verify call itself) poisons the whole lockstep group.
pub fn speculative_generate_batch<D: ModelBackend, T: ModelBackend>(
    draft: &D,
    target: &T,
    items: &[SpecBatchItem<'_>],
) -> Vec<Result<GenOutput>> {
    let mut results: Vec<Option<Result<GenOutput>>> = (0..items.len()).map(|_| None).collect();
    let mut lock = Vec::new();
    for (i, it) in items.iter().enumerate() {
        if it.cfg.probe_rate > 0.0 {
            results[i] =
                Some(speculative_generate(draft, target, it.table.as_deref(), it.context, it.cfg));
        } else {
            lock.push(i);
        }
    }
    if !lock.is_empty() {
        for (i, out) in lock.iter().zip(lockstep_generate(draft, target, items, &lock)) {
            results[*i] = Some(out);
        }
    }
    results.into_iter().map(|o| o.expect("every item decoded")).collect()
}

/// Dispatch-shape key of a lockstep group: the knobs that fix the shapes
/// of the shared draft/verify dispatches. Requests may share decode rounds
/// iff `(c, gamma, tree)` match — the tree policy fixes the round's node
/// table, so it is part of the shape; seed, `max_len`, context, the k-mer
/// *table* and selection knobs — per-sequence since the SeqSpec redesign,
/// so different protein families and mixed SpecMER/vanilla methods splice
/// into one group — and the sampling params (`temp`/`top_p` only gate the
/// per-row `adjust_dist`, threaded per-sequence through
/// [`DraftSeq`]/[`VerifySeq`]) — stay free per sequence. `Eq`/`Hash` make
/// the shape usable directly as the batcher's grouping key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LockstepShape {
    pub c: usize,
    pub gamma: usize,
    /// Candidate-tree drafting policy (default = flat chains).
    pub tree: TreePolicy,
}

impl LockstepShape {
    pub fn of(cfg: &GenConfig) -> LockstepShape {
        LockstepShape { c: cfg.c, gamma: cfg.gamma, tree: cfg.tree }
    }

    /// Whether a request with `cfg` may join a group of this shape.
    pub fn admits(&self, cfg: &GenConfig) -> bool {
        cfg.c == self.c && cfg.gamma == self.gamma && cfg.tree == self.tree
    }
}

/// One request joining an in-flight lockstep group. Owned (unlike
/// [`SpecBatchItem`]): admitted requests outlive the caller's borrow of the
/// round that admitted them. `ticket` is the caller's correlation key,
/// echoed back through [`AdmissionHook::complete`]. The table handle rides
/// per item, so requests for different protein families join one group.
pub struct AdmitItem {
    pub ticket: u64,
    pub context: Vec<u8>,
    pub cfg: GenConfig,
    /// This sequence's k-mer table (None for vanilla speculative decoding).
    pub table: Option<Arc<KmerTable>>,
}

/// Round-boundary admission control for continuous batching.
///
/// [`speculative_generate_continuous`] calls `admit` at *every* draft/verify
/// round boundary — the worker's chance to splice newly-queued compatible
/// requests into the in-flight group — and `complete` the moment any
/// sequence finishes (so clients are answered mid-flight, not when the
/// whole group drains).
pub trait AdmissionHook {
    /// Called at each round boundary with the number of sequences still in
    /// flight; returns the requests to admit into the group.
    fn admit(&mut self, active: usize) -> Vec<AdmitItem>;
    /// Delivers one sequence's final result (exactly once per ticket).
    fn complete(&mut self, ticket: u64, result: Result<GenOutput>);
    /// Called at each round boundary with the resident tickets; returns the
    /// sequences to cancel mid-group and the error to answer each with
    /// (deadline enforcement lives behind this: wall-clock policy stays in
    /// the coordinator, the lockstep driver only retires what it is told).
    /// Cancelled tickets are delivered through [`Self::complete`] like any
    /// other retirement. Defaults to cancelling nothing.
    fn cancel(&mut self, resident: &[u64]) -> Vec<(u64, anyhow::Error)> {
        let _ = resident;
        Vec::new()
    }
}

/// Worker-resident prefix-reuse and admission-cost knobs for
/// [`speculative_generate_continuous_with`]. Default = disabled: no
/// stores, one-shot prefill at admission (the pre-prefix-store behavior).
///
/// The stores are `Rc<RefCell<_>>` because engines — and therefore
/// lockstep groups — live on one worker thread (`GenEngine` is
/// deliberately `!Send`); the coordinator-visible side of the store is the
/// `Send + Sync` [`crate::runtime::Residency`] map the store publishes
/// into.
#[derive(Clone, Default)]
pub struct PrefixParams {
    /// Draft-model KV snapshot store (exact-context keys).
    pub draft_store: Option<Rc<RefCell<PrefixStore>>>,
    /// Target-model KV snapshot store.
    pub target_store: Option<Rc<RefCell<PrefixStore>>>,
    /// Max context tokens fed per model per lockstep round while a cold
    /// admission prefills (0 = one-shot prefill at admission). Only
    /// contexts longer than one chunk enter the chunked-prefill phase.
    pub prefill_chunk: usize,
}

/// Generate sequences with continuous batching: an in-flight lockstep
/// group that admits new compatible requests at every round boundary while
/// finished sequences drop out (and are answered) mid-flight.
///
/// Starts empty: the first `admit` call supplies the initial members.
/// Returns when a round boundary finds the group empty and the hook has
/// nothing to admit. Admission never perturbs resident sequences — each
/// sequence keeps its own RNG/acceptance state and cache, and the batched
/// dispatches are row-independent, so every token stream stays bitwise
/// identical to a solo [`speculative_generate`] run with the same seed
/// (pinned by `tests/batch_decode_equivalence.rs`).
pub fn speculative_generate_continuous<D: ModelBackend, T: ModelBackend>(
    draft: &D,
    target: &T,
    shape: LockstepShape,
    hook: &mut dyn AdmissionHook,
) {
    speculative_generate_continuous_with(draft, target, shape, hook, PrefixParams::default())
}

/// [`speculative_generate_continuous`] with prefix-store reuse and chunked
/// prefill admission ([`PrefixParams`]). Still-prefilling admissions count
/// as active (the group keeps stepping to advance them) but join the
/// shared dispatches only once fully fed, so the determinism contract
/// above is unchanged.
pub fn speculative_generate_continuous_with<D: ModelBackend, T: ModelBackend>(
    draft: &D,
    target: &T,
    shape: LockstepShape,
    hook: &mut dyn AdmissionHook,
    params: PrefixParams,
) {
    let mut group = LockstepGroup::with_params(draft, target, shape, params);
    loop {
        let items = hook.admit(group.active());
        let none_admitted = items.is_empty();
        for item in items {
            group.admit(item);
        }
        // Round-boundary cancellation (e.g. expired deadlines). Retiring a
        // sequence here is indistinguishable from it finishing this round:
        // per-sequence RNG/caches and row-independent dispatches mean the
        // survivors' token streams are untouched.
        if group.active() > 0 {
            for (ticket, err) in hook.cancel(&group.tickets()) {
                group.cancel(ticket, err);
            }
        }
        for (ticket, result) in group.drain_completed() {
            hook.complete(ticket, result);
        }
        if group.active() == 0 {
            if none_admitted {
                return;
            }
            continue; // every admitted item failed init or finished instantly
        }
        group.step_round();
        for (ticket, result) in group.drain_completed() {
            hook.complete(ticket, result);
        }
    }
}

/// Per-sequence state of the lockstep loop. The RNG stream is consumed in
/// exactly the order the sequential path consumes it (round uniforms, then
/// coupling draws, then the bonus draw), which is what makes the batched
/// token stream reproduce the solo one.
struct LockSeq<DC, TC> {
    ticket: u64,
    dcache: DC,
    tcache: TC,
    rng: Pcg64,
    out: GenOutput,
    draft_fed: usize,
    /// Last target-fed frontier (tree mode): `verify_tree` only commits
    /// trunk KV, so every token committed in a round is re-fed in the next
    /// round's trunk `tokens[target_fed..committed]`. Unused by the flat
    /// path, whose `verify` rewrites from `committed - 1` each round.
    target_fed: usize,
    /// Per-sequence sampling params (free within a lockstep group: they
    /// only gate this sequence's `adjust_dist` rows).
    temp: f32,
    top_p: f32,
    /// cfg.max_len clamped to the model cap (the accept-loop limit).
    eff_max: usize,
    /// Round-loop limit: eff_max further clamped by the KV hard cap.
    stop_at: usize,
    /// This sequence's own family's k-mer table: selection in a mixed-
    /// family group always scores a block against *its* MSA statistics.
    table: Option<Arc<KmerTable>>,
    kset: crate::kmer::KmerSet,
    kmer_boundary: bool,
    // round scratch (kept across rounds to avoid per-round allocation)
    committed: usize,
    sel: usize,
    feed: Vec<u8>,
    u: Vec<f32>,
    vtoks: Vec<u8>,
}

impl<DC, TC> LockSeq<DC, TC> {
    /// The sequential loop's stop predicate, checked at round boundaries.
    fn finished(&self) -> bool {
        self.out.tokens.len() >= self.stop_at || *self.out.tokens.last().unwrap() == EOS
    }
}

/// One model side's prefill progress for an admission in flight. `fed` is
/// the context-prefill frontier (target: `context.len() - 1`); the
/// sequence activates only when both sides reach it.
struct PrefillProgress<C> {
    cache: C,
    /// Context positions prefilled so far.
    fed: usize,
    /// Prefill positions this admission actually *computed* (0 on a
    /// snapshot hit) — summed into [`GenOutput::prefill_tokens`].
    computed: u64,
    /// Publish the finished KV back into the prefix store (set on a cold
    /// chunked admission when a store is configured).
    publish: bool,
}

/// A chunk-admitted request between admission and activation: it holds its
/// half-prefilled caches and advances at most `prefill_chunk` tokens per
/// model per round boundary (`LockstepGroup::advance_pending`) before
/// becoming a resident `LockSeq`. Config validation already passed at
/// admission; the RNG stream is not created until activation, so the
/// eventual token stream is bitwise-identical to a cold solo run.
struct PrefillState<DC, TC> {
    ticket: u64,
    context: Vec<u8>,
    cfg: GenConfig,
    table: Option<Arc<KmerTable>>,
    draft: PrefillProgress<DC>,
    target: PrefillProgress<TC>,
}

/// Acquire one model side's prefilled cache for an admission, cheapest
/// path first: (1) prefix-store **hit** — attach the snapshot
/// copy-on-write (`prefill_into`, no forward); (2) cold + chunking
/// enabled + context longer than one chunk + backend supports incremental
/// prefill — start an empty cache to be fed across round boundaries;
/// (3) one-shot prefill, publishing the snapshot to the store if present.
fn acquire_prefill<B: ModelBackend>(
    backend: &B,
    store: &Option<Rc<RefCell<PrefixStore>>>,
    context: &[u8],
    chunk: usize,
) -> Result<PrefillProgress<B::Cache>> {
    let n_feed = context.len() - 1;
    if let Some(st) = store {
        if let Some(snap) = st.borrow_mut().lookup(context) {
            return Ok(PrefillProgress {
                cache: backend.prefill_into(&snap)?,
                fed: n_feed,
                computed: 0,
                publish: false,
            });
        }
    }
    if chunk > 0 && n_feed > chunk {
        if let Some(cache) = backend.prefill_begin() {
            return Ok(PrefillProgress { cache, fed: 0, computed: 0, publish: store.is_some() });
        }
    }
    let cache = backend.prefill(context)?;
    if let Some(st) = store {
        let host = backend.cache_to_host(&cache)?;
        st.borrow_mut().insert(context, Arc::new(host));
    }
    Ok(PrefillProgress { cache, fed: n_feed, computed: n_feed as u64, publish: false })
}

/// Build one sequence's lockstep state from already-prefilled caches.
/// Validation happened at admission; this cannot fail.
#[allow(clippy::too_many_arguments)]
fn make_seq<DC, TC>(
    ticket: u64,
    context: Vec<u8>,
    cfg: &GenConfig,
    table: Option<Arc<KmerTable>>,
    dcache: DC,
    tcache: TC,
    prefill_tokens: u64,
    c: usize,
    gamma: usize,
    model_cap: usize,
) -> LockSeq<DC, TC> {
    let eff_max = cfg.max_len.min(model_cap);
    // same slack rule as the sequential loop: a full block must fit
    let hard_cap = model_cap - gamma;
    let context_len = context.len();
    LockSeq {
        ticket,
        dcache,
        tcache,
        rng: Pcg64::new(cfg.seed),
        out: GenOutput {
            tokens: context,
            context_len,
            prefill_tokens,
            ..Default::default()
        },
        draft_fed: context_len - 1,
        target_fed: context_len - 1,
        temp: cfg.temp,
        top_p: cfg.top_p,
        eff_max,
        stop_at: eff_max.min(hard_cap),
        table,
        kset: cfg.kset,
        kmer_boundary: cfg.kmer_boundary,
        committed: 0,
        sel: 0,
        feed: Vec::new(),
        u: Vec::with_capacity(c * gamma),
        vtoks: Vec::with_capacity(gamma + 1),
    }
}

/// Explicit state machine of one in-flight lockstep group: resident
/// sequences share each round's draft/verify dispatches; [`Self::admit`]
/// splices a new sequence in at a round boundary (prefilling its caches so
/// the backend can reuse a freed arena slot next round) and finished
/// sequences are retired into a completion queue the caller drains between
/// rounds. Every resident sequence is active — retirement happens at the
/// boundary, so a round never carries dead rows.
struct LockstepGroup<'m, D: ModelBackend, T: ModelBackend> {
    draft: &'m D,
    target: &'m T,
    shape: LockstepShape,
    model_cap: usize,
    /// The round's candidate-forest node table (tree mode; empty when the
    /// policy is off). Fixed by the shape, so computed once per group.
    tree_parents: Vec<Option<usize>>,
    /// Root-to-leaf node-id paths of that forest — the candidate blocks
    /// k-mer selection ranks and coupling walks.
    tree_paths: Vec<Vec<usize>>,
    seqs: Vec<LockSeq<D::Cache, T::Cache>>,
    /// Chunk-admitted requests still prefilling: they count as active and
    /// advance at each round boundary, but join dispatches only once fed.
    pending: Vec<PrefillState<D::Cache, T::Cache>>,
    params: PrefixParams,
    completed: Vec<(u64, Result<GenOutput>)>,
}

impl<'m, D: ModelBackend, T: ModelBackend> LockstepGroup<'m, D, T> {
    fn new(draft: &'m D, target: &'m T, shape: LockstepShape) -> Self {
        LockstepGroup::with_params(draft, target, shape, PrefixParams::default())
    }

    fn with_params(
        draft: &'m D,
        target: &'m T,
        shape: LockstepShape,
        params: PrefixParams,
    ) -> Self {
        let model_cap = target.maxlen().min(draft.maxlen());
        let (tree_parents, tree_paths) = if shape.tree.enabled() {
            let parents = shape.tree.build_parents(shape.c, shape.gamma);
            let shape_tree =
                TokenTree { tokens: vec![0; parents.len()], parents: parents.clone() };
            let paths = shape_tree.paths();
            (parents, paths)
        } else {
            (Vec::new(), Vec::new())
        };
        LockstepGroup {
            draft,
            target,
            shape,
            model_cap,
            tree_parents,
            tree_paths,
            seqs: Vec::new(),
            pending: Vec::new(),
            params,
            completed: Vec::new(),
        }
    }

    /// Sequences the group still owes a completion for: resident decoders
    /// plus chunk-admitted requests that are still prefilling (the driver
    /// must keep stepping to advance those).
    fn active(&self) -> usize {
        self.seqs.len() + self.pending.len()
    }

    fn drain_completed(&mut self) -> Vec<(u64, Result<GenOutput>)> {
        std::mem::take(&mut self.completed)
    }

    /// Tickets of the resident (still-decoding) sequences in slot order,
    /// then the still-prefilling admissions in arrival order.
    fn tickets(&self) -> Vec<u64> {
        self.seqs
            .iter()
            .map(|s| s.ticket)
            .chain(self.pending.iter().map(|p| p.ticket))
            .collect()
    }

    /// Retire one resident or still-prefilling sequence mid-group with an
    /// error, through the same completion queue as natural (EOS / length)
    /// retirement. Unknown tickets are ignored — the sequence may have
    /// finished this round.
    fn cancel(&mut self, ticket: u64, err: anyhow::Error) {
        if let Some(i) = self.seqs.iter().position(|s| s.ticket == ticket) {
            let seq = self.seqs.remove(i);
            self.completed.push((seq.ticket, Err(err)));
        } else if let Some(i) = self.pending.iter().position(|p| p.ticket == ticket) {
            let st = self.pending.remove(i);
            self.completed.push((st.ticket, Err(err)));
        }
    }

    /// Check the group's slot-liveness, ticket-uniqueness, feed-accounting
    /// and tree-table invariants. Always compiled — the seeded-corruption
    /// tests call it directly — while the round-boundary call site in
    /// [`Self::step_round`] is `cfg!(debug_assertions)` +
    /// `SPECMER_VALIDATE`-gated. The error message names the invariant.
    fn debug_validate(&self) -> Result<(), String> {
        let mut seen = std::collections::BTreeSet::new();
        for (i, s) in self.seqs.iter().enumerate() {
            if !seen.insert(s.ticket) {
                return Err(format!(
                    "LockstepGroup slot liveness invariant broken (double-freed slot): \
                     ticket {} is resident in more than one slot",
                    s.ticket
                ));
            }
            if s.finished() {
                return Err(format!(
                    "LockstepGroup slot liveness invariant broken: slot {i} (ticket {}) is \
                     already finished but still resident",
                    s.ticket
                ));
            }
            let len = s.out.tokens.len();
            if s.committed > len || s.draft_fed > len || s.target_fed > len {
                return Err(format!(
                    "LockstepGroup feed accounting invariant broken: slot {i} (ticket {}) \
                     has committed {} / draft_fed {} / target_fed {} beyond its {len} tokens",
                    s.ticket, s.committed, s.draft_fed, s.target_fed
                ));
            }
        }
        for p in &self.pending {
            if !seen.insert(p.ticket) {
                return Err(format!(
                    "LockstepGroup slot liveness invariant broken (double-freed slot): \
                     ticket {} is both prefilling and resident",
                    p.ticket
                ));
            }
            let n_feed = p.context.len() - 1;
            if p.draft.fed > n_feed || p.target.fed > n_feed {
                return Err(format!(
                    "LockstepGroup prefill frontier invariant broken: ticket {} has \
                     draft fed {} / target fed {} beyond its {} context-prefill tokens",
                    p.ticket, p.draft.fed, p.target.fed, n_feed
                ));
            }
        }
        for (ticket, _) in &self.completed {
            if seen.contains(ticket) {
                return Err(format!(
                    "LockstepGroup slot liveness invariant broken (double-freed slot): \
                     ticket {ticket} is both resident and completed"
                ));
            }
        }
        if let Some(st) = &self.params.draft_store {
            st.borrow().debug_validate().map_err(|e| format!("draft prefix store: {e}"))?;
        }
        if let Some(st) = &self.params.target_store {
            st.borrow().debug_validate().map_err(|e| format!("target prefix store: {e}"))?;
        }
        for (i, p) in self.tree_parents.iter().enumerate() {
            if let Some(p) = *p {
                if p >= i {
                    return Err(format!(
                        "LockstepGroup tree parent table invariant broken (cycle risk): \
                         node {i} lists parent {p}, but parents must precede children"
                    ));
                }
            }
        }
        for (pi, path) in self.tree_paths.iter().enumerate() {
            let rooted = match path.first() {
                Some(&r) => self.tree_parents.get(r) == Some(&None),
                None => false,
            };
            let mut linked = true;
            for w in path.windows(2) {
                if self.tree_parents.get(w[1]) != Some(&Some(w[0])) {
                    linked = false;
                }
            }
            if !rooted || !linked {
                return Err(format!(
                    "LockstepGroup tree path table invariant broken: path {pi} ({path:?}) is \
                     not a root-to-leaf chain of the parent table"
                ));
            }
        }
        Ok(())
    }

    /// Admit one request at the current round boundary. A shape mismatch,
    /// probing config, invalid config or failed prefill completes the
    /// ticket with an error (never poisons residents); a context already at
    /// its limit completes immediately with a zero-round output, exactly
    /// like the solo loop.
    fn admit(&mut self, item: AdmitItem) {
        if !self.shape.admits(&item.cfg) {
            self.completed.push((
                item.ticket,
                Err(anyhow::anyhow!(
                    "request admitted into a lockstep group with a different \
                     (c, gamma) shape"
                )),
            ));
            return;
        }
        // probe items interleave extra dispatches and RNG draws the solo
        // path performs but lockstep rounds cannot: admitting one would
        // silently diverge from its solo run (the batch entry point routes
        // them through the sequential engine instead — do the same upstream)
        if item.cfg.probe_rate > 0.0 {
            self.completed.push((
                item.ticket,
                Err(anyhow::anyhow!(
                    "probe_rate > 0 requests cannot join a lockstep group; \
                     decode them through the sequential path"
                )),
            ));
            return;
        }
        if let Err(e) = item.cfg.validate(item.context.len(), self.model_cap) {
            self.completed.push((item.ticket, Err(e)));
            return;
        }
        let chunk = self.params.prefill_chunk;
        let draft =
            match acquire_prefill(self.draft, &self.params.draft_store, &item.context, chunk) {
                Ok(p) => p,
                Err(e) => {
                    self.completed.push((item.ticket, Err(e)));
                    return;
                }
            };
        let target =
            match acquire_prefill(self.target, &self.params.target_store, &item.context, chunk) {
                Ok(p) => p,
                Err(e) => {
                    self.completed.push((item.ticket, Err(e)));
                    return;
                }
            };
        let n_feed = item.context.len() - 1;
        let st = PrefillState {
            ticket: item.ticket,
            context: item.context,
            cfg: item.cfg,
            table: item.table,
            draft,
            target,
        };
        if st.draft.fed == n_feed && st.target.fed == n_feed {
            self.activate(st);
        } else {
            self.pending.push(st);
        }
    }

    /// Promote a fully-prefilled admission to a resident sequence. The RNG
    /// stream starts here — exactly where a cold solo run would start it.
    fn activate(&mut self, st: PrefillState<D::Cache, T::Cache>) {
        let prefill_tokens = st.draft.computed + st.target.computed;
        let s = make_seq(
            st.ticket,
            st.context,
            &st.cfg,
            st.table,
            st.draft.cache,
            st.target.cache,
            prefill_tokens,
            self.shape.c,
            self.shape.gamma,
            self.model_cap,
        );
        if s.finished() {
            self.completed.push((s.ticket, Ok(s.out)));
        } else {
            self.seqs.push(s);
        }
    }

    /// Advance every still-prefilling admission by at most one chunk per
    /// model, activating the ones that finish (publishing their KV snapshot
    /// to the prefix store first, so the *next* same-context admission is a
    /// copy-on-write hit). A failed chunk fails only its own ticket.
    fn advance_pending(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let chunk = self.params.prefill_chunk.max(1);
        let mut i = 0;
        while i < self.pending.len() {
            let mut failed: Option<anyhow::Error> = None;
            {
                let st = &mut self.pending[i];
                let n_feed = st.context.len() - 1;
                if st.draft.fed < n_feed {
                    let end = (st.draft.fed + chunk).min(n_feed);
                    match self.draft.prefill_chunked(
                        &mut st.draft.cache,
                        &st.context[st.draft.fed..end],
                        st.draft.fed,
                    ) {
                        Ok(()) => {
                            st.draft.computed += (end - st.draft.fed) as u64;
                            st.draft.fed = end;
                        }
                        Err(e) => failed = Some(e),
                    }
                }
                if failed.is_none() && st.target.fed < n_feed {
                    let end = (st.target.fed + chunk).min(n_feed);
                    match self.target.prefill_chunked(
                        &mut st.target.cache,
                        &st.context[st.target.fed..end],
                        st.target.fed,
                    ) {
                        Ok(()) => {
                            st.target.computed += (end - st.target.fed) as u64;
                            st.target.fed = end;
                        }
                        Err(e) => failed = Some(e),
                    }
                }
            }
            if let Some(e) = failed {
                let st = self.pending.remove(i);
                self.completed.push((st.ticket, Err(e)));
                continue;
            }
            let n_feed = self.pending[i].context.len() - 1;
            if self.pending[i].draft.fed == n_feed && self.pending[i].target.fed == n_feed {
                let st = self.pending.remove(i);
                if st.draft.publish {
                    if let Some(store) = &self.params.draft_store {
                        if let Ok(host) = self.draft.cache_to_host(&st.draft.cache) {
                            store.borrow_mut().insert(&st.context, Arc::new(host));
                        }
                    }
                }
                if st.target.publish {
                    if let Some(store) = &self.params.target_store {
                        if let Ok(host) = self.target.cache_to_host(&st.target.cache) {
                            store.borrow_mut().insert(&st.context, Arc::new(host));
                        }
                    }
                }
                self.activate(st);
                continue;
            }
            i += 1;
        }
    }

    /// Run one draft/verify round over every resident sequence, then retire
    /// the ones that finished. A *shared* dispatch error fails all residents
    /// (per-sequence work the dispatch was carrying is lost) and empties the
    /// group.
    fn step_round(&mut self) {
        // round boundary: both the flat and tree variants pass through here
        if cfg!(debug_assertions) && crate::runtime::simd::validate_enabled() {
            if let Err(e) = self.debug_validate() {
                panic!("SPECMER_VALIDATE: LockstepGroup invariant violated: {e}");
            }
        }
        // chunk-admitted requests advance their prefill at the boundary;
        // fully-fed ones activate and join this very round's dispatches
        self.advance_pending();
        if self.seqs.is_empty() {
            return; // nothing resident yet (pending may still be prefilling)
        }
        if self.shape.tree.enabled() {
            self.step_round_tree();
            return;
        }
        let (c, gamma) = (self.shape.c, self.shape.gamma);

        // ---- round setup: draw round uniforms on each sequence's RNG ----
        for s in self.seqs.iter_mut() {
            s.out.rounds += 1;
            s.committed = s.out.tokens.len();
            s.feed.clear();
            s.feed.extend_from_slice(&s.out.tokens[s.draft_fed..]);
            s.u.clear();
            for _ in 0..c * gamma {
                s.u.push(s.rng.next_f32());
            }
            s.out.draft_calls += 1;
            s.out.tree_nodes += (c * gamma) as u64;
        }

        // ---- 1. candidate construction: one lockstep draft dispatch -----
        let mut dseqs: Vec<DraftSeq<'_, D::Cache>> = Vec::new();
        for s in self.seqs.iter_mut() {
            dseqs.push(DraftSeq {
                cache: &mut s.dcache,
                feed: &s.feed,
                pos: s.draft_fed,
                u: &s.u,
                temp: s.temp,
                top_p: s.top_p,
            });
        }
        let blocks_res = self.draft.generate_batch(&mut dseqs, c, gamma);
        drop(dseqs);
        let blocks = match blocks_res {
            Ok(b) => b,
            Err(e) => {
                self.poison(e);
                return;
            }
        };

        // ---- 2. per-sequence k-mer selection (each against its *own*
        //         family's table — groups may mix proteins and methods) ---
        for (s, block) in self.seqs.iter_mut().zip(&blocks) {
            s.draft_fed = s.committed;
            s.sel = match (s.table.as_deref(), c) {
                (Some(t), cc) if cc > 1 => {
                    if s.kmer_boundary {
                        let tail_len = s.kset.kmax() - 1;
                        let tail = &s.out.tokens[s.committed.saturating_sub(tail_len)..];
                        score::select_best_with_context(t, tail, &block.tokens, s.kset)
                    } else {
                        score::select_best(t, &block.tokens, s.kset)
                    }
                }
                _ => 0,
            };
            s.vtoks.clear();
            s.vtoks.push(s.out.tokens[s.committed - 1]);
            s.vtoks.extend_from_slice(&block.tokens[s.sel]);
        }

        // ---- 3. conditional probabilities: one lockstep verify ----------
        let mut vseqs: Vec<VerifySeq<'_, T::Cache>> = Vec::new();
        for s in self.seqs.iter_mut() {
            vseqs.push(VerifySeq {
                cache: &mut s.tcache,
                toks: &s.vtoks,
                pos: s.committed - 1,
                temp: s.temp,
                top_p: s.top_p,
            });
        }
        let verifies_res = self.target.verify_batch(&mut vseqs);
        drop(vseqs);
        let verifies = match verifies_res {
            Ok(v) => v,
            Err(e) => {
                self.poison(e);
                return;
            }
        };

        // ---- 4. per-sequence maximal coupling on its own RNG stream -----
        for ((s, block), verify) in self.seqs.iter_mut().zip(&blocks).zip(&verifies) {
            s.out.target_calls += 1;
            let cand = &block.tokens[s.sel];
            let p_dists = &block.dists[s.sel];
            let mut all_accepted = true;
            for i in 0..gamma {
                let x = cand[i] as usize;
                let (acc, tok) = sampling::couple(&p_dists[i], &verify.dists[i], x, &mut s.rng);
                s.out.online_nll_sum += sampling::nll_of(&verify.dists[i], tok);
                s.out.tokens.push(tok as u8);
                if acc {
                    s.out.accepted += 1;
                } else {
                    s.out.rejected += 1;
                    all_accepted = false;
                }
                if !acc || tok as u8 == EOS || s.out.tokens.len() >= s.eff_max {
                    // stopping for any reason means no bonus token this round
                    all_accepted = false;
                    break;
                }
            }
            if all_accepted && s.out.tokens.len() < s.eff_max {
                let bonus_dist = &verify.dists[gamma];
                let tok = sampling::sample(bonus_dist, s.rng.next_f32());
                s.out.online_nll_sum += sampling::nll_of(bonus_dist, tok);
                s.out.tokens.push(tok as u8);
                s.out.bonus += 1;
            }
        }

        // ---- retire finished sequences (frees their slots for admission) -
        let mut still = Vec::with_capacity(self.seqs.len());
        for s in std::mem::take(&mut self.seqs) {
            if s.finished() {
                self.completed.push((s.ticket, Ok(s.out)));
            } else {
                still.push(s);
            }
        }
        self.seqs = still;
    }

    /// One tree-drafting round: per sequence, draft the shape's candidate
    /// forest ([`ModelBackend::draft_tree`]), rank its root-to-leaf paths
    /// by k-mer score, verify the whole tree in one tree-masked pass
    /// ([`ModelBackend::verify_tree`]), and walk the selected path with
    /// maximal coupling. The RNG stream order matches the flat round
    /// (node uniforms up front, then coupling draws, then the bonus draw),
    /// and for chain-shaped trees the per-node uniforms coincide with the
    /// flat `u[ci*gamma + gi]` — the degenerate bitwise equivalence.
    ///
    /// The draft/verify calls are per-sequence (cross-sequence tree
    /// batching is an open ROADMAP item), so a failing call retires only
    /// its own sequence instead of poisoning the group.
    fn step_round_tree(&mut self) {
        let n_nodes = self.tree_parents.len();
        let nseq = self.seqs.len();
        let mut failed: Vec<Option<anyhow::Error>> = (0..nseq).map(|_| None).collect();
        for (si, s) in self.seqs.iter_mut().enumerate() {
            s.out.rounds += 1;
            s.committed = s.out.tokens.len();
            s.feed.clear();
            s.feed.extend_from_slice(&s.out.tokens[s.draft_fed..]);
            s.u.clear();
            for _ in 0..n_nodes {
                s.u.push(s.rng.next_f32());
            }
            s.out.draft_calls += 1;
            s.out.tree_nodes += n_nodes as u64;

            // ---- 1. draft the candidate forest (shared prefixes once) ----
            let block = match self.draft.draft_tree(
                &mut s.dcache,
                &s.feed,
                s.draft_fed,
                &self.tree_parents,
                &s.u,
                s.temp,
                s.top_p,
            ) {
                Ok(b) => b,
                Err(e) => {
                    failed[si] = Some(e);
                    continue;
                }
            };
            s.draft_fed = s.committed;
            let tree = TokenTree { parents: self.tree_parents.clone(), tokens: block.tokens };

            // ---- 2. k-mer selection over root-to-leaf candidate paths ----
            let path_toks: Vec<Vec<u8>> = self
                .tree_paths
                .iter()
                .map(|p| p.iter().map(|&q| tree.tokens[q]).collect())
                .collect();
            s.sel = match s.table.as_deref() {
                Some(t) if path_toks.len() > 1 => {
                    if s.kmer_boundary {
                        let tail_len = s.kset.kmax() - 1;
                        let tail = &s.out.tokens[s.committed.saturating_sub(tail_len)..];
                        score::select_best_with_context(t, tail, &path_toks, s.kset)
                    } else {
                        score::select_best(t, &path_toks, s.kset)
                    }
                }
                _ => 0,
            };

            // ---- 3. verify the whole tree in one tree-masked pass --------
            s.vtoks.clear();
            s.vtoks.extend_from_slice(&s.out.tokens[s.target_fed..s.committed]);
            let vb = match self.target.verify_tree(
                &mut s.tcache,
                &s.vtoks,
                s.target_fed,
                &tree,
                s.temp,
                s.top_p,
            ) {
                Ok(v) => v,
                Err(e) => {
                    failed[si] = Some(e);
                    continue;
                }
            };
            s.out.target_calls += 1;
            s.target_fed = s.committed;

            // ---- 4. maximal coupling along the selected path -------------
            let path = &self.tree_paths[s.sel];
            let mut all_accepted = true;
            for (i, &q) in path.iter().enumerate() {
                let x = tree.tokens[q] as usize;
                let qd = if i == 0 { &vb.root_dist } else { &vb.dists[path[i - 1]] };
                let (acc, tok) = sampling::couple(&block.dists[q], qd, x, &mut s.rng);
                s.out.online_nll_sum += sampling::nll_of(qd, tok);
                s.out.tokens.push(tok as u8);
                if acc {
                    s.out.accepted += 1;
                } else {
                    s.out.rejected += 1;
                    all_accepted = false;
                }
                if !acc || tok as u8 == EOS || s.out.tokens.len() >= s.eff_max {
                    // stopping for any reason means no bonus token this round
                    all_accepted = false;
                    break;
                }
            }
            if all_accepted && s.out.tokens.len() < s.eff_max {
                // the selected leaf's dist is the bonus distribution
                let bonus_dist = &vb.dists[*path.last().expect("paths are non-empty")];
                let tok = sampling::sample(bonus_dist, s.rng.next_f32());
                s.out.online_nll_sum += sampling::nll_of(bonus_dist, tok);
                s.out.tokens.push(tok as u8);
                s.out.bonus += 1;
            }
        }

        // ---- retire failed and finished sequences ------------------------
        let mut still = Vec::with_capacity(self.seqs.len());
        for (si, s) in std::mem::take(&mut self.seqs).into_iter().enumerate() {
            if let Some(e) = failed[si].take() {
                self.completed
                    .push((s.ticket, Err(anyhow::anyhow!("tree dispatch failed: {e:#}"))));
            } else if s.finished() {
                self.completed.push((s.ticket, Ok(s.out)));
            } else {
                still.push(s);
            }
        }
        self.seqs = still;
    }

    /// A shared dispatch died mid-round: fail every resident sequence.
    /// Sequences retired at earlier boundaries keep their valid outputs.
    fn poison(&mut self, e: anyhow::Error) {
        let msg = format!("{e:#}");
        for s in self.seqs.drain(..) {
            self.completed
                .push((s.ticket, Err(anyhow::anyhow!("lockstep dispatch failed: {msg}"))));
        }
    }
}

fn lockstep_generate<D: ModelBackend, T: ModelBackend>(
    draft: &D,
    target: &T,
    items: &[SpecBatchItem<'_>],
    idxs: &[usize],
) -> Vec<Result<GenOutput>> {
    let shape = LockstepShape::of(items[idxs[0]].cfg);
    for &i in &idxs[1..] {
        if !shape.admits(items[i].cfg) {
            // a caller bug, not a request failure: report it on every item
            return idxs
                .iter()
                .map(|_| {
                    Err(anyhow::anyhow!(
                        "lockstep batch requires equal (c, gamma) across items \
                         (group requests before dispatching)"
                    ))
                })
                .collect();
        }
    }

    let mut group = LockstepGroup::new(draft, target, shape);
    // per-item init: a bad config or failed prefill drops only that item
    for (slot, &i) in idxs.iter().enumerate() {
        group.admit(AdmitItem {
            ticket: slot as u64,
            context: items[i].context.to_vec(),
            cfg: items[i].cfg.clone(),
            table: items[i].table.clone(),
        });
    }
    let mut results: Vec<Option<Result<GenOutput>>> = (0..idxs.len()).map(|_| None).collect();
    loop {
        for (ticket, result) in group.drain_completed() {
            results[ticket as usize] = Some(result);
        }
        if group.active() == 0 {
            break;
        }
        group.step_round();
    }
    results.into_iter().map(|o| o.expect("every slot resolved")).collect()
}

/// Estimate a misranking event: did *any* candidate pass a sequence-level
/// acceptance check (the M(s) of Prop. 4.4), and did the selected one? A
/// common uniform couples the comparison across candidates.
///
/// Implementation note: `verify` only rewrites cache slots >= pos, and the
/// frontier convention makes those slots unobservable until rewritten, so
/// we may probe the non-selected candidates against the live cache and then
/// re-verify the selected block to restore its KV — no cache cloning
/// needed. Costs c extra target calls per probed round; off by default.
#[allow(clippy::too_many_arguments)]
fn probe_misranking<T: ModelBackend>(
    target: &T,
    tcache: &mut T::Cache,
    target_calls: &mut u64,
    tokens: &[u8],
    cands: &[Vec<u8>],
    dists: &[Vec<Vec<f32>>],
    sel: usize,
    sel_q: &[Vec<f32>],
    cfg: &GenConfig,
    rng: &mut Pcg64,
) -> Result<(bool, bool)> {
    let committed = tokens.len();
    let eta = rng.next_f64();
    let seq_ratio = |p: &[Vec<f32>], q: &[Vec<f32>], cand: &[u8]| -> f64 {
        let mut lr = 0.0f64;
        for i in 0..cand.len() {
            let x = cand[i] as usize;
            lr += (q[i][x].max(1e-12) as f64).ln() - (p[i][x].max(1e-12) as f64).ln();
        }
        lr.exp().min(1.0)
    };
    let mut any = false;
    let mut sel_ok = false;
    for (i, cand) in cands.iter().enumerate() {
        let r = if i == sel {
            seq_ratio(&dists[i], sel_q, cand)
        } else {
            let mut vtoks = vec![tokens[committed - 1]];
            vtoks.extend_from_slice(cand);
            let vb = target.verify(tcache, &vtoks, committed - 1, cfg.temp, cfg.top_p)?;
            *target_calls += 1;
            seq_ratio(&dists[i], &vb.dists, cand)
        };
        let ok = eta <= r;
        any |= ok;
        if i == sel {
            sel_ok = ok;
        }
    }
    // restore the selected block's KV in the live cache
    let mut vtoks = vec![tokens[committed - 1]];
    vtoks.extend_from_slice(&cands[sel]);
    let _ = target.verify(tcache, &vtoks, committed - 1, cfg.temp, cfg.top_p)?;
    *target_calls += 1;
    Ok((any, sel_ok))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmer::KmerSet;
    use crate::msa::simulate::generate_family;
    use crate::runtime::cpu_ref::CpuModel;
    use crate::tokenizer::BOS;

    fn models() -> (CpuModel, CpuModel) {
        // identical seeds -> draft == target (alpha should be ~1)
        (
            CpuModel::synthetic(2, 16, 2, 64, 7),
            CpuModel::synthetic(2, 16, 2, 64, 7),
        )
    }

    fn cfg(c: usize, gamma: usize, seed: u64) -> GenConfig {
        GenConfig {
            c,
            gamma,
            max_len: 48,
            seed,
            kset: KmerSet::new(true, true, true),
            ..Default::default()
        }
    }

    #[test]
    fn identical_models_accept_everything() {
        let (d, t) = models();
        let out = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(1, 5, 3)).unwrap();
        assert_eq!(out.rejected, 0, "p == q must always accept");
        assert!(out.acceptance_ratio() > 0.999);
        assert!(out.tokens.len() > 3);
    }

    #[test]
    fn different_models_reject_sometimes() {
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let mut total_rej = 0;
        for seed in 0..5 {
            let out = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(1, 5, seed)).unwrap();
            total_rej += out.rejected;
        }
        assert!(total_rej > 0, "independent models should disagree sometimes");
    }

    #[test]
    fn specmer_runs_with_table() {
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let (d, t) = models();
        let out =
            speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &cfg(3, 5, 11)).unwrap();
        assert!(out.tokens.len() > 3);
        assert!(out.rounds > 0);
        assert_eq!(out.draft_calls, out.rounds);
        assert_eq!(out.target_calls, out.rounds);
    }

    #[test]
    fn c1_with_table_equals_plain_speculative() {
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let (d, t) = models();
        let a = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &cfg(1, 5, 13)).unwrap();
        let b = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(1, 5, 13)).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn deterministic_in_seed() {
        let (d, t) = models();
        let a = speculative_generate(&d, &t, None, &[BOS, 5], &cfg(2, 5, 21)).unwrap();
        let b = speculative_generate(&d, &t, None, &[BOS, 5], &cfg(2, 5, 21)).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.accepted, b.accepted);
    }

    #[test]
    fn respects_max_len() {
        let (d, t) = models();
        let mut c = cfg(2, 10, 2);
        c.max_len = 20;
        let out = speculative_generate(&d, &t, None, &[BOS, 5], &c).unwrap();
        assert!(out.tokens.len() <= 20);
    }

    #[test]
    fn token_accounting_consistent() {
        let (d, t) = models();
        for seed in 0..4 {
            let out = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(2, 5, seed)).unwrap();
            // every committed token past context is accepted, rejected(corrected), or bonus
            let committed = (out.tokens.len() - out.context_len) as u64;
            assert_eq!(
                committed,
                out.accepted + out.rejected + out.bonus,
                "accounting: {out:?}"
            );
        }
    }

    /// The lossless-ness property of speculative decoding: with identical
    /// draft and target and the same seed structure, outputs are target-
    /// distributed. We verify a weaker invariant that every committed token
    /// lies in the target's nucleus at its position.
    #[test]
    fn committed_tokens_lie_in_target_nucleus() {
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 9);
        let out = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(2, 5, 33)).unwrap();
        let logits = t.forward_logits(&out.tokens);
        for i in out.context_len..out.tokens.len() {
            let dist = sampling::adjust_dist(&logits[i - 1], 1.0, 0.95);
            assert!(
                dist[out.tokens[i] as usize] > 0.0,
                "token at {i} outside target nucleus"
            );
        }
    }

    #[test]
    fn invalid_configs_error_instead_of_panicking() {
        let (d, t) = models(); // maxlen 64
        // gamma >= model maxlen used to underflow the hard cap and panic
        let mut big = cfg(1, 64, 3);
        big.max_len = 200;
        assert!(speculative_generate(&d, &t, None, &[BOS, 5, 9], &big).is_err());
        let mut huge = cfg(1, 100, 3);
        huge.max_len = 200;
        assert!(speculative_generate(&d, &t, None, &[BOS, 5, 9], &huge).is_err());
        // degenerate c / gamma
        assert!(speculative_generate(&d, &t, None, &[BOS, 5], &cfg(0, 5, 3)).is_err());
        assert!(speculative_generate(&d, &t, None, &[BOS, 5], &cfg(2, 0, 3)).is_err());
        // empty / oversized context
        assert!(speculative_generate(&d, &t, None, &[], &cfg(2, 5, 3)).is_err());
        let mut small = cfg(2, 5, 3);
        small.max_len = 3;
        assert!(speculative_generate(&d, &t, None, &[BOS, 5, 9], &small).is_err());
    }

    #[test]
    fn boundary_selection_derives_tail_from_kset() {
        // with only k=3 active the boundary tail is 2 tokens; selection must
        // agree with scoring every candidate against that exact tail
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let (d, t) = models();
        let mut c = cfg(3, 5, 19);
        c.kset = KmerSet::new(false, true, false);
        c.kmer_boundary = true;
        let out = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &c).unwrap();
        assert!(out.tokens.len() > 3);
        assert!(out.rounds > 0);
    }

    #[test]
    fn batch_matches_sequential_per_sequence() {
        // the tentpole invariant at the decode level: B lockstep sequences
        // == B solo runs, token for token and stat for stat
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = Arc::new(KmerTable::build(&msa));
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let ctxs: [&[u8]; 3] = [&[BOS, 5, 9], &[BOS, 7], &[BOS, 5, 9, 13]];
        let mut cfgs = vec![cfg(3, 5, 11), cfg(3, 5, 23), cfg(3, 5, 31)];
        cfgs[1].max_len = 20; // finishes early and must drop out cleanly
        cfgs[2].kmer_boundary = true; // per-sequence selection knob

        let solo: Vec<GenOutput> = ctxs
            .iter()
            .zip(&cfgs)
            .map(|(ctx, cfg)| speculative_generate(&d, &t, Some(&table), ctx, cfg).unwrap())
            .collect();
        let items: Vec<SpecBatchItem<'_>> = ctxs
            .iter()
            .zip(&cfgs)
            .map(|(ctx, cfg)| SpecBatchItem { context: ctx, cfg, table: Some(table.clone()) })
            .collect();
        let batch = speculative_generate_batch(&d, &t, &items);

        assert_eq!(batch.len(), solo.len());
        for (b, (got, want)) in batch.iter().zip(&solo).enumerate() {
            let got = got.as_ref().unwrap();
            assert_eq!(got.tokens, want.tokens, "seq {b} tokens diverged");
            assert_eq!(got.accepted, want.accepted, "seq {b}");
            assert_eq!(got.rejected, want.rejected, "seq {b}");
            assert_eq!(got.bonus, want.bonus, "seq {b}");
            assert_eq!(got.rounds, want.rounds, "seq {b}");
            assert_eq!(got.draft_calls, want.draft_calls, "seq {b}");
            assert_eq!(got.target_calls, want.target_calls, "seq {b}");
        }
    }

    #[test]
    fn batch_with_mixed_sampling_params_matches_solo_runs() {
        // temp/top_p only gate per-row adjust_dist: requests differing in
        // them share one lockstep group and must still reproduce their solo
        // token streams exactly
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let ctx: &[u8] = &[BOS, 5, 9];
        let mut cfgs = vec![cfg(2, 5, 3), cfg(2, 5, 7), cfg(2, 5, 11)];
        cfgs[0].temp = 1.0;
        cfgs[0].top_p = 1.0;
        cfgs[1].temp = 0.8;
        cfgs[1].top_p = 0.95;
        cfgs[2].temp = 0.6;
        cfgs[2].top_p = 0.9;
        let solo: Vec<GenOutput> = cfgs
            .iter()
            .map(|c| speculative_generate(&d, &t, None, ctx, c).unwrap())
            .collect();
        let items: Vec<SpecBatchItem<'_>> =
            cfgs.iter().map(|c| SpecBatchItem { context: ctx, cfg: c, table: None }).collect();
        let batch = speculative_generate_batch(&d, &t, &items);
        for (b, (got, want)) in batch.iter().zip(&solo).enumerate() {
            let got = got.as_ref().expect("mixed-sampling item failed");
            assert_eq!(got.tokens, want.tokens, "seq {b} diverged");
            assert_eq!(got.accepted, want.accepted, "seq {b}");
            assert_eq!(got.rejected, want.rejected, "seq {b}");
            assert_eq!(got.bonus, want.bonus, "seq {b}");
        }
    }

    #[test]
    fn batch_rejects_mismatched_shapes() {
        let (d, t) = models();
        let a = cfg(2, 5, 1);
        let b = cfg(2, 8, 2); // different gamma: not lockstep-compatible
        let ctx: &[u8] = &[BOS, 5, 9];
        let items = [
            SpecBatchItem { context: ctx, cfg: &a, table: None },
            SpecBatchItem { context: ctx, cfg: &b, table: None },
        ];
        let outs = speculative_generate_batch(&d, &t, &items);
        assert!(outs.iter().all(|r| r.is_err()), "shape mismatch is a caller bug");
    }

    #[test]
    fn batch_isolates_per_item_failures() {
        // one invalid config (context >= max_len) must not take down the
        // healthy requests sharing its lockstep group
        let (d, t) = models();
        let good = cfg(2, 5, 1);
        let mut bad = cfg(2, 5, 2);
        bad.max_len = 3; // context length 3 >= max_len -> validate() fails
        let ctx: &[u8] = &[BOS, 5, 9];
        let items = [
            SpecBatchItem { context: ctx, cfg: &good, table: None },
            SpecBatchItem { context: ctx, cfg: &bad, table: None },
            SpecBatchItem { context: ctx, cfg: &good, table: None },
        ];
        let outs = speculative_generate_batch(&d, &t, &items);
        assert!(outs[0].is_ok(), "{:?}", outs[0].as_ref().err());
        assert!(outs[1].is_err());
        assert!(outs[2].is_ok());
        let want = speculative_generate(&d, &t, None, ctx, &good).unwrap();
        assert_eq!(outs[0].as_ref().unwrap().tokens, want.tokens);
        assert_eq!(outs[2].as_ref().unwrap().tokens, want.tokens);
    }

    #[test]
    fn batch_splices_probe_items_through_sequential_path() {
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = Arc::new(KmerTable::build(&msa));
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let mut probing = cfg(3, 5, 17);
        probing.probe_rate = 1.0;
        let plain = cfg(3, 5, 19);
        let ctx: &[u8] = &[BOS, 5, 9];
        let items = [
            SpecBatchItem { context: ctx, cfg: &probing, table: Some(table.clone()) },
            SpecBatchItem { context: ctx, cfg: &plain, table: Some(table.clone()) },
        ];
        let outs = speculative_generate_batch(&d, &t, &items);
        let probed = outs[0].as_ref().unwrap();
        assert!(!probed.probes.is_empty(), "probe item must still probe");
        let want = speculative_generate(&d, &t, Some(&table), ctx, &plain).unwrap();
        assert_eq!(outs[1].as_ref().unwrap().tokens, want.tokens);
    }

    /// Minimal scripted hook: admits each item once its boundary index is
    /// reached, collects completions by ticket.
    struct Scripted {
        pending: Vec<(usize, AdmitItem)>,
        boundary: usize,
        done: Vec<(u64, Result<GenOutput>)>,
    }

    impl AdmissionHook for Scripted {
        fn admit(&mut self, _active: usize) -> Vec<AdmitItem> {
            let b = self.boundary;
            self.boundary += 1;
            let (now, later): (Vec<_>, Vec<_>) =
                self.pending.drain(..).partition(|(at, _)| *at <= b);
            self.pending = later;
            now.into_iter().map(|(_, item)| item).collect()
        }
        fn complete(&mut self, ticket: u64, result: Result<GenOutput>) {
            self.done.push((ticket, result));
        }
    }

    #[test]
    fn continuous_admission_matches_solo_runs() {
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let ctx: &[u8] = &[BOS, 5, 9];
        let cfgs = [cfg(2, 5, 3), cfg(2, 5, 17)];
        let mut hook = Scripted {
            pending: cfgs
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    // second request arrives a round boundary after the first
                    let item = AdmitItem {
                        ticket: i as u64,
                        context: ctx.to_vec(),
                        cfg: c.clone(),
                        table: None,
                    };
                    (i, item)
                })
                .collect(),
            boundary: 0,
            done: Vec::new(),
        };
        speculative_generate_continuous(&d, &t, LockstepShape::of(&cfgs[0]), &mut hook);
        assert_eq!(hook.done.len(), 2, "every admitted request completed");
        hook.done.sort_by_key(|(t, _)| *t);
        for (i, (ticket, got)) in hook.done.iter().enumerate() {
            assert_eq!(*ticket, i as u64);
            let want = speculative_generate(&d, &t, None, ctx, &cfgs[i]).unwrap();
            assert_eq!(got.as_ref().unwrap().tokens, want.tokens, "seq {i} diverged");
        }
    }

    #[test]
    fn continuous_admission_rejects_mismatched_and_probing_items() {
        let (d, t) = models();
        let good = cfg(2, 5, 1);
        let bad = cfg(2, 8, 2); // different gamma than the group shape
        let mut probing = cfg(2, 5, 4); // probes splice extra dispatches:
        probing.probe_rate = 1.0; // sequential-path only, must be refused
        let ctx: &[u8] = &[BOS, 5, 9];
        let mut hook = Scripted {
            pending: vec![
                (0, AdmitItem { ticket: 0, context: ctx.to_vec(), cfg: good.clone(), table: None }),
                (1, AdmitItem { ticket: 1, context: ctx.to_vec(), cfg: bad, table: None }),
                (1, AdmitItem { ticket: 2, context: ctx.to_vec(), cfg: probing, table: None }),
            ],
            boundary: 0,
            done: Vec::new(),
        };
        speculative_generate_continuous(&d, &t, LockstepShape::of(&good), &mut hook);
        assert_eq!(hook.done.len(), 3);
        hook.done.sort_by_key(|(t, _)| *t);
        assert!(hook.done[0].1.is_ok(), "resident sequence unaffected");
        assert!(hook.done[1].1.is_err(), "mismatched shape must be refused");
        assert!(hook.done[2].1.is_err(), "probe_rate > 0 must be refused");
    }

    #[test]
    fn probe_records_events() {
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let mut c = cfg(3, 5, 17);
        c.probe_rate = 1.0;
        let out = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &c).unwrap();
        assert!(!out.probes.is_empty());
    }

    #[test]
    fn spec_options_is_send() {
        // the Rc -> Arc move on hlo_kmer exists so coordinator workers can
        // carry SpecOptions across threads; pin it at compile time
        fn assert_send<T: Send>() {}
        assert_send::<SpecOptions>();
    }

    #[test]
    fn degenerate_chain_trees_match_flat_bitwise() {
        // branch == 1 with a non-zero mask drives chain-shaped trees through
        // the whole tree path (draft_tree, path scoring, verify_tree) and
        // must reproduce the flat driver bit for bit
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        for seed in [3u64, 11, 29] {
            let flat = cfg(3, 5, seed);
            let mut chain = flat.clone();
            chain.tree = TreePolicy { branch: 1, split_mask: 0b0110 };
            let a = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &flat).unwrap();
            let b = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &chain).unwrap();
            assert_eq!(a.tokens, b.tokens, "seed {seed} tokens diverged");
            assert_eq!(a.accepted, b.accepted, "seed {seed}");
            assert_eq!(a.rejected, b.rejected, "seed {seed}");
            assert_eq!(a.bonus, b.bonus, "seed {seed}");
            assert_eq!(a.rounds, b.rounds, "seed {seed}");
            assert_eq!(a.tree_nodes, b.tree_nodes, "chain trees draft c*gamma nodes");
        }
    }

    #[test]
    fn degenerate_chain_trees_match_flat_without_table() {
        // no k-mer table: flat falls back to candidate 0, the tree path must
        // fall back to path 0 of the same forest
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let flat = cfg(2, 5, 41);
        let mut chain = flat.clone();
        chain.tree = TreePolicy { branch: 1, split_mask: 0b10 };
        let a = speculative_generate(&d, &t, None, &[BOS, 5, 9], &flat).unwrap();
        let b = speculative_generate(&d, &t, None, &[BOS, 5, 9], &chain).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.accepted, b.accepted);
        assert_eq!(a.rejected, b.rejected);
    }

    #[test]
    fn branched_trees_account_and_stay_deterministic() {
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let mut c = cfg(2, 5, 7);
        // per root: 1+1+1+2+2 = 7 nodes, 2 leaves; forest: 14 nodes, 4 paths
        c.tree = TreePolicy { branch: 2, split_mask: 0b1000 };
        let a = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &c).unwrap();
        let b = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &c).unwrap();
        assert_eq!(a.tokens, b.tokens, "tree decoding must be deterministic in seed");
        assert!(a.tokens.len() > 3);
        let committed = (a.tokens.len() - a.context_len) as u64;
        assert_eq!(committed, a.accepted + a.rejected + a.bonus, "accounting: {a:?}");
        assert_eq!(a.tree_nodes, a.rounds * 14, "forest drafts 14 nodes per round");
        assert_eq!(a.draft_calls, a.rounds);
        assert_eq!(a.target_calls, a.rounds);
    }

    #[test]
    fn tree_batch_matches_solo_tree_runs() {
        // the lockstep invariant extends to tree shapes: B tree sequences in
        // one group == B solo tree runs, token for token
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = Arc::new(KmerTable::build(&msa));
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let pol = TreePolicy { branch: 2, split_mask: 0b0100 };
        let ctxs: [&[u8]; 3] = [&[BOS, 5, 9], &[BOS, 7], &[BOS, 5, 9, 13]];
        let mut cfgs = vec![cfg(2, 5, 11), cfg(2, 5, 23), cfg(2, 5, 31)];
        for c in &mut cfgs {
            c.tree = pol;
        }
        cfgs[1].max_len = 20; // finishes early and must drop out cleanly

        let solo: Vec<GenOutput> = ctxs
            .iter()
            .zip(&cfgs)
            .map(|(ctx, cfg)| speculative_generate(&d, &t, Some(&table), ctx, cfg).unwrap())
            .collect();
        let items: Vec<SpecBatchItem<'_>> = ctxs
            .iter()
            .zip(&cfgs)
            .map(|(ctx, cfg)| SpecBatchItem { context: ctx, cfg, table: Some(table.clone()) })
            .collect();
        let batch = speculative_generate_batch(&d, &t, &items);

        assert_eq!(batch.len(), solo.len());
        for (b, (got, want)) in batch.iter().zip(&solo).enumerate() {
            let got = got.as_ref().unwrap();
            assert_eq!(got.tokens, want.tokens, "seq {b} tokens diverged");
            assert_eq!(got.accepted, want.accepted, "seq {b}");
            assert_eq!(got.rejected, want.rejected, "seq {b}");
            assert_eq!(got.bonus, want.bonus, "seq {b}");
            assert_eq!(got.rounds, want.rounds, "seq {b}");
            assert_eq!(got.tree_nodes, want.tree_nodes, "seq {b}");
        }
    }

    #[test]
    fn tree_rejects_invalid_policies() {
        let (d, t) = models();
        let mut zero_branch = cfg(2, 5, 3);
        zero_branch.tree = TreePolicy { branch: 0, split_mask: 0b10 };
        assert!(speculative_generate(&d, &t, None, &[BOS, 5], &zero_branch).is_err());
        let mut out_of_range = cfg(2, 5, 3);
        out_of_range.tree = TreePolicy { branch: 2, split_mask: 1 << 5 };
        assert!(speculative_generate(&d, &t, None, &[BOS, 5], &out_of_range).is_err());
        let mut too_big = cfg(4, 5, 3);
        too_big.tree = TreePolicy { branch: 2, split_mask: 0b11110 };
        assert!(speculative_generate(&d, &t, None, &[BOS, 5], &too_big).is_err());
        let mut probing = cfg(2, 5, 3);
        probing.tree = TreePolicy { branch: 2, split_mask: 0b100 };
        probing.probe_rate = 1.0;
        assert!(speculative_generate(&d, &t, None, &[BOS, 5], &probing).is_err());
    }

    // ---- seeded-corruption tests: each mutates exactly one invariant and
    // asserts debug_validate trips with a message naming that invariant ----

    #[test]
    fn lockstep_validator_trips_on_seeded_corruption() {
        let (d, t) = models();
        let c = cfg(2, 3, 5);
        let mut group = LockstepGroup::new(&d, &t, LockstepShape::of(&c));
        group.admit(AdmitItem {
            ticket: 1,
            context: vec![BOS, 5, 9],
            cfg: c.clone(),
            table: None,
        });
        group.admit(AdmitItem {
            ticket: 2,
            context: vec![BOS, 5, 9],
            cfg: c.clone(),
            table: None,
        });
        assert_eq!(group.debug_validate(), Ok(()));

        // corrupt: a retired slot handed out twice (duplicate ticket)
        let saved_ticket = group.seqs[1].ticket;
        group.seqs[1].ticket = group.seqs[0].ticket;
        let err = group.debug_validate().unwrap_err();
        assert!(err.contains("double-freed"), "got: {err}");
        group.seqs[1].ticket = saved_ticket;
        assert_eq!(group.debug_validate(), Ok(()));

        // corrupt: stale feed accounting (frontier beyond the token stream)
        let saved_fed = group.seqs[0].draft_fed;
        group.seqs[0].draft_fed = group.seqs[0].out.tokens.len() + 1;
        let err = group.debug_validate().unwrap_err();
        assert!(err.contains("feed accounting"), "got: {err}");
        group.seqs[0].draft_fed = saved_fed;
        assert_eq!(group.debug_validate(), Ok(()));

        // corrupt: a finished sequence left resident in its slot
        group.seqs[0].stop_at = 0;
        let err = group.debug_validate().unwrap_err();
        assert!(err.contains("slot liveness"), "got: {err}");
    }

    #[test]
    fn lockstep_validator_trips_on_tree_table_corruption() {
        let (d, t) = models();
        let mut c = cfg(2, 3, 5);
        c.tree = TreePolicy { branch: 2, split_mask: 0b10 };
        let mut group = LockstepGroup::new(&d, &t, LockstepShape::of(&c));
        assert!(!group.tree_parents.is_empty());
        assert_eq!(group.debug_validate(), Ok(()));

        // corrupt: back-edge in the parent table (cycle)
        let saved = group.tree_parents[1];
        group.tree_parents[1] = Some(group.tree_parents.len() - 1);
        let err = group.debug_validate().unwrap_err();
        assert!(err.contains("tree parent table"), "got: {err}");
        group.tree_parents[1] = saved;
        assert_eq!(group.debug_validate(), Ok(()));

        // corrupt: a ranked path that no longer chains through the table
        group.tree_paths[0].reverse();
        let err = group.debug_validate().unwrap_err();
        assert!(err.contains("tree path table"), "got: {err}");
    }

    // ---- prefix-store reuse & chunked-prefill admission ------------------

    fn prefix_params(cap_bytes: usize, chunk: usize) -> PrefixParams {
        PrefixParams {
            draft_store: Some(Rc::new(RefCell::new(PrefixStore::new(cap_bytes)))),
            target_store: Some(Rc::new(RefCell::new(PrefixStore::new(cap_bytes)))),
            prefill_chunk: chunk,
        }
    }

    #[test]
    fn warm_admission_attaches_snapshot_and_matches_cold_solo() {
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let ctx: &[u8] = &[BOS, 5, 9, 13, 5];
        let cfgs = [cfg(2, 5, 3), cfg(2, 5, 17)];
        let params = prefix_params(8 << 20, 0);
        let target_store = params.target_store.clone().unwrap();
        let mut hook = Scripted {
            pending: cfgs
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    let item = AdmitItem {
                        ticket: i as u64,
                        context: ctx.to_vec(),
                        cfg: c.clone(),
                        table: None,
                    };
                    (i, item)
                })
                .collect(),
            boundary: 0,
            done: Vec::new(),
        };
        let shape = LockstepShape::of(&cfgs[0]);
        speculative_generate_continuous_with(&d, &t, shape, &mut hook, params);
        assert_eq!(hook.done.len(), 2);
        hook.done.sort_by_key(|(t, _)| *t);
        let n_feed = (ctx.len() - 1) as u64;
        for (i, (_, got)) in hook.done.iter().enumerate() {
            let got = got.as_ref().unwrap();
            let want = speculative_generate(&d, &t, None, ctx, &cfgs[i]).unwrap();
            assert_eq!(got.tokens, want.tokens, "seq {i} diverged from its cold solo run");
            // first admission prefilled both models cold; the second attached
            // both snapshots copy-on-write and computed nothing
            let expect = if i == 0 { 2 * n_feed } else { 0 };
            assert_eq!(got.prefill_tokens, expect, "seq {i} prefill accounting");
        }
        let st = target_store.borrow().stats();
        assert_eq!((st.hits, st.misses), (1, 1), "one cold insert, one warm attach");
    }

    #[test]
    fn chunk_admitted_sequence_matches_cold_solo() {
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        // long context: 11 feed tokens at chunk 3 spans 4 round boundaries
        let ctx: Vec<u8> = vec![BOS, 5, 9, 13, 4, 8, 15, 6, 10, 3, 12, 7];
        let cfgs = [cfg(2, 5, 3), cfg(2, 5, 17)];
        let params = prefix_params(8 << 20, 3);
        let target_store = params.target_store.clone().unwrap();
        let mk = |ticket: u64, c: &GenConfig| AdmitItem {
            ticket,
            context: ctx.clone(),
            cfg: c.clone(),
            table: None,
        };
        let mut hook = Scripted {
            // ticket 0 at boundary 0: cold — chunk-prefills, then publishes
            // its KV; ticket 1 at boundary 4 (after the publish): a
            // copy-on-write hit
            pending: vec![(0, mk(0, &cfgs[0])), (4, mk(1, &cfgs[1]))],
            boundary: 0,
            done: Vec::new(),
        };
        let shape = LockstepShape::of(&cfgs[0]);
        speculative_generate_continuous_with(&d, &t, shape, &mut hook, params);
        assert_eq!(hook.done.len(), 2);
        hook.done.sort_by_key(|(t, _)| *t);
        let n_feed = (ctx.len() - 1) as u64;
        for (i, (_, got)) in hook.done.iter().enumerate() {
            let got = got.as_ref().unwrap();
            let want = speculative_generate(&d, &t, None, &ctx, &cfgs[i]).unwrap();
            assert_eq!(got.tokens, want.tokens, "seq {i} diverged from its one-shot solo run");
        }
        assert_eq!(hook.done[0].1.as_ref().unwrap().prefill_tokens, 2 * n_feed);
        assert_eq!(hook.done[1].1.as_ref().unwrap().prefill_tokens, 0);
        let st = target_store.borrow().stats();
        assert_eq!((st.hits, st.misses), (1, 1), "chunked publish must enable the warm hit");
    }

    #[test]
    fn lockstep_validator_trips_on_prefill_corruption() {
        let (d, t) = models();
        let c = cfg(2, 3, 5);
        let ctx: Vec<u8> = vec![BOS, 5, 9, 13, 4, 8, 15, 6, 10, 3, 12, 7];
        let mut group =
            LockstepGroup::with_params(&d, &t, LockstepShape::of(&c), prefix_params(1 << 20, 2));
        group.admit(AdmitItem { ticket: 1, context: ctx.clone(), cfg: c.clone(), table: None });
        // long context + chunking: the admission is pending, and counts active
        assert_eq!(group.pending.len(), 1);
        assert!(group.seqs.is_empty());
        assert_eq!(group.active(), 1);
        assert_eq!(group.tickets(), vec![1]);
        assert_eq!(group.debug_validate(), Ok(()));

        // corrupt: prefill frontier beyond the context's feed span
        let saved = group.pending[0].draft.fed;
        group.pending[0].draft.fed = ctx.len();
        let err = group.debug_validate().unwrap_err();
        assert!(err.contains("prefill frontier"), "got: {err}");
        group.pending[0].draft.fed = saved;
        assert_eq!(group.debug_validate(), Ok(()));

        // corrupt: one ticket admitted into the prefilling phase twice
        group.admit(AdmitItem { ticket: 1, context: ctx.clone(), cfg: c.clone(), table: None });
        let err = group.debug_validate().unwrap_err();
        assert!(err.contains("double-freed"), "got: {err}");
        group.pending.pop();
        assert_eq!(group.debug_validate(), Ok(()));

        // cancelling a still-prefilling ticket retires it through completion
        group.cancel(1, anyhow::anyhow!("deadline"));
        assert_eq!(group.active(), 0);
        assert_eq!(group.drain_completed().len(), 1);
    }
}
