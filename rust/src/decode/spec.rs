//! Speculative decoding (Algorithm 1) and SpecMER batch-and-select.
//!
//! One engine implements both: with `c == 1` (or no k-mer table) the
//! candidate-selection step degenerates and this is exactly vanilla
//! speculative decoding; with `c > 1` and a table it is SpecMER (paper
//! §3.1): draft `c` candidate blocks in one batched call, pick the block
//! with the highest Eq.-2 k-mer score, verify only that block with the
//! target, and accept/correct tokens by token-level maximal coupling.

use anyhow::Result;

use super::{GenConfig, GenOutput};
use crate::kmer::{score, KmerTable};
use crate::runtime::ModelBackend;
use crate::sampling;
use crate::tokenizer::EOS;
use crate::util::rng::Pcg64;

/// Extra knobs for speculative generation.
#[derive(Clone, Default)]
pub struct SpecOptions {
    /// Use the exported Pallas k-mer kernel instead of the Rust scorer
    /// (requires HLO runtime; for TPU-deployment parity runs).
    pub hlo_kmer: Option<std::rc::Rc<crate::runtime::Runtime>>,
}

/// Generate one sequence with speculative decoding / SpecMER.
///
/// `table` enables k-mer guidance; pass `None` for pure Algorithm 1.
pub fn speculative_generate<D: ModelBackend, T: ModelBackend>(
    draft: &D,
    target: &T,
    table: Option<&KmerTable>,
    context: &[u8],
    cfg: &GenConfig,
) -> Result<GenOutput> {
    let model_cap = target.maxlen().min(draft.maxlen());
    cfg.validate(context.len(), model_cap)?;
    let max_len = cfg.max_len.min(model_cap);
    let gamma = cfg.gamma;

    let mut rng = Pcg64::new(cfg.seed);
    let mut out = GenOutput {
        tokens: context.to_vec(),
        context_len: context.len(),
        ..Default::default()
    };

    let mut dcache = draft.prefill(context)?;
    let mut tcache = target.prefill(context)?;
    let mut draft_fed = context.len() - 1; // draft convention: all committed-but-unfed
    // target convention: exactly one unfed committed token before verify

    // KV slots are written through committed+gamma each round (draft feed +
    // block, verify block); stop while a full block still fits. Cannot
    // underflow: validate() guarantees gamma < model_cap.
    let hard_cap = model_cap - gamma;
    while out.tokens.len() < max_len.min(hard_cap) && *out.tokens.last().unwrap() != EOS {
        out.rounds += 1;
        let committed = out.tokens.len();

        // ---- 1. candidate construction (one batched draft dispatch) -----
        let feed = out.tokens[draft_fed..].to_vec();
        let u: Vec<f32> = (0..cfg.c * gamma).map(|_| rng.next_f32()).collect();
        let block = draft.generate(
            &mut dcache,
            &feed,
            draft_fed,
            cfg.c,
            gamma,
            &u,
            cfg.temp,
            cfg.top_p,
        )?;
        out.draft_calls += 1;
        draft_fed = committed;

        // ---- 2. k-mer scoring & selection ------------------------------
        let sel = match (table, cfg.c) {
            (Some(t), c) if c > 1 => {
                if cfg.kmer_boundary {
                    // context tail sized by the largest active k, not a
                    // hardcoded constant
                    let tail_len = cfg.kset.kmax() - 1;
                    let tail = &out.tokens[committed.saturating_sub(tail_len)..];
                    score::select_best_with_context(t, tail, &block.tokens, cfg.kset)
                } else {
                    score::select_best(t, &block.tokens, cfg.kset)
                }
            }
            _ => 0,
        };
        let cand = &block.tokens[sel];
        let p_dists = &block.dists[sel];

        // ---- 3. conditional probability computation (target verify) ----
        let mut vtoks = Vec::with_capacity(gamma + 1);
        vtoks.push(out.tokens[committed - 1]);
        vtoks.extend_from_slice(cand);
        let verify = target.verify(&mut tcache, &vtoks, committed - 1, cfg.temp, cfg.top_p)?;
        out.target_calls += 1;

        // ---- optional misranking probe (Fig. 3's ε) ---------------------
        if cfg.probe_rate > 0.0 && rng.next_f64() < cfg.probe_rate && cfg.c > 1 {
            let probe = probe_misranking(
                target, &mut tcache, &mut out.target_calls, &out.tokens, &block.tokens,
                &block.dists, sel, &verify.dists, cfg, &mut rng,
            )?;
            out.probes.push(probe);
        }

        // ---- 4. draft selection: token-level maximal coupling -----------
        let mut all_accepted = true;
        for i in 0..gamma {
            let x = cand[i] as usize;
            let (acc, tok) = sampling::couple(&p_dists[i], &verify.dists[i], x, &mut rng);
            out.online_nll_sum += sampling::nll_of(&verify.dists[i], tok);
            out.tokens.push(tok as u8);
            if acc {
                out.accepted += 1;
            } else {
                out.rejected += 1;
                all_accepted = false;
            }
            if !acc || tok as u8 == EOS || out.tokens.len() >= max_len {
                if !acc {
                    // corrected token replaces the draft token; stop block
                }
                all_accepted = acc && tok as u8 != EOS && out.tokens.len() < max_len;
                break;
            }
        }

        // ---- bonus token when the whole block was accepted ---------------
        if all_accepted && out.tokens.len() < max_len {
            let bonus_dist = &verify.dists[gamma];
            let tok = sampling::sample(bonus_dist, rng.next_f32());
            out.online_nll_sum += sampling::nll_of(bonus_dist, tok);
            out.tokens.push(tok as u8);
            out.bonus += 1;
        }
    }
    Ok(out)
}

/// Estimate a misranking event: did *any* candidate pass a sequence-level
/// acceptance check (the M(s) of Prop. 4.4), and did the selected one? A
/// common uniform couples the comparison across candidates.
///
/// Implementation note: `verify` only rewrites cache slots >= pos, and the
/// frontier convention makes those slots unobservable until rewritten, so
/// we may probe the non-selected candidates against the live cache and then
/// re-verify the selected block to restore its KV — no cache cloning
/// needed. Costs c extra target calls per probed round; off by default.
#[allow(clippy::too_many_arguments)]
fn probe_misranking<T: ModelBackend>(
    target: &T,
    tcache: &mut T::Cache,
    target_calls: &mut u64,
    tokens: &[u8],
    cands: &[Vec<u8>],
    dists: &[Vec<Vec<f32>>],
    sel: usize,
    sel_q: &[Vec<f32>],
    cfg: &GenConfig,
    rng: &mut Pcg64,
) -> Result<(bool, bool)> {
    let committed = tokens.len();
    let eta = rng.next_f64();
    let seq_ratio = |p: &[Vec<f32>], q: &[Vec<f32>], cand: &[u8]| -> f64 {
        let mut lr = 0.0f64;
        for i in 0..cand.len() {
            let x = cand[i] as usize;
            lr += (q[i][x].max(1e-12) as f64).ln() - (p[i][x].max(1e-12) as f64).ln();
        }
        lr.exp().min(1.0)
    };
    let mut any = false;
    let mut sel_ok = false;
    for (i, cand) in cands.iter().enumerate() {
        let r = if i == sel {
            seq_ratio(&dists[i], sel_q, cand)
        } else {
            let mut vtoks = vec![tokens[committed - 1]];
            vtoks.extend_from_slice(cand);
            let vb = target.verify(tcache, &vtoks, committed - 1, cfg.temp, cfg.top_p)?;
            *target_calls += 1;
            seq_ratio(&dists[i], &vb.dists, cand)
        };
        let ok = eta <= r;
        any |= ok;
        if i == sel {
            sel_ok = ok;
        }
    }
    // restore the selected block's KV in the live cache
    let mut vtoks = vec![tokens[committed - 1]];
    vtoks.extend_from_slice(&cands[sel]);
    let _ = target.verify(tcache, &vtoks, committed - 1, cfg.temp, cfg.top_p)?;
    *target_calls += 1;
    Ok((any, sel_ok))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmer::KmerSet;
    use crate::msa::simulate::generate_family;
    use crate::runtime::cpu_ref::CpuModel;
    use crate::tokenizer::BOS;

    fn models() -> (CpuModel, CpuModel) {
        // identical seeds -> draft == target (alpha should be ~1)
        (
            CpuModel::synthetic(2, 16, 2, 64, 7),
            CpuModel::synthetic(2, 16, 2, 64, 7),
        )
    }

    fn cfg(c: usize, gamma: usize, seed: u64) -> GenConfig {
        GenConfig {
            c,
            gamma,
            max_len: 48,
            seed,
            kset: KmerSet::new(true, true, true),
            ..Default::default()
        }
    }

    #[test]
    fn identical_models_accept_everything() {
        let (d, t) = models();
        let out = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(1, 5, 3)).unwrap();
        assert_eq!(out.rejected, 0, "p == q must always accept");
        assert!(out.acceptance_ratio() > 0.999);
        assert!(out.tokens.len() > 3);
    }

    #[test]
    fn different_models_reject_sometimes() {
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let mut total_rej = 0;
        for seed in 0..5 {
            let out = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(1, 5, seed)).unwrap();
            total_rej += out.rejected;
        }
        assert!(total_rej > 0, "independent models should disagree sometimes");
    }

    #[test]
    fn specmer_runs_with_table() {
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let (d, t) = models();
        let out =
            speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &cfg(3, 5, 11)).unwrap();
        assert!(out.tokens.len() > 3);
        assert!(out.rounds > 0);
        assert_eq!(out.draft_calls, out.rounds);
        assert_eq!(out.target_calls, out.rounds);
    }

    #[test]
    fn c1_with_table_equals_plain_speculative() {
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let (d, t) = models();
        let a = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &cfg(1, 5, 13)).unwrap();
        let b = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(1, 5, 13)).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn deterministic_in_seed() {
        let (d, t) = models();
        let a = speculative_generate(&d, &t, None, &[BOS, 5], &cfg(2, 5, 21)).unwrap();
        let b = speculative_generate(&d, &t, None, &[BOS, 5], &cfg(2, 5, 21)).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.accepted, b.accepted);
    }

    #[test]
    fn respects_max_len() {
        let (d, t) = models();
        let mut c = cfg(2, 10, 2);
        c.max_len = 20;
        let out = speculative_generate(&d, &t, None, &[BOS, 5], &c).unwrap();
        assert!(out.tokens.len() <= 20);
    }

    #[test]
    fn token_accounting_consistent() {
        let (d, t) = models();
        for seed in 0..4 {
            let out = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(2, 5, seed)).unwrap();
            // every committed token past context is accepted, rejected(corrected), or bonus
            let committed = (out.tokens.len() - out.context_len) as u64;
            assert_eq!(
                committed,
                out.accepted + out.rejected + out.bonus,
                "accounting: {out:?}"
            );
        }
    }

    /// The lossless-ness property of speculative decoding: with identical
    /// draft and target and the same seed structure, outputs are target-
    /// distributed. We verify a weaker invariant that every committed token
    /// lies in the target's nucleus at its position.
    #[test]
    fn committed_tokens_lie_in_target_nucleus() {
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 9);
        let out = speculative_generate(&d, &t, None, &[BOS, 5, 9], &cfg(2, 5, 33)).unwrap();
        let logits = t.forward_logits(&out.tokens);
        for i in out.context_len..out.tokens.len() {
            let dist = sampling::adjust_dist(&logits[i - 1], 1.0, 0.95);
            assert!(
                dist[out.tokens[i] as usize] > 0.0,
                "token at {i} outside target nucleus"
            );
        }
    }

    #[test]
    fn invalid_configs_error_instead_of_panicking() {
        let (d, t) = models(); // maxlen 64
        // gamma >= model maxlen used to underflow the hard cap and panic
        let mut big = cfg(1, 64, 3);
        big.max_len = 200;
        assert!(speculative_generate(&d, &t, None, &[BOS, 5, 9], &big).is_err());
        let mut huge = cfg(1, 100, 3);
        huge.max_len = 200;
        assert!(speculative_generate(&d, &t, None, &[BOS, 5, 9], &huge).is_err());
        // degenerate c / gamma
        assert!(speculative_generate(&d, &t, None, &[BOS, 5], &cfg(0, 5, 3)).is_err());
        assert!(speculative_generate(&d, &t, None, &[BOS, 5], &cfg(2, 0, 3)).is_err());
        // empty / oversized context
        assert!(speculative_generate(&d, &t, None, &[], &cfg(2, 5, 3)).is_err());
        let mut small = cfg(2, 5, 3);
        small.max_len = 3;
        assert!(speculative_generate(&d, &t, None, &[BOS, 5, 9], &small).is_err());
    }

    #[test]
    fn boundary_selection_derives_tail_from_kset() {
        // with only k=3 active the boundary tail is 2 tokens; selection must
        // agree with scoring every candidate against that exact tail
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let (d, t) = models();
        let mut c = cfg(3, 5, 19);
        c.kset = KmerSet::new(false, true, false);
        c.kmer_boundary = true;
        let out = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &c).unwrap();
        assert!(out.tokens.len() > 3);
        assert!(out.rounds > 0);
    }

    #[test]
    fn probe_records_events() {
        let (_prof, msa) = generate_family("T", 40, 30, 5);
        let table = KmerTable::build(&msa);
        let d = CpuModel::synthetic(2, 16, 2, 64, 7);
        let t = CpuModel::synthetic(2, 16, 2, 64, 8);
        let mut c = cfg(3, 5, 17);
        c.probe_rate = 1.0;
        let out = speculative_generate(&d, &t, Some(&table), &[BOS, 5, 9], &c).unwrap();
        assert!(!out.probes.is_empty());
    }
}
