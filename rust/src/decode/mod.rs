//! Decoding engines: target-only autoregressive baseline, vanilla
//! speculative decoding (Algorithm 1), and SpecMER batch-and-select.

pub mod spec;
pub mod target_only;

pub use spec::{
    speculative_generate, speculative_generate_batch, speculative_generate_continuous,
    AdmissionHook, AdmitItem, LockstepShape, SpecBatchItem, SpecOptions,
};
pub use target_only::target_only_generate;

use crate::kmer::KmerSet;

/// One generation request's decoding configuration.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// Draft block length γ ∈ {5, 10, 15}.
    pub gamma: usize,
    /// Number of batch-drafted candidates c (1 = vanilla speculative).
    pub c: usize,
    pub temp: f32,
    pub top_p: f32,
    /// K-mer guidance set; ignored when c == 1 or no table is given.
    pub kset: KmerSet,
    /// Maximum total sequence length (BOS + residues + EOS), capped at the
    /// model maxlen by the engines.
    pub max_len: usize,
    pub seed: u64,
    /// Score candidate k-mers across the context/block boundary (extension,
    /// off = paper-faithful).
    pub kmer_boundary: bool,
    /// Probability of running a misranking probe on a round (Fig. 3's ε).
    pub probe_rate: f64,
    /// Target-only baseline chunk: 0 = largest exported scan-fused chunk;
    /// 1 = paper-faithful stepwise AR (one dispatch per token).
    pub ar_chunk: usize,
}

impl GenConfig {
    /// Validate (gamma, c, max_len) against a context and the backend pair's
    /// capability (`model_cap` = min of the models' maxlens) before any
    /// cache is touched. Catches configurations that previously blew up
    /// deep inside the engines — most notably `gamma >= model_cap`, which
    /// underflowed the decode hard cap and panicked.
    pub fn validate(&self, context_len: usize, model_cap: usize) -> anyhow::Result<()> {
        if self.c < 1 {
            anyhow::bail!("GenConfig: c must be >= 1 (got {})", self.c);
        }
        if self.gamma < 1 {
            anyhow::bail!("GenConfig: gamma must be >= 1 (got {})", self.gamma);
        }
        if self.gamma >= model_cap {
            anyhow::bail!(
                "GenConfig: gamma {} leaves no room to draft a block under model maxlen {model_cap}",
                self.gamma
            );
        }
        if context_len == 0 {
            anyhow::bail!("GenConfig: context must be non-empty");
        }
        let effective = self.max_len.min(model_cap);
        if context_len >= effective {
            anyhow::bail!(
                "GenConfig: context length {context_len} >= effective max_len {effective} \
                 (max_len {} capped by model maxlen {model_cap})",
                self.max_len
            );
        }
        Ok(())
    }
}

impl Default for GenConfig {
    fn default() -> GenConfig {
        GenConfig {
            gamma: 5,
            c: 3,
            temp: 1.0,
            top_p: 0.95,
            kset: KmerSet::new(true, true, false),
            max_len: 192,
            seed: 0,
            kmer_boundary: false,
            probe_rate: 0.0,
            ar_chunk: 0,
        }
    }
}

/// Outcome of one generated sequence plus decoding statistics.
#[derive(Clone, Debug, Default)]
pub struct GenOutput {
    /// Full token sequence including the context (BOS..., possibly EOS).
    pub tokens: Vec<u8>,
    /// Context length that was supplied (tokens[..context_len] is the prompt).
    pub context_len: usize,
    pub accepted: u64,
    pub rejected: u64,
    /// Bonus tokens sampled when a whole block was accepted.
    pub bonus: u64,
    pub rounds: u64,
    /// Online NLL of each committed token under the *adjusted* target dist
    /// (diagnostic; the paper's reported NLL is re-scored by eval::nll).
    pub online_nll_sum: f64,
    /// Misranking probe outcomes: (E occurred, A* accepted) pairs.
    pub probes: Vec<(bool, bool)>,
    /// Target-model forward passes (≈ cost driver).
    pub target_calls: u64,
    pub draft_calls: u64,
}

impl GenOutput {
    /// Acceptance ratio α̂ = accepted / (accepted + rejected)   (Eq. 6).
    pub fn acceptance_ratio(&self) -> f64 {
        let d = (self.accepted + self.rejected) as f64;
        if d == 0.0 {
            0.0
        } else {
            self.accepted as f64 / d
        }
    }

    /// Generated residues (excluding context and specials).
    pub fn generated_residues(&self) -> usize {
        self.tokens[self.context_len..]
            .iter()
            .filter(|&&t| crate::tokenizer::is_residue(t))
            .count()
    }

    /// All committed tokens past the context.
    pub fn new_tokens(&self) -> usize {
        self.tokens.len() - self.context_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acceptance_ratio_edge_cases() {
        let mut o = GenOutput::default();
        assert_eq!(o.acceptance_ratio(), 0.0);
        o.accepted = 9;
        o.rejected = 1;
        assert!((o.acceptance_ratio() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn generated_residue_count_skips_specials() {
        let o = GenOutput {
            tokens: vec![1, 5, 6, 7, 2],
            context_len: 2,
            ..Default::default()
        };
        assert_eq!(o.generated_residues(), 2); // 6,7 (2 is EOS)
        assert_eq!(o.new_tokens(), 3);
    }
}
