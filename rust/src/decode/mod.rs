//! Decoding engines: target-only autoregressive baseline, vanilla
//! speculative decoding (Algorithm 1), and SpecMER batch-and-select.

pub mod spec;
pub mod target_only;

pub use spec::{
    speculative_generate, speculative_generate_batch, speculative_generate_continuous,
    speculative_generate_continuous_with, AdmissionHook, AdmitItem, LockstepShape, PrefixParams,
    SpecBatchItem, SpecOptions,
};
pub use target_only::target_only_generate;

use crate::kmer::KmerSet;

/// Shape of the shared-prefix candidate tree a speculation round drafts.
///
/// The default (`split_mask == 0`) is *off*: rounds draft `c` independent
/// flat chains exactly as before, through the flat code path. With a
/// non-zero mask, rounds draft a forest of `c` trees instead: bit `d`
/// (1-based, `1 <= d < gamma`) set means every frontier node at depth
/// `d - 1` spawns `branch` children at depth `d` (unset bits extend each
/// node with a single child). `branch == 1` with a non-zero mask yields
/// chain-shaped trees driven through the *tree* code path — the degenerate
/// configuration the bitwise-equivalence tests pin against the flat oracle.
///
/// Node ids are assigned in DFS path order (a root's whole subtree before
/// the next root), so for chain-shaped trees node `c_i * gamma + g_i` is
/// flat candidate `c_i`'s token `g_i` — which is what lets the round's
/// per-node uniforms line up with the flat driver's `u[ci*gamma + gi]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub struct TreePolicy {
    /// Children per frontier node at split depths (>= 2 to actually branch).
    pub branch: u8,
    /// Bit `d` set ⇒ split when extending the frontier to depth `d`.
    pub split_mask: u16,
}

impl TreePolicy {
    /// Tree drafting enabled? Off ⇒ the flat chain path runs verbatim.
    pub fn enabled(&self) -> bool {
        self.split_mask != 0
    }

    /// Children each depth-`d - 1` frontier node spawns at depth `d`.
    pub fn branch_at(&self, depth: usize) -> usize {
        if depth < 16 && (self.split_mask >> depth) & 1 == 1 {
            (self.branch as usize).max(1)
        } else {
            1
        }
    }

    /// Parent-pointer table of the round's candidate forest in DFS path
    /// order: `c` roots, each grown to depth `gamma - 1`; `parents[i]`
    /// is `None` for roots and always `< i` otherwise.
    pub fn build_parents(&self, c: usize, gamma: usize) -> Vec<Option<usize>> {
        fn grow(
            parents: &mut Vec<Option<usize>>,
            pol: &TreePolicy,
            parent: Option<usize>,
            depth: usize,
            gamma: usize,
        ) {
            let id = parents.len();
            parents.push(parent);
            if depth + 1 < gamma {
                for _ in 0..pol.branch_at(depth + 1) {
                    grow(parents, pol, Some(id), depth + 1, gamma);
                }
            }
        }
        let mut parents = Vec::new();
        for _ in 0..c {
            grow(&mut parents, self, None, 0, gamma);
        }
        parents
    }

    /// Total nodes a round's forest drafts (`c * gamma` when disabled).
    pub fn node_count(&self, c: usize, gamma: usize) -> usize {
        let mut frontier = 1usize;
        let mut per_root = 0usize;
        for d in 0..gamma {
            if d > 0 {
                frontier *= self.branch_at(d);
            }
            per_root += frontier;
        }
        c * per_root
    }

    /// Root-to-leaf paths (= candidate blocks the k-mer scorer ranks).
    pub fn leaf_count(&self, c: usize, gamma: usize) -> usize {
        let mut frontier = 1usize;
        for d in 1..gamma {
            frontier *= self.branch_at(d);
        }
        c * frontier
    }
}

/// One generation request's decoding configuration.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// Draft block length γ ∈ {5, 10, 15}.
    pub gamma: usize,
    /// Number of batch-drafted candidates c (1 = vanilla speculative).
    pub c: usize,
    pub temp: f32,
    pub top_p: f32,
    /// K-mer guidance set; ignored when c == 1 or no table is given.
    pub kset: KmerSet,
    /// Maximum total sequence length (BOS + residues + EOS), capped at the
    /// model maxlen by the engines.
    pub max_len: usize,
    pub seed: u64,
    /// Score candidate k-mers across the context/block boundary (extension,
    /// off = paper-faithful).
    pub kmer_boundary: bool,
    /// Probability of running a misranking probe on a round (Fig. 3's ε).
    pub probe_rate: f64,
    /// Target-only baseline chunk: 0 = largest exported scan-fused chunk;
    /// 1 = paper-faithful stepwise AR (one dispatch per token).
    pub ar_chunk: usize,
    /// Shared-prefix candidate-tree drafting policy (default: off = flat
    /// chains). See [`TreePolicy`].
    pub tree: TreePolicy,
}

impl GenConfig {
    /// Validate (gamma, c, max_len) against a context and the backend pair's
    /// capability (`model_cap` = min of the models' maxlens) before any
    /// cache is touched. Catches configurations that previously blew up
    /// deep inside the engines — most notably `gamma >= model_cap`, which
    /// underflowed the decode hard cap and panicked.
    pub fn validate(&self, context_len: usize, model_cap: usize) -> anyhow::Result<()> {
        if self.c < 1 {
            anyhow::bail!("GenConfig: c must be >= 1 (got {})", self.c);
        }
        if self.gamma < 1 {
            anyhow::bail!("GenConfig: gamma must be >= 1 (got {})", self.gamma);
        }
        if self.gamma >= model_cap {
            anyhow::bail!(
                "GenConfig: gamma {} leaves no room to draft a block under model maxlen {model_cap}",
                self.gamma
            );
        }
        if context_len == 0 {
            anyhow::bail!("GenConfig: context must be non-empty");
        }
        let effective = self.max_len.min(model_cap);
        if context_len >= effective {
            anyhow::bail!(
                "GenConfig: context length {context_len} >= effective max_len {effective} \
                 (max_len {} capped by model maxlen {model_cap})",
                self.max_len
            );
        }
        if self.tree.enabled() {
            if self.tree.branch == 0 {
                anyhow::bail!("GenConfig: tree branch must be >= 1 when splits are set");
            }
            // valid split bits are 1..gamma (roots are always the c candidates)
            let valid = if self.gamma >= 16 { u16::MAX } else { (1u16 << self.gamma) - 2 };
            if self.tree.split_mask & !valid != 0 {
                anyhow::bail!(
                    "GenConfig: tree split_mask {:#x} sets bits outside 1..gamma={}",
                    self.tree.split_mask,
                    self.gamma
                );
            }
            let nodes = self.tree.node_count(self.c, self.gamma);
            if nodes > 64 {
                anyhow::bail!(
                    "GenConfig: tree of {nodes} nodes exceeds the per-round budget of 64 \
                     (c={}, gamma={}, branch={}, split_mask={:#x})",
                    self.c,
                    self.gamma,
                    self.tree.branch,
                    self.tree.split_mask
                );
            }
            if self.probe_rate > 0.0 {
                anyhow::bail!("GenConfig: misranking probes are not supported in tree mode");
            }
        }
        Ok(())
    }
}

impl Default for GenConfig {
    fn default() -> GenConfig {
        GenConfig {
            gamma: 5,
            c: 3,
            temp: 1.0,
            top_p: 0.95,
            kset: KmerSet::new(true, true, false),
            max_len: 192,
            seed: 0,
            kmer_boundary: false,
            probe_rate: 0.0,
            ar_chunk: 0,
            tree: TreePolicy::default(),
        }
    }
}

/// Outcome of one generated sequence plus decoding statistics.
#[derive(Clone, Debug, Default)]
pub struct GenOutput {
    /// Full token sequence including the context (BOS..., possibly EOS).
    pub tokens: Vec<u8>,
    /// Context length that was supplied (tokens[..context_len] is the prompt).
    pub context_len: usize,
    pub accepted: u64,
    pub rejected: u64,
    /// Bonus tokens sampled when a whole block was accepted.
    pub bonus: u64,
    pub rounds: u64,
    /// Online NLL of each committed token under the *adjusted* target dist
    /// (diagnostic; the paper's reported NLL is re-scored by eval::nll).
    pub online_nll_sum: f64,
    /// Misranking probe outcomes: (E occurred, A* accepted) pairs.
    pub probes: Vec<(bool, bool)>,
    /// Target-model forward passes (≈ cost driver).
    pub target_calls: u64,
    pub draft_calls: u64,
    /// Candidate tokens drafted across all rounds (`c * gamma` per flat
    /// round; the forest's node count per tree round). Feeds the
    /// `/metrics` tree_nodes_per_round gauge.
    pub tree_nodes: u64,
    /// Context-prefill positions actually *computed* at admission, summed
    /// over both models (cold one-shot = `2 * (context_len - 1)`; a
    /// prefix-store copy-on-write hit contributes 0 for its side). Feeds
    /// the `/metrics` admission_prefill_tokens_avg gauge.
    pub prefill_tokens: u64,
}

impl GenOutput {
    /// Acceptance ratio α̂ = accepted / (accepted + rejected)   (Eq. 6).
    pub fn acceptance_ratio(&self) -> f64 {
        let d = (self.accepted + self.rejected) as f64;
        if d == 0.0 {
            0.0
        } else {
            self.accepted as f64 / d
        }
    }

    /// Generated residues (excluding context and specials).
    pub fn generated_residues(&self) -> usize {
        self.tokens[self.context_len..]
            .iter()
            .filter(|&&t| crate::tokenizer::is_residue(t))
            .count()
    }

    /// All committed tokens past the context.
    pub fn new_tokens(&self) -> usize {
        self.tokens.len() - self.context_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acceptance_ratio_edge_cases() {
        let mut o = GenOutput::default();
        assert_eq!(o.acceptance_ratio(), 0.0);
        o.accepted = 9;
        o.rejected = 1;
        assert!((o.acceptance_ratio() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn tree_policy_shapes() {
        let off = TreePolicy::default();
        assert!(!off.enabled());
        assert_eq!(off.node_count(3, 5), 15);
        assert_eq!(off.leaf_count(3, 5), 3);
        // chain-shaped through the tree path: branch 1, any split bit
        let chain = TreePolicy { branch: 1, split_mask: 0b10 };
        assert!(chain.enabled());
        let parents = chain.build_parents(3, 4);
        assert_eq!(parents.len(), 12);
        // DFS path order: candidate ci owns ids ci*gamma .. (ci+1)*gamma
        for ci in 0..3 {
            assert_eq!(parents[ci * 4], None);
            for gi in 1..4 {
                assert_eq!(parents[ci * 4 + gi], Some(ci * 4 + gi - 1));
            }
        }
        // a real split: 2 roots, 2-way branch into depth 2
        let t = TreePolicy { branch: 2, split_mask: 0b100 };
        assert_eq!(t.node_count(2, 4), 2 * (1 + 1 + 2 + 2));
        assert_eq!(t.leaf_count(2, 4), 4);
        assert_eq!(t.build_parents(2, 4).len(), t.node_count(2, 4));
    }

    #[test]
    fn tree_policy_validation() {
        let ctx = 4;
        let cap = 64;
        let mut cfg =
            GenConfig { tree: TreePolicy { branch: 2, split_mask: 0b10 }, ..Default::default() };
        assert!(cfg.validate(ctx, cap).is_ok());
        // split bit at/above gamma is rejected
        cfg.tree.split_mask = 1 << cfg.gamma;
        assert!(cfg.validate(ctx, cap).is_err());
        // branch 0 with splits set is rejected
        cfg.tree = TreePolicy { branch: 0, split_mask: 0b10 };
        assert!(cfg.validate(ctx, cap).is_err());
        // node budget: 8 * (1+2+4+8+16) = 248 >> 64
        cfg.c = 8;
        cfg.tree = TreePolicy { branch: 2, split_mask: 0b11110 };
        assert!(cfg.validate(ctx, cap).is_err());
        // probes are flat-only
        cfg = GenConfig {
            tree: TreePolicy { branch: 2, split_mask: 0b10 },
            probe_rate: 0.5,
            ..Default::default()
        };
        assert!(cfg.validate(ctx, cap).is_err());
    }

    #[test]
    fn generated_residue_count_skips_specials() {
        let o = GenOutput {
            tokens: vec![1, 5, 6, 7, 2],
            context_len: 2,
            ..Default::default()
        };
        assert_eq!(o.generated_residues(), 2); // 6,7 (2 is EOS)
        assert_eq!(o.new_tokens(), 3);
    }
}
