//! System configuration: artifact locations, model pair, serving knobs.

use crate::decode::GenConfig;
use crate::kmer::KmerSet;
use crate::util::cli::Args;
use std::path::PathBuf;

/// Which decoding method a request uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Plain nucleus sampling from the target model.
    TargetOnly,
    /// Plain nucleus sampling from the draft model (Table 5's "Draft" row).
    DraftOnly,
    /// Vanilla speculative decoding (c = 1).
    Speculative,
    /// SpecMER with c candidates and k-mer guidance.
    SpecMer,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "target" | "target-only" | "ar" => Some(Method::TargetOnly),
            "draft" | "draft-only" => Some(Method::DraftOnly),
            "spec" | "speculative" | "specdec" => Some(Method::Speculative),
            "specmer" => Some(Method::SpecMer),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Method::TargetOnly => "target",
            Method::DraftOnly => "draft",
            Method::Speculative => "speculative",
            Method::SpecMer => "specmer",
        }
    }
}

/// Global configuration (CLI > defaults).
#[derive(Clone, Debug)]
pub struct Config {
    pub artifacts: PathBuf,
    pub results_dir: PathBuf,
    pub draft_model: String,
    pub target_model: String,
    /// Use the pure-Rust reference backend instead of PJRT.
    pub cpu_ref: bool,
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait_ms: u64,
    /// Per-worker queue bound: submissions past it are shed (429).
    pub queue_cap: usize,
    /// Router-level outstanding-request limit; 0 = unlimited.
    pub max_inflight: usize,
    /// Default per-request deadline applied by the HTTP server when the
    /// client sends no `timeout_ms`; 0 = no deadline.
    pub timeout_ms: u64,
    /// Per-worker shared-prefix KV cache budget in MiB, split between the
    /// draft and target stores; 0 disables the prefix cache.
    pub prefix_cache_mb: usize,
    /// Chunked-admission prefill slice in context tokens: a cold context
    /// longer than this is prefilled across lockstep round boundaries
    /// instead of in one stalling forward; 0 = one-shot prefill.
    pub prefill_chunk: usize,
    pub port: u16,
    pub gen: GenConfig,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            artifacts: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
            draft_model: "draft".into(),
            target_model: "target".into(),
            cpu_ref: false,
            workers: 1,
            max_batch: 8,
            max_wait_ms: 5,
            queue_cap: 256,
            max_inflight: 0,
            timeout_ms: 0,
            prefix_cache_mb: 32,
            prefill_chunk: 0,
            port: 7878,
            gen: GenConfig::default(),
        }
    }
}

impl Config {
    /// Apply CLI overrides on top of defaults.
    pub fn from_args(args: &Args) -> anyhow::Result<Config> {
        let mut c = Config::default();
        if let Some(a) = args.get("artifacts") {
            c.artifacts = PathBuf::from(a);
        } else if let Ok(env) = std::env::var("SPECMER_ARTIFACTS") {
            c.artifacts = PathBuf::from(env);
        }
        if let Some(r) = args.get("results") {
            c.results_dir = PathBuf::from(r);
        }
        c.draft_model = args.str_or("draft-model", &c.draft_model);
        c.target_model = args.str_or("target-model", &c.target_model);
        c.cpu_ref = args.flag("cpu-ref");
        c.workers = args.usize_or("workers", c.workers)?;
        c.max_batch = args.usize_or("max-batch", c.max_batch)?;
        c.max_wait_ms = args.u64_or("max-wait-ms", c.max_wait_ms)?;
        c.queue_cap = args.usize_or("queue-cap", c.queue_cap)?;
        c.max_inflight = args.usize_or("max-inflight", c.max_inflight)?;
        c.timeout_ms = args.u64_or("timeout-ms", c.timeout_ms)?;
        c.prefix_cache_mb = args.usize_or("prefix-cache-mb", c.prefix_cache_mb)?;
        c.prefill_chunk = args.usize_or("prefill-chunk", c.prefill_chunk)?;
        c.port = args.usize_or("port", c.port as usize)? as u16;
        c.gen.gamma = args.usize_or("gamma", c.gen.gamma)?;
        c.gen.c = args.usize_or("c", c.gen.c)?;
        c.gen.temp = args.f64_or("temp", c.gen.temp as f64)? as f32;
        c.gen.top_p = args.f64_or("top-p", c.gen.top_p as f64)? as f32;
        c.gen.seed = args.u64_or("seed", c.gen.seed)?;
        c.gen.kmer_boundary = args.flag("boundary");
        if let Some(k) = args.get("k") {
            c.gen.kset = KmerSet::parse(k)
                .ok_or_else(|| anyhow::anyhow!("bad --k '{k}' (expected e.g. 1,3,5)"))?;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Config {
        Config::from_args(&Args::parse(s.split_whitespace().map(String::from)).unwrap()).unwrap()
    }

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert_eq!(c.gen.top_p, 0.95);
        assert_eq!(c.gen.c, 3);
    }

    #[test]
    fn cli_overrides() {
        let c = parse("--gamma 10 --c 5 --temp 0.7 --k 1,3 --workers 2 --cpu-ref");
        assert_eq!(c.gen.gamma, 10);
        assert_eq!(c.gen.c, 5);
        assert!((c.gen.temp - 0.7).abs() < 1e-6);
        assert!(c.gen.kset.k1 && c.gen.kset.k3 && !c.gen.kset.k5);
        assert_eq!(c.workers, 2);
        assert!(c.cpu_ref);
    }

    #[test]
    fn serving_hardening_knobs() {
        let c = parse("--queue-cap 32 --max-inflight 64 --timeout-ms 1500");
        assert_eq!(c.queue_cap, 32);
        assert_eq!(c.max_inflight, 64);
        assert_eq!(c.timeout_ms, 1500);
        let d = Config::default();
        assert_eq!(d.queue_cap, 256);
        assert_eq!(d.max_inflight, 0, "unlimited by default");
        assert_eq!(d.timeout_ms, 0, "no default deadline");
    }

    #[test]
    fn prefix_cache_knobs() {
        let c = parse("--prefix-cache-mb 128 --prefill-chunk 64");
        assert_eq!(c.prefix_cache_mb, 128);
        assert_eq!(c.prefill_chunk, 64);
        let d = Config::default();
        assert_eq!(d.prefix_cache_mb, 32, "prefix cache on by default");
        assert_eq!(d.prefill_chunk, 0, "one-shot prefill by default");
    }

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("SpecMER"), Some(Method::SpecMer));
        assert_eq!(Method::parse("target"), Some(Method::TargetOnly));
        assert_eq!(Method::parse("spec"), Some(Method::Speculative));
        assert_eq!(Method::parse("???"), None);
    }

    #[test]
    fn bad_k_rejected() {
        let args = Args::parse("--k 2,7".split_whitespace().map(String::from)).unwrap();
        assert!(Config::from_args(&args).is_err());
    }
}
