//! # SpecMER — k-mer guided speculative decoding for protein generation
//!
//! Reproduction of "SpecMER: Fast Protein Generation with K-mer Guided
//! Speculative Decoding" (CS.LG 2025) as a three-layer Rust + JAX + Pallas
//! serving system. See DESIGN.md for the architecture and EXPERIMENTS.md
//! for paper-vs-measured results.
//!
//! Layering:
//!   * L3 (this crate): request router, dynamic batcher, speculative
//!     scheduler, k-mer guidance, metrics, HTTP server, experiment harness.
//!   * L2/L1 (python/compile, build-time only): JAX transformer + Pallas
//!     kernels, AOT-lowered to HLO text consumed by [`runtime`].
//!
//! ## Unsafe code and determinism policy
//!
//! Every `unsafe` site carries an adjacent `// SAFETY:` justification, and
//! the kernel modules obey a bitwise-determinism contract (no FMA outside the
//! opt-in fast tier, no wall clocks or hash-ordered iteration in kernel or
//! decode code). The policy is written out in `docs/unsafe-policy.md` and
//! mechanically enforced by the `specmer-lint` workspace member
//! (`make lint-specmer`).

#![deny(unsafe_op_in_unsafe_fn)]

pub mod config;
pub mod coordinator;
pub mod decode;
pub mod eval;
pub mod experiments;
pub mod kmer;
pub mod params;
pub mod runtime;
pub mod msa;
pub mod sampling;
pub mod server;
pub mod theory;
pub mod tokenizer;
pub mod util;
