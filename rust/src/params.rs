//! Model parameter loading: `manifest.json` + `params_<model>.bin`.
//!
//! aot.py serializes each checkpoint as one flat little-endian f32 vector;
//! the manifest records the model hyperparameters, per-tensor offsets and
//! the KV-cache shape. The flat vector is argument 0 of every exported HLO
//! program, so Rust never needs to understand the tensor layout — but the
//! pure-Rust reference model (runtime::cpu_ref) does, via [`ModelParams::tensor`].

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug)]
pub enum ParamsError {
    Io(std::io::Error),
    Manifest(String),
    SizeMismatch { model: String, got: usize, want: usize },
    UnknownTensor(String),
}

impl std::fmt::Display for ParamsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParamsError::Io(e) => write!(f, "io: {e}"),
            ParamsError::Manifest(m) => write!(f, "manifest: {m}"),
            ParamsError::SizeMismatch { model, got, want } => {
                write!(f, "params_{model}.bin has {got} floats, manifest says {want}")
            }
            ParamsError::UnknownTensor(t) => write!(f, "unknown tensor {t}"),
        }
    }
}

impl std::error::Error for ParamsError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ParamsError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ParamsError {
    fn from(e: std::io::Error) -> ParamsError {
        ParamsError::Io(e)
    }
}

/// Hyperparameters of one exported checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelDims {
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub n_params: usize,
    /// [layer, k/v, head, position, d_head]
    pub cache_shape: [usize; 5],
}

impl ModelDims {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }
    pub fn maxlen(&self) -> usize {
        self.cache_shape[3]
    }
    pub fn cache_len(&self) -> usize {
        self.cache_shape.iter().product()
    }
}

#[derive(Clone, Debug)]
struct TensorSpec {
    shape: Vec<usize>,
    offset: usize,
}

/// One checkpoint: dims + flat parameter vector + tensor directory.
pub struct ModelParams {
    pub name: String,
    pub dims: ModelDims,
    pub flat: Vec<f32>,
    tensors: BTreeMap<String, TensorSpec>,
}

impl ModelParams {
    /// View of one named tensor (row-major) with its shape.
    pub fn tensor(&self, name: &str) -> Result<(&[f32], &[usize]), ParamsError> {
        let spec = self
            .tensors
            .get(name)
            .ok_or_else(|| ParamsError::UnknownTensor(name.to_string()))?;
        let n: usize = spec.shape.iter().product();
        Ok((&self.flat[spec.offset..spec.offset + n], &spec.shape))
    }
}

/// Everything manifest.json describes.
pub struct Manifest {
    pub maxlen: usize,
    pub vocab: usize,
    pub models: BTreeMap<String, ModelDims>,
}

/// Prepacked weight panels for the CPU runtime's column-vectorized kernels,
/// built **once at model load** (`CpuModel::from_params` / `synthetic`).
///
/// The weight-tied logits head multiplies hidden states against the token
/// embedding, which is stored row-major `[V, D]` — the wrong orientation
/// for a kernel that vectorizes across output columns, which is why the
/// seed path ran a per-vocab-entry transposed dot product (`matmul_nt`).
/// Packing transposes the embedding once into a row-major `[D, V_pad]`
/// panel (`V_pad` = vocab rounded up to `lanes`, zero-filled), so the head
/// becomes a plain `[rows, D] × [D, V]` `matmul_dense` call. Per output
/// element the accumulation order over `D` is unchanged, so the packed
/// head is bitwise-identical to the seed head. The CPU runtime packs at
/// `lanes = 1` (exact width — its kernels handle trailing columns with a
/// scalar tail); alignment padding is for panels whose consumer wants
/// full-width vector tiles only.
///
/// Projection weights are exported row-major `[in, out]` — already the
/// column-lane orientation — so only the tied head needs a packed panel.
pub struct PackedWeights {
    /// Transposed tied embedding, row-major `[D, V_pad]`.
    pub emb_t: Vec<f32>,
    /// Columns in the packed panel (`vocab` rounded up to `lanes`).
    pub v_pad: usize,
    /// Real vocab width (columns `vocab..v_pad` are zero padding).
    pub vocab: usize,
}

impl PackedWeights {
    /// Transpose the first `vocab` rows of a `[V, D]` embedding into a
    /// `[D, V_pad]` panel aligned to `lanes` columns.
    pub fn pack(tok_emb: &[f32], vocab: usize, d: usize, lanes: usize) -> PackedWeights {
        let lanes = lanes.max(1);
        let v_pad = (vocab + lanes - 1) / lanes * lanes;
        let mut emb_t = vec![0.0f32; d * v_pad];
        for t in 0..vocab {
            for i in 0..d {
                emb_t[i * v_pad + t] = tok_emb[t * d + i];
            }
        }
        PackedWeights { emb_t, v_pad, vocab }
    }
}

fn req_usize(v: &Json, key: &str) -> Result<usize, ParamsError> {
    v.get(key)
        .and_then(|x| x.as_usize())
        .ok_or_else(|| ParamsError::Manifest(format!("missing {key}")))
}

pub fn load_manifest(dir: &Path) -> Result<Manifest, ParamsError> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let v = Json::parse(&text).map_err(|e| ParamsError::Manifest(e.to_string()))?;
    let mut models = BTreeMap::new();
    let mobj = v
        .get("models")
        .and_then(|m| m.as_obj())
        .ok_or_else(|| ParamsError::Manifest("missing models".into()))?;
    for (name, m) in mobj {
        let cs = m
            .get("cache_shape")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| ParamsError::Manifest("missing cache_shape".into()))?;
        let mut cache_shape = [0usize; 5];
        for (i, c) in cs.iter().enumerate().take(5) {
            cache_shape[i] = c.as_usize().unwrap_or(0);
        }
        models.insert(
            name.clone(),
            ModelDims {
                n_layer: req_usize(m, "n_layer")?,
                d_model: req_usize(m, "d_model")?,
                n_head: req_usize(m, "n_head")?,
                d_ff: req_usize(m, "d_ff")?,
                n_params: req_usize(m, "n_params")?,
                cache_shape,
            },
        );
    }
    Ok(Manifest {
        maxlen: req_usize(&v, "maxlen")?,
        vocab: req_usize(&v, "vocab")?,
        models,
    })
}

/// Read `params_<name>.bin` (little-endian f32) and attach tensor specs.
pub fn load_model(dir: &Path, name: &str) -> Result<ModelParams, ParamsError> {
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let v = Json::parse(&manifest_text).map_err(|e| ParamsError::Manifest(e.to_string()))?;
    let m = v
        .get("models")
        .and_then(|ms| ms.get(name))
        .ok_or_else(|| ParamsError::Manifest(format!("model {name} not in manifest")))?;

    let manifest = load_manifest(dir)?;
    let dims = manifest.models[name].clone();

    let bytes = std::fs::read(dir.join(format!("params_{name}.bin")))?;
    let flat: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    if flat.len() != dims.n_params {
        return Err(ParamsError::SizeMismatch {
            model: name.to_string(),
            got: flat.len(),
            want: dims.n_params,
        });
    }

    let mut tensors = BTreeMap::new();
    if let Some(list) = m.get("tensors").and_then(|t| t.as_arr()) {
        for t in list {
            let tname = t
                .get("name")
                .and_then(|x| x.as_str())
                .ok_or_else(|| ParamsError::Manifest("tensor missing name".into()))?;
            let shape: Vec<usize> = t
                .get("shape")
                .and_then(|x| x.as_arr())
                .map(|a| a.iter().filter_map(|s| s.as_usize()).collect())
                .unwrap_or_default();
            let offset = req_usize(t, "offset")?;
            tensors.insert(tname.to_string(), TensorSpec { shape, offset });
        }
    }

    Ok(ModelParams { name: name.to_string(), dims, flat, tensors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_artifacts(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("specmer_params_{}_{}", std::process::id(), tag));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "maxlen": 8, "vocab": 32,
          "models": {
            "tiny": {
              "n_layer": 1, "d_model": 4, "n_head": 2, "d_ff": 8,
              "n_params": 6, "cache_shape": [1,2,2,8,2],
              "tensors": [
                {"name":"a","shape":[2,2],"offset":0},
                {"name":"b","shape":[2],"offset":4}
              ]
            }
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let vals: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut f = std::fs::File::create(dir.join("params_tiny.bin")).unwrap();
        for v in &vals {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        dir
    }

    #[test]
    fn loads_manifest_and_params() {
        let dir = fake_artifacts("load");
        let man = load_manifest(&dir).unwrap();
        assert_eq!(man.maxlen, 8);
        assert_eq!(man.models["tiny"].d_head(), 2);
        let mp = load_model(&dir, "tiny").unwrap();
        assert_eq!(mp.flat, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let (a, shape) = mp.tensor("a").unwrap();
        assert_eq!(shape, &[2, 2]);
        assert_eq!(a, &[1.0, 2.0, 3.0, 4.0]);
        let (b, _) = mp.tensor("b").unwrap();
        assert_eq!(b, &[5.0, 6.0]);
    }

    #[test]
    fn size_mismatch_detected() {
        let dir = fake_artifacts("mismatch");
        std::fs::write(dir.join("params_tiny.bin"), [0u8; 8]).unwrap();
        assert!(matches!(
            load_model(&dir, "tiny"),
            Err(ParamsError::SizeMismatch { .. })
        ));
    }

    #[test]
    fn unknown_tensor_errors() {
        let dir = fake_artifacts("unknown");
        let mp = load_model(&dir, "tiny");
        if let Ok(mp) = mp {
            assert!(mp.tensor("nope").is_err());
        }
    }

    #[test]
    fn packed_weights_transpose_and_pad() {
        // [V=3, D=2] embedding packed at lane width 4 -> [D=2, V_pad=4]
        let emb = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = PackedWeights::pack(&emb, 3, 2, 4);
        assert_eq!(p.v_pad, 4);
        assert_eq!(p.vocab, 3);
        assert_eq!(p.emb_t, vec![1.0, 3.0, 5.0, 0.0, 2.0, 4.0, 6.0, 0.0]);
        // already-aligned vocab gets no padding
        let p2 = PackedWeights::pack(&emb[..4], 2, 2, 2);
        assert_eq!(p2.v_pad, 2);
        assert_eq!(p2.emb_t, vec![1.0, 3.0, 2.0, 4.0]);
    }
}
