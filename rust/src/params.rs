//! Model parameter loading (`manifest.json` + `params_<model>.bin`) and the
//! dtype-tagged weight panel store for the CPU runtime.
//!
//! aot.py serializes each checkpoint as one flat little-endian f32 vector;
//! the manifest records the model hyperparameters, per-tensor offsets and
//! the KV-cache shape. The flat vector is argument 0 of every exported HLO
//! program, so Rust never needs to understand the tensor layout — but the
//! pure-Rust reference model (runtime::cpu_ref) does, via [`ModelParams::tensor`].
//!
//! # Weight panels and dtypes
//!
//! Decode on CPU is memory-bandwidth-bound on weight traffic, so the weight
//! matrices the GEMM kernels stream every round — the per-layer QKV/O and
//! MLP projections plus the prepacked logits head — are held in a
//! [`Panel`]: a dtype-tagged store quantized **once at model load**
//! ([`WeightDtype`], selected by `SPECMER_WEIGHT_DTYPE`). Narrow dtypes
//! (`bf16`, `f16`, `int8` + per-row f32 scales) never touch memory as f32;
//! the kernels dequantize in registers and accumulate in f32
//! ([`crate::runtime::gemm::matmul_panel`]).
//!
//! Tier contract: the default `f32` panel tier is **bitwise-pinned** to the
//! seed scalar path. Narrow tiers change values (quantization rounds the
//! weights) and are pinned differently: dequantization is deterministic and
//! identical across kernel arms, so for a fixed dtype the AVX2 arm, the
//! portable arm, and a dequantize-then-f32 oracle stay bitwise-equal to
//! *each other* (`tests/quantization.rs`), while accuracy vs f32 is bounded
//! by the end-to-end tolerance suites (`tests/fast_tier.rs`).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug)]
pub enum ParamsError {
    Io(std::io::Error),
    Manifest(String),
    SizeMismatch { model: String, got: usize, want: usize },
    UnknownTensor(String),
}

impl std::fmt::Display for ParamsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParamsError::Io(e) => write!(f, "io: {e}"),
            ParamsError::Manifest(m) => write!(f, "manifest: {m}"),
            ParamsError::SizeMismatch { model, got, want } => {
                write!(f, "params_{model}.bin has {got} floats, manifest says {want}")
            }
            ParamsError::UnknownTensor(t) => write!(f, "unknown tensor {t}"),
        }
    }
}

impl std::error::Error for ParamsError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ParamsError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ParamsError {
    fn from(e: std::io::Error) -> ParamsError {
        ParamsError::Io(e)
    }
}

/// Hyperparameters of one exported checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelDims {
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub n_params: usize,
    /// [layer, k/v, head, position, d_head]
    pub cache_shape: [usize; 5],
}

impl ModelDims {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }
    pub fn maxlen(&self) -> usize {
        self.cache_shape[3]
    }
    pub fn cache_len(&self) -> usize {
        self.cache_shape.iter().product()
    }
}

#[derive(Clone, Debug)]
struct TensorSpec {
    shape: Vec<usize>,
    offset: usize,
}

/// One checkpoint: dims + flat parameter vector + tensor directory.
pub struct ModelParams {
    pub name: String,
    pub dims: ModelDims,
    pub flat: Vec<f32>,
    tensors: BTreeMap<String, TensorSpec>,
}

impl ModelParams {
    /// View of one named tensor (row-major) with its shape.
    pub fn tensor(&self, name: &str) -> Result<(&[f32], &[usize]), ParamsError> {
        let spec = self
            .tensors
            .get(name)
            .ok_or_else(|| ParamsError::UnknownTensor(name.to_string()))?;
        let n: usize = spec.shape.iter().product();
        Ok((&self.flat[spec.offset..spec.offset + n], &spec.shape))
    }
}

/// Everything manifest.json describes.
pub struct Manifest {
    pub maxlen: usize,
    pub vocab: usize,
    pub models: BTreeMap<String, ModelDims>,
}

/// Storage dtype of a weight [`Panel`] (see module docs for the tier
/// contract). Selected per model at load; `SPECMER_WEIGHT_DTYPE` sets the
/// process default (resolved by [`crate::runtime::simd::weight_dtype`]).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum WeightDtype {
    /// 4 bytes/weight; the bitwise-exact default tier.
    #[default]
    F32,
    /// bfloat16: f32's exponent range, 8-bit mantissa, 2 bytes/weight.
    /// Dequant is an exact shift-widen — every bf16 value is exactly
    /// representable in f32.
    Bf16,
    /// IEEE half: 5-bit exponent, 11-bit mantissa, 2 bytes/weight. Exact
    /// dequant, but weights outside ±65504 saturate at quantization.
    F16,
    /// int8 with one f32 scale per `k` row (`scale = max_abs(row)/127`),
    /// ~1 byte/weight. Dequant folds the scale into the broadcast input.
    Int8,
}

impl WeightDtype {
    /// Stable name for logs / metrics / bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            WeightDtype::F32 => "f32",
            WeightDtype::Bf16 => "bf16",
            WeightDtype::F16 => "f16",
            WeightDtype::Int8 => "int8",
        }
    }

    /// Parse an env/config spelling; `None` for unrecognized values.
    pub fn parse(s: &str) -> Option<WeightDtype> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" | "" => Some(WeightDtype::F32),
            "bf16" | "bfloat16" => Some(WeightDtype::Bf16),
            "f16" | "fp16" | "float16" | "half" => Some(WeightDtype::F16),
            "int8" | "i8" | "q8" => Some(WeightDtype::Int8),
            _ => None,
        }
    }
}

/// f32 → bf16, round-to-nearest-even (NaN forced quiet so the payload
/// truncation can't produce an infinity bit pattern).
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let b = x.to_bits();
    if x.is_nan() {
        return ((b >> 16) as u16) | 0x0040;
    }
    let round = ((b >> 16) & 1) + 0x7fff;
    ((b.wrapping_add(round)) >> 16) as u16
}

/// bf16 → f32: exact (bf16 is f32 with the low 16 mantissa bits dropped).
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// f32 → IEEE binary16, round-to-nearest-even, with subnormal halves and
/// overflow-to-infinity handled (the `half` crate is unavailable offline
/// and core's `f16` is unstable, so the bit manipulation lives here).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp32 = ((bits >> 23) & 0xff) as i32;
    let mant32 = bits & 0x007f_ffff;
    if exp32 == 0xff {
        // Inf / NaN; keep NaN payloads nonzero after truncation.
        let payload = if mant32 == 0 { 0 } else { 0x0200 | (((mant32 >> 13) as u16) & 0x03ff) };
        return sign | 0x7c00 | payload;
    }
    let exp = exp32 - 127 + 15;
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // below half the smallest subnormal → ±0
        }
        // Subnormal half: shift the (implicit-bit-restored) mantissa so the
        // result exponent field is 0, rounding half-to-even on the cut.
        let mant = mant32 | 0x0080_0000;
        let shift = (14 - exp) as u32; // 14..=24
        let half = 1u32 << (shift - 1);
        let rest = mant & ((half << 1) - 1);
        let mut h = (mant >> shift) as u16;
        if rest > half || (rest == half && (h & 1) == 1) {
            h += 1; // carry into the exponent field is correct rounding
        }
        return sign | h;
    }
    let mut h = (((exp as u32) << 10) | (mant32 >> 13)) as u16;
    let rest = mant32 & 0x1fff;
    if rest > 0x1000 || (rest == 0x1000 && (h & 1) == 1) {
        h = h.wrapping_add(1); // may carry to ±inf; that is the rounded value
    }
    sign | h
}

/// IEEE binary16 → f32: exact for every half value (normal, subnormal,
/// ±inf; NaN payloads are widened left-aligned).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal half = mant · 2⁻²⁴: normalize into an f32 exponent.
            let p = 31 - mant.leading_zeros(); // MSB position, 0..=9
            let biased = p + 103; // (p - 24) + 127
            sign | (biased << 23) | ((mant << (23 - p)) & 0x007f_ffff)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// One dtype-tagged `[k, n]` row-major weight matrix, quantized once at
/// model load. Kernels consume it through [`Panel::view`].
#[derive(Clone, Debug)]
pub enum Panel {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    F16(Vec<u16>),
    /// Row-major quantized values + one scale per `k` row (`scales.len()`
    /// = `k`; an all-zero row gets scale 0 so dequant stays exact).
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

/// Borrowed view of a [`Panel`], the type the GEMM entry points take (lets
/// one code path serve both layer weights and the packed logits head).
#[derive(Clone, Copy, Debug)]
pub enum PanelRef<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
    F16(&'a [u16]),
    Int8 { q: &'a [i8], scales: &'a [f32] },
}

impl Panel {
    /// Quantize a `[k, n]` row-major f32 matrix into `dtype` storage.
    /// `k` is the shared GEMM dimension: int8 scales are per `k` row.
    pub fn quantize(w: &[f32], k: usize, n: usize, dtype: WeightDtype) -> Panel {
        debug_assert_eq!(w.len(), k * n);
        match dtype {
            WeightDtype::F32 => Panel::F32(w.to_vec()),
            WeightDtype::Bf16 => Panel::Bf16(w.iter().map(|&x| f32_to_bf16(x)).collect()),
            WeightDtype::F16 => Panel::F16(w.iter().map(|&x| f32_to_f16(x)).collect()),
            WeightDtype::Int8 => {
                let mut q = vec![0i8; w.len()];
                let mut scales = vec![0.0f32; k];
                for i in 0..k {
                    let row = &w[i * n..(i + 1) * n];
                    let maxabs = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                    if maxabs > 0.0 {
                        let scale = maxabs / 127.0;
                        scales[i] = scale;
                        let inv = 127.0 / maxabs;
                        for (qe, &x) in q[i * n..(i + 1) * n].iter_mut().zip(row) {
                            *qe = (x * inv).round().clamp(-127.0, 127.0) as i8;
                        }
                    }
                }
                Panel::Int8 { q, scales }
            }
        }
    }

    pub fn dtype(&self) -> WeightDtype {
        match self {
            Panel::F32(_) => WeightDtype::F32,
            Panel::Bf16(_) => WeightDtype::Bf16,
            Panel::F16(_) => WeightDtype::F16,
            Panel::Int8 { .. } => WeightDtype::Int8,
        }
    }

    /// Bytes of weight storage streamed per full pass over the panel
    /// (includes int8 scales — they are read traffic too).
    pub fn weight_bytes(&self) -> usize {
        match self {
            Panel::F32(w) => w.len() * 4,
            Panel::Bf16(w) | Panel::F16(w) => w.len() * 2,
            Panel::Int8 { q, scales } => q.len() + scales.len() * 4,
        }
    }

    /// Borrowed view for the GEMM entry points (named `view`, not
    /// `as_ref`, to stay clear of the `AsRef` trait convention).
    pub fn view(&self) -> PanelRef<'_> {
        match self {
            Panel::F32(w) => PanelRef::F32(w),
            Panel::Bf16(w) => PanelRef::Bf16(w),
            Panel::F16(w) => PanelRef::F16(w),
            Panel::Int8 { q, scales } => PanelRef::Int8 { q, scales },
        }
    }

    /// The f32 storage when this is an f32 panel (the scalar reference path
    /// requires the exact tier; narrow panels return `None`).
    pub fn f32_slice(&self) -> Option<&[f32]> {
        match self {
            Panel::F32(w) => Some(w),
            _ => None,
        }
    }

    /// Dequantize back to a dense f32 matrix. For bf16/f16 this is exact
    /// (the oracle `matmul` over this output is bitwise-equal to the fused
    /// kernels); for int8 it reconstructs `q · scale` per element.
    pub fn to_f32(&self, k: usize, n: usize) -> Vec<f32> {
        match self {
            Panel::F32(w) => w.clone(),
            Panel::Bf16(w) => w.iter().map(|&h| bf16_to_f32(h)).collect(),
            Panel::F16(w) => w.iter().map(|&h| f16_to_f32(h)).collect(),
            Panel::Int8 { q, scales } => {
                let mut out = vec![0.0f32; k * n];
                for i in 0..k {
                    let s = scales[i];
                    let row = &q[i * n..(i + 1) * n];
                    for (o, &qe) in out[i * n..(i + 1) * n].iter_mut().zip(row) {
                        *o = qe as f32 * s;
                    }
                }
                out
            }
        }
    }
}

impl PanelRef<'_> {
    pub fn dtype(&self) -> WeightDtype {
        match self {
            PanelRef::F32(_) => WeightDtype::F32,
            PanelRef::Bf16(_) => WeightDtype::Bf16,
            PanelRef::F16(_) => WeightDtype::F16,
            PanelRef::Int8 { .. } => WeightDtype::Int8,
        }
    }

    /// Element count of the underlying `[k, n]` matrix.
    pub fn len(&self) -> usize {
        match self {
            PanelRef::F32(w) => w.len(),
            PanelRef::Bf16(w) | PanelRef::F16(w) => w.len(),
            PanelRef::Int8 { q, .. } => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Prepacked weight panels for the CPU runtime's column-vectorized kernels,
/// built **once at model load** (`CpuModel::from_params` / `synthetic`).
///
/// The weight-tied logits head multiplies hidden states against the token
/// embedding, which is stored row-major `[V, D]` — the wrong orientation
/// for a kernel that vectorizes across output columns, which is why the
/// seed path ran a per-vocab-entry transposed dot product (`matmul_nt`).
/// Packing transposes the embedding once into a row-major `[D, V_pad]`
/// panel (`V_pad` = vocab rounded up to `lanes`, zero-filled), so the head
/// becomes a plain `[rows, D] × [D, V]` `matmul_dense` call. Per output
/// element the accumulation order over `D` is unchanged, so the packed
/// head is bitwise-identical to the seed head. The CPU runtime packs at
/// `lanes = 1` (exact width — its kernels handle trailing columns with a
/// scalar tail); alignment padding is for panels whose consumer wants
/// full-width vector tiles only.
///
/// Projection weights are exported row-major `[in, out]` — already the
/// column-lane orientation — so only the tied head needs a packed panel.
pub struct PackedWeights {
    /// Transposed tied embedding, row-major `[D, V_pad]` — the f32-tier
    /// storage. Empty when a narrow dtype is packed (see `quant`).
    pub emb_t: Vec<f32>,
    /// Narrow-dtype storage of the same `[D, V_pad]` panel; `None` on the
    /// f32 tier so the head is never held twice.
    pub quant: Option<Panel>,
    /// Columns in the packed panel (`vocab` rounded up to `lanes`).
    pub v_pad: usize,
    /// Real vocab width (columns `vocab..v_pad` are zero padding).
    pub vocab: usize,
}

impl PackedWeights {
    /// Transpose the first `vocab` rows of a `[V, D]` embedding into a
    /// `[D, V_pad]` panel aligned to `lanes` columns (f32 tier).
    pub fn pack(tok_emb: &[f32], vocab: usize, d: usize, lanes: usize) -> PackedWeights {
        let lanes = lanes.max(1);
        let v_pad = (vocab + lanes - 1) / lanes * lanes;
        let mut emb_t = vec![0.0f32; d * v_pad];
        for t in 0..vocab {
            for i in 0..d {
                emb_t[i * v_pad + t] = tok_emb[t * d + i];
            }
        }
        PackedWeights { emb_t, quant: None, v_pad, vocab }
    }

    /// [`PackedWeights::pack`] then quantize the panel into `dtype`
    /// storage. `F32` keeps the transposed f32 panel unchanged.
    pub fn pack_dtype(
        tok_emb: &[f32],
        vocab: usize,
        d: usize,
        lanes: usize,
        dtype: WeightDtype,
    ) -> PackedWeights {
        let mut p = Self::pack(tok_emb, vocab, d, lanes);
        if dtype != WeightDtype::F32 {
            p.quant = Some(Panel::quantize(&p.emb_t, d, p.v_pad, dtype));
            p.emb_t = Vec::new();
        }
        p
    }

    /// The `[D, V_pad]` head panel on whichever tier is packed.
    pub fn head(&self) -> PanelRef<'_> {
        match &self.quant {
            Some(p) => p.view(),
            None => PanelRef::F32(&self.emb_t),
        }
    }

    pub fn dtype(&self) -> WeightDtype {
        self.quant.as_ref().map_or(WeightDtype::F32, |p| p.dtype())
    }

    /// Weight bytes streamed by one full pass over the head panel.
    pub fn weight_bytes(&self) -> usize {
        match &self.quant {
            Some(p) => p.weight_bytes(),
            None => self.emb_t.len() * 4,
        }
    }
}

fn req_usize(v: &Json, key: &str) -> Result<usize, ParamsError> {
    v.get(key)
        .and_then(|x| x.as_usize())
        .ok_or_else(|| ParamsError::Manifest(format!("missing {key}")))
}

pub fn load_manifest(dir: &Path) -> Result<Manifest, ParamsError> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let v = Json::parse(&text).map_err(|e| ParamsError::Manifest(e.to_string()))?;
    let mut models = BTreeMap::new();
    let mobj = v
        .get("models")
        .and_then(|m| m.as_obj())
        .ok_or_else(|| ParamsError::Manifest("missing models".into()))?;
    for (name, m) in mobj {
        let cs = m
            .get("cache_shape")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| ParamsError::Manifest("missing cache_shape".into()))?;
        let mut cache_shape = [0usize; 5];
        for (i, c) in cs.iter().enumerate().take(5) {
            cache_shape[i] = c.as_usize().unwrap_or(0);
        }
        models.insert(
            name.clone(),
            ModelDims {
                n_layer: req_usize(m, "n_layer")?,
                d_model: req_usize(m, "d_model")?,
                n_head: req_usize(m, "n_head")?,
                d_ff: req_usize(m, "d_ff")?,
                n_params: req_usize(m, "n_params")?,
                cache_shape,
            },
        );
    }
    Ok(Manifest {
        maxlen: req_usize(&v, "maxlen")?,
        vocab: req_usize(&v, "vocab")?,
        models,
    })
}

/// Read `params_<name>.bin` (little-endian f32) and attach tensor specs.
pub fn load_model(dir: &Path, name: &str) -> Result<ModelParams, ParamsError> {
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let v = Json::parse(&manifest_text).map_err(|e| ParamsError::Manifest(e.to_string()))?;
    let m = v
        .get("models")
        .and_then(|ms| ms.get(name))
        .ok_or_else(|| ParamsError::Manifest(format!("model {name} not in manifest")))?;

    let manifest = load_manifest(dir)?;
    let dims = manifest.models[name].clone();

    let bytes = std::fs::read(dir.join(format!("params_{name}.bin")))?;
    let flat: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    if flat.len() != dims.n_params {
        return Err(ParamsError::SizeMismatch {
            model: name.to_string(),
            got: flat.len(),
            want: dims.n_params,
        });
    }

    let mut tensors = BTreeMap::new();
    if let Some(list) = m.get("tensors").and_then(|t| t.as_arr()) {
        for t in list {
            let tname = t
                .get("name")
                .and_then(|x| x.as_str())
                .ok_or_else(|| ParamsError::Manifest("tensor missing name".into()))?;
            let shape: Vec<usize> = t
                .get("shape")
                .and_then(|x| x.as_arr())
                .map(|a| a.iter().filter_map(|s| s.as_usize()).collect())
                .unwrap_or_default();
            let offset = req_usize(t, "offset")?;
            tensors.insert(tname.to_string(), TensorSpec { shape, offset });
        }
    }

    Ok(ModelParams { name: name.to_string(), dims, flat, tensors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_artifacts(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("specmer_params_{}_{}", std::process::id(), tag));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "maxlen": 8, "vocab": 32,
          "models": {
            "tiny": {
              "n_layer": 1, "d_model": 4, "n_head": 2, "d_ff": 8,
              "n_params": 6, "cache_shape": [1,2,2,8,2],
              "tensors": [
                {"name":"a","shape":[2,2],"offset":0},
                {"name":"b","shape":[2],"offset":4}
              ]
            }
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let vals: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut f = std::fs::File::create(dir.join("params_tiny.bin")).unwrap();
        for v in &vals {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        dir
    }

    #[test]
    fn loads_manifest_and_params() {
        let dir = fake_artifacts("load");
        let man = load_manifest(&dir).unwrap();
        assert_eq!(man.maxlen, 8);
        assert_eq!(man.models["tiny"].d_head(), 2);
        let mp = load_model(&dir, "tiny").unwrap();
        assert_eq!(mp.flat, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let (a, shape) = mp.tensor("a").unwrap();
        assert_eq!(shape, &[2, 2]);
        assert_eq!(a, &[1.0, 2.0, 3.0, 4.0]);
        let (b, _) = mp.tensor("b").unwrap();
        assert_eq!(b, &[5.0, 6.0]);
    }

    #[test]
    fn size_mismatch_detected() {
        let dir = fake_artifacts("mismatch");
        std::fs::write(dir.join("params_tiny.bin"), [0u8; 8]).unwrap();
        assert!(matches!(
            load_model(&dir, "tiny"),
            Err(ParamsError::SizeMismatch { .. })
        ));
    }

    #[test]
    fn unknown_tensor_errors() {
        let dir = fake_artifacts("unknown");
        let mp = load_model(&dir, "tiny");
        if let Ok(mp) = mp {
            assert!(mp.tensor("nope").is_err());
        }
    }

    #[test]
    fn packed_weights_transpose_and_pad() {
        // [V=3, D=2] embedding packed at lane width 4 -> [D=2, V_pad=4]
        let emb = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = PackedWeights::pack(&emb, 3, 2, 4);
        assert_eq!(p.v_pad, 4);
        assert_eq!(p.vocab, 3);
        assert_eq!(p.emb_t, vec![1.0, 3.0, 5.0, 0.0, 2.0, 4.0, 6.0, 0.0]);
        // already-aligned vocab gets no padding
        let p2 = PackedWeights::pack(&emb[..4], 2, 2, 2);
        assert_eq!(p2.v_pad, 2);
        assert_eq!(p2.emb_t, vec![1.0, 3.0, 2.0, 4.0]);
    }
}
