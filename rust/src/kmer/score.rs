//! Candidate scoring — Eq. 2 of the paper.
//!
//!   Score(s) = (1/L) Σ_{k∈K} Σ_{i=0}^{L-k} P_k( s[i:i+k] )
//!
//! Additive (not multiplicative) so unseen k-mers don't zero the score and
//! partially-formed motifs still earn credit (paper §3.2). The hot-path
//! implementation lives here in Rust (a table lookup per window — the
//! paper's "near-zero cost"); `kmer_score_c8_g*.hlo.txt` carries the same
//! computation as a Pallas kernel for TPU deployments, checked equal in
//! tests.

use super::table::KmerTable;

/// Which k values are active (paper sweeps {1}, {3}, {1,3}, {1,3,5}).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KmerSet {
    pub k1: bool,
    pub k3: bool,
    pub k5: bool,
}

impl KmerSet {
    pub const fn new(k1: bool, k3: bool, k5: bool) -> KmerSet {
        KmerSet { k1, k3, k5 }
    }

    /// Parse "1,3,5"-style strings.
    pub fn parse(s: &str) -> Option<KmerSet> {
        let mut set = KmerSet::new(false, false, false);
        for part in s.split(',') {
            match part.trim() {
                "1" => set.k1 = true,
                "3" => set.k3 = true,
                "5" => set.k5 = true,
                "" => {}
                _ => return None,
            }
        }
        if set.k1 || set.k3 || set.k5 {
            Some(set)
        } else {
            None
        }
    }

    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.k1 {
            parts.push("1");
        }
        if self.k3 {
            parts.push("3");
        }
        if self.k5 {
            parts.push("5");
        }
        parts.join(",")
    }

    /// Largest active k (context windows need `kmax() - 1` tail tokens).
    pub fn kmax(&self) -> usize {
        if self.k5 {
            5
        } else if self.k3 {
            3
        } else {
            1
        }
    }

    /// The paper's four swept configurations.
    pub const SWEEP: [KmerSet; 4] = [
        KmerSet::new(true, false, false),
        KmerSet::new(false, true, false),
        KmerSet::new(true, true, false),
        KmerSet::new(true, true, true),
    ];
}

/// Score one candidate block (paper-faithful: windows within the block).
pub fn score_block(table: &KmerTable, block: &[u8], ks: KmerSet) -> f32 {
    if block.is_empty() {
        return 0.0;
    }
    let mut s = 0.0f32;
    if ks.k1 {
        for &t in block {
            s += table.p1[t as usize];
        }
    }
    if ks.k3 && block.len() >= 3 {
        for w in block.windows(3) {
            s += table.p3[super::table::idx3(w)];
        }
    }
    if ks.k5 && block.len() >= 5 {
        for w in block.windows(5) {
            s += table.p5[super::table::hash5(w.try_into().unwrap())];
        }
    }
    s / block.len() as f32
}

/// Extension: also count windows spanning the context/block boundary by
/// prepending the last (k_max - 1) context tokens. Off by default
/// (`Config::kmer_context_boundary`); exercised by the ablation bench.
pub fn score_block_with_context(
    table: &KmerTable,
    context_tail: &[u8],
    block: &[u8],
    ks: KmerSet,
) -> f32 {
    if block.is_empty() {
        return 0.0;
    }
    let tail_n = (ks.kmax() - 1).min(context_tail.len());
    let mut ext = Vec::with_capacity(tail_n + block.len());
    ext.extend_from_slice(&context_tail[context_tail.len() - tail_n..]);
    ext.extend_from_slice(block);
    let mut s = 0.0f32;
    if ks.k1 {
        for &t in block {
            s += table.p1[t as usize];
        }
    }
    if ks.k3 && ext.len() >= 3 {
        for w in ext.windows(3) {
            s += table.p3[super::table::idx3(w)];
        }
    }
    if ks.k5 && ext.len() >= 5 {
        for w in ext.windows(5) {
            s += table.p5[super::table::hash5(w.try_into().unwrap())];
        }
    }
    s / block.len() as f32
}

/// Index of the best-scoring candidate (ties → lowest index, so c=1
/// degenerates to vanilla speculative decoding exactly). With an empty
/// context tail, boundary scoring reduces exactly to [`score_block`], so
/// this is the boundary-free special case of the selection loop.
pub fn select_best(table: &KmerTable, candidates: &[Vec<u8>], ks: KmerSet) -> usize {
    select_best_with_context(table, &[], candidates, ks)
}

/// [`select_best`] with boundary-spanning windows: each candidate is scored
/// by [`score_block_with_context`] against the same committed-context tail
/// (pass at least the last `kmax() - 1` committed tokens; longer tails are
/// trimmed). Ties → lowest index, matching `select_best`.
pub fn select_best_with_context(
    table: &KmerTable,
    context_tail: &[u8],
    candidates: &[Vec<u8>],
    ks: KmerSet,
) -> usize {
    let mut best = 0usize;
    let mut best_s = f32::NEG_INFINITY;
    for (i, c) in candidates.iter().enumerate() {
        let s = score_block_with_context(table, context_tail, c, ks);
        if s > best_s {
            best_s = s;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmer::table::KmerTable;
    use crate::msa::Msa;
    use crate::tokenizer::encode;
    use crate::util::proptest::check;

    fn table() -> KmerTable {
        KmerTable::build(&Msa {
            name: "t".into(),
            wild_type: "ACDEFG".into(),
            rows: vec!["ACDEFG".into(); 10],
        })
    }

    #[test]
    fn motif_block_beats_random() {
        let t = table();
        let ks = KmerSet::new(true, true, true);
        let motif = score_block(&t, &encode("ACDEF"), ks);
        let junk = score_block(&t, &encode("WWYYW"), ks);
        assert!(motif > junk, "{motif} vs {junk}");
    }

    #[test]
    fn kset_parse_and_label() {
        let ks = KmerSet::parse("1,3,5").unwrap();
        assert_eq!(ks, KmerSet::new(true, true, true));
        assert_eq!(ks.label(), "1,3,5");
        assert_eq!(KmerSet::parse("3").unwrap(), KmerSet::new(false, true, false));
        assert!(KmerSet::parse("2").is_none());
        assert!(KmerSet::parse("").is_none());
    }

    #[test]
    fn select_best_prefers_motif() {
        let t = table();
        let cands = vec![encode("WWYYW"), encode("ACDEF"), encode("KLKLK")];
        assert_eq!(select_best(&t, &cands, KmerSet::new(true, true, true)), 1);
    }

    #[test]
    fn empty_block_scores_zero() {
        let t = table();
        assert_eq!(score_block(&t, &[], KmerSet::new(true, true, true)), 0.0);
    }

    #[test]
    fn context_boundary_adds_windows() {
        let t = table();
        let ks = KmerSet::new(false, true, false);
        // block "EF" alone has no 3-mer windows; with context tail "CD" the
        // windows CDE and DEF appear.
        let plain = score_block(&t, &encode("EF"), ks);
        let ctx = score_block_with_context(&t, &encode("ACD"), &encode("EF"), ks);
        assert_eq!(plain, 0.0);
        assert!(ctx > 0.0);
    }

    #[test]
    fn prop_score_bounded() {
        // additive score of L windows each <= 1, normalized by L => <= kmax
        check("score within [0, 3]", 50, |g| {
            let seed = g.u64();
            let (_p, msa) = crate::msa::simulate::generate_family("T", 30, 6, seed);
            let t = KmerTable::build(&msa);
            let block: Vec<u8> = (0..g.usize_in(1..16))
                .map(|_| 3 + g.rng().below(20) as u8)
                .collect();
            let s = score_block(&t, &block, KmerSet::new(true, true, true));
            assert!((0.0..=3.0).contains(&s), "score {s}");
        });
    }

    #[test]
    fn ties_resolve_to_first() {
        let t = table();
        let cands = vec![encode("ACDEF"), encode("ACDEF")];
        assert_eq!(select_best(&t, &cands, KmerSet::new(true, true, true)), 0);
    }

    #[test]
    fn kmax_reflects_largest_active_k() {
        assert_eq!(KmerSet::new(true, false, false).kmax(), 1);
        assert_eq!(KmerSet::new(true, true, false).kmax(), 3);
        assert_eq!(KmerSet::new(false, true, false).kmax(), 3);
        assert_eq!(KmerSet::new(true, true, true).kmax(), 5);
    }

    #[test]
    fn select_best_with_context_matches_per_candidate_scoring() {
        let t = table();
        let ks = KmerSet::new(false, true, false);
        let tail = encode("ACD");
        let cands = vec![encode("EF"), encode("WW"), encode("CD")];
        let sel = select_best_with_context(&t, &tail, &cands, ks);
        let mut best = 0;
        let mut best_s = f32::NEG_INFINITY;
        for (i, c) in cands.iter().enumerate() {
            let s = score_block_with_context(&t, &tail, c, ks);
            if s > best_s {
                best_s = s;
                best = i;
            }
        }
        assert_eq!(sel, best);
        // boundary windows make "EF" (completing ACD|EF motifs) win over junk
        assert_eq!(sel, 0);
    }

    #[test]
    fn context_scoring_uses_only_kmax_tail() {
        // a longer-than-needed tail must score identically to the trimmed
        // one (the decode engine passes exactly kmax-1 tokens)
        let t = table();
        let ks = KmerSet::new(true, true, true);
        let block = encode("EF");
        let long = score_block_with_context(&t, &encode("AACDEF")[..], &block, ks);
        let trimmed = score_block_with_context(&t, &encode("CDEF")[..], &block, ks);
        assert_eq!(long, trimmed);
    }
}
