//! K-mer statistics: table construction from MSAs and candidate scoring
//! (the paper's §3.2, Eq. 2).

pub mod score;
pub mod table;

pub use score::{
    score_block, score_block_with_context, select_best, select_best_with_context, KmerSet,
};
pub use table::KmerTable;
