//! K-mer frequency tables built from an MSA (paper §3.2, App. E).
//!
//! K-mers are extracted with a sliding window over the *ungapped* rows of
//! the alignment (gap characters are ignored, App. E), counted, and
//! normalized into a probability distribution per k.  Storage:
//!
//!   k=1  dense  [V]        (V = 32 token ids)
//!   k=3  dense  [V^3]      (32768 f32, 128 KiB)
//!   k=5  hashed [HSZ=2^18] open-addressing-free: colliding 5-mers simply
//!        share a slot (probability mass merges). The hash is wrapping-u32
//!        base-33 + Knuth multiplier and matches
//!        python/compile/kernels/kmer_score.py bit-for-bit, so the Pallas
//!        scoring kernel and this module agree exactly.
//!
//! The paper caps k at 5 because dense tables grow as V^k; the hashed k=5
//! table is our TPU-friendly equivalent (1 MiB, VMEM-resident).

use crate::msa::Msa;
use crate::tokenizer::VOCAB;

pub const HSZ: usize = 1 << 18;
const HASH_MUL: u32 = 2654435761;

/// Wrapping-u32 hash of a 5-mer of token ids. MUST match kmer_score.py.
#[inline]
pub fn hash5(t: &[u8; 5]) -> usize {
    let mut h: u32 = t[0] as u32;
    for &x in &t[1..] {
        h = h.wrapping_mul(33).wrapping_add(x as u32);
    }
    (h.wrapping_mul(HASH_MUL) & (HSZ as u32 - 1)) as usize
}

#[inline]
pub fn idx3(t: &[u8]) -> usize {
    ((t[0] as usize) * VOCAB + t[1] as usize) * VOCAB + t[2] as usize
}

/// Normalized k-mer probability tables for one protein family.
#[derive(Clone)]
pub struct KmerTable {
    pub family: String,
    /// Total k-mer windows counted per k (diagnostics / tests).
    pub totals: [u64; 3],
    pub p1: Vec<f32>,
    pub p3: Vec<f32>,
    pub p5: Vec<f32>,
}

impl KmerTable {
    /// Count k-mers over the ungapped rows of an MSA and normalize.
    pub fn build(msa: &Msa) -> KmerTable {
        Self::build_from_rows(&msa.name, &msa.tokenized_rows())
    }

    pub fn build_from_rows(family: &str, rows: &[Vec<u8>]) -> KmerTable {
        let mut c1 = vec![0u64; VOCAB];
        let mut c3 = vec![0u64; VOCAB * VOCAB * VOCAB];
        let mut c5 = vec![0u64; HSZ];
        let mut totals = [0u64; 3];
        for row in rows {
            for &t in row {
                c1[t as usize] += 1;
                totals[0] += 1;
            }
            if row.len() >= 3 {
                for w in row.windows(3) {
                    c3[idx3(w)] += 1;
                    totals[1] += 1;
                }
            }
            if row.len() >= 5 {
                for w in row.windows(5) {
                    let arr: &[u8; 5] = w.try_into().unwrap();
                    c5[hash5(arr)] += 1;
                    totals[2] += 1;
                }
            }
        }
        let norm = |c: &[u64], total: u64| -> Vec<f32> {
            if total == 0 {
                vec![0.0; c.len()]
            } else {
                c.iter().map(|&x| (x as f64 / total as f64) as f32).collect()
            }
        };
        KmerTable {
            family: family.to_string(),
            totals,
            p1: norm(&c1, totals[0]),
            p3: norm(&c3, totals[1]),
            p5: norm(&c5, totals[2]),
        }
    }

    /// Probability of a single k-mer window (k = w.len() ∈ {1,3,5}).
    #[inline]
    pub fn prob(&self, w: &[u8]) -> f32 {
        match w.len() {
            1 => self.p1[w[0] as usize],
            3 => self.p3[idx3(w)],
            5 => self.p5[hash5(w.try_into().unwrap())],
            _ => 0.0,
        }
    }

    /// Rough memory footprint in bytes (perf accounting).
    pub fn nbytes(&self) -> usize {
        4 * (self.p1.len() + self.p3.len() + self.p5.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msa::Msa;
    use crate::tokenizer::encode;
    use crate::util::proptest::check;

    fn toy() -> Msa {
        Msa {
            name: "toy".into(),
            wild_type: "ACDEA".into(),
            rows: vec!["ACDEA".into(), "ACD-A".into(), "ACKEA".into()],
        }
    }

    #[test]
    fn normalized_distributions() {
        let t = KmerTable::build(&toy());
        let s1: f64 = t.p1.iter().map(|&x| x as f64).sum();
        let s3: f64 = t.p3.iter().map(|&x| x as f64).sum();
        let s5: f64 = t.p5.iter().map(|&x| x as f64).sum();
        assert!((s1 - 1.0).abs() < 1e-5);
        assert!((s3 - 1.0).abs() < 1e-5);
        assert!((s5 - 1.0).abs() < 1e-5, "s5={s5}");
    }

    #[test]
    fn gaps_ignored_in_windows() {
        // "ACD-A" contributes 3-mers of the UNGAPPED string ACDA: ACD, CDA
        let t = KmerTable::build(&toy());
        let cda = encode("CDA");
        assert!(t.prob(&cda) > 0.0);
    }

    #[test]
    fn frequent_kmer_scores_higher() {
        let t = KmerTable::build(&toy());
        let acd = encode("ACD");
        let www = encode("WWW");
        assert!(t.prob(&acd) > t.prob(&www));
    }

    #[test]
    fn hash5_matches_reference_values() {
        // Anchors for the Python contract (test_kmer_kernel.py checks the
        // same tuples): recompute by hand here.
        let cases: [[u8; 5]; 3] = [[3, 4, 5, 6, 3], [0, 0, 0, 0, 0], [31, 31, 31, 31, 31]];
        for c in cases {
            let mut h: u32 = c[0] as u32;
            for &x in &c[1..] {
                h = h.wrapping_mul(33).wrapping_add(x as u32);
            }
            let expect = (h.wrapping_mul(2654435761) & (HSZ as u32 - 1)) as usize;
            assert_eq!(hash5(&c), expect);
        }
    }

    #[test]
    fn prop_tables_are_distributions() {
        check("kmer tables normalized", 15, |g| {
            let seed = g.u64();
            let (_p, msa) = crate::msa::simulate::generate_family("T", 40, 8, seed);
            let t = KmerTable::build(&msa);
            for (p, total) in [(&t.p1, t.totals[0]), (&t.p3, t.totals[1]), (&t.p5, t.totals[2])] {
                if total == 0 {
                    continue;
                }
                let s: f64 = p.iter().map(|&x| x as f64).sum();
                assert!((s - 1.0).abs() < 1e-4, "sum {s}");
                assert!(p.iter().all(|&x| x >= 0.0));
            }
        });
    }
}
