//! Amino-acid tokenizer — mirrors `python/compile/vocab.py` exactly.
//!
//! Layout (V = 32): 0 PAD, 1 BOS, 2 EOS (ProGen2's stop token is literally
//! "2", see paper App. B.3), 3..=22 the 20 canonical amino acids in
//! alphabetical letter order, 23 X (unknown), 24..=31 reserved.

pub const PAD: u8 = 0;
pub const BOS: u8 = 1;
pub const EOS: u8 = 2;
pub const AA_OFFSET: u8 = 3;
pub const X: u8 = 23;
pub const VOCAB: usize = 32;
pub const N_AA: usize = 20;

/// Canonical amino-acid letters, index i ↔ token AA_OFFSET + i.
pub const AA: [u8; N_AA] = *b"ACDEFGHIKLMNPQRSTVWY";

/// Token id of an amino-acid letter ('-'/'.' are alignment gaps → None;
/// anything unrecognized → X).
#[inline]
pub fn tok_of(ch: u8) -> Option<u8> {
    let up = ch.to_ascii_uppercase();
    if up == b'-' || up == b'.' {
        return None;
    }
    match AA.iter().position(|&a| a == up) {
        Some(i) => Some(AA_OFFSET + i as u8),
        None => Some(X),
    }
}

/// Letter of a token id (specials → None).
#[inline]
pub fn chr_of(tok: u8) -> Option<u8> {
    if tok == X {
        Some(b'X')
    } else if (AA_OFFSET..AA_OFFSET + N_AA as u8).contains(&tok) {
        Some(AA[(tok - AA_OFFSET) as usize])
    } else {
        None
    }
}

/// Is this token an amino acid (incl. X)?
#[inline]
pub fn is_residue(tok: u8) -> bool {
    (AA_OFFSET..=X).contains(&tok)
}

/// Encode a protein string (gaps dropped) — no BOS/EOS added.
pub fn encode(seq: &str) -> Vec<u8> {
    seq.bytes().filter_map(tok_of).collect()
}

/// Encode with BOS prefix and EOS suffix.
pub fn encode_with_specials(seq: &str) -> Vec<u8> {
    let mut v = Vec::with_capacity(seq.len() + 2);
    v.push(BOS);
    v.extend(encode(seq));
    v.push(EOS);
    v
}

/// Decode token ids to a protein string (specials skipped).
pub fn decode(toks: &[u8]) -> String {
    toks.iter()
        .filter_map(|&t| chr_of(t))
        .map(|b| b as char)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = "MKTAYIAKQRQISFVKSHFSRQLEERLGLIEVQ";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn gaps_dropped() {
        assert_eq!(decode(&encode("A-C.D")), "ACD");
    }

    #[test]
    fn unknown_maps_to_x() {
        assert_eq!(encode("B")[0], X);
        assert_eq!(decode(&[X]), "X");
    }

    #[test]
    fn specials() {
        let v = encode_with_specials("AC");
        assert_eq!(v[0], BOS);
        assert_eq!(*v.last().unwrap(), EOS);
        assert_eq!(decode(&v), "AC");
    }

    #[test]
    fn vocab_ids_match_python() {
        // spot-check the contract with python/compile/vocab.py
        assert_eq!(tok_of(b'A'), Some(3));
        assert_eq!(tok_of(b'C'), Some(4));
        assert_eq!(tok_of(b'Y'), Some(22));
        assert_eq!(tok_of(b'a'), Some(3)); // case-insensitive
    }

    #[test]
    fn all_residues_roundtrip() {
        for (i, &a) in AA.iter().enumerate() {
            let t = AA_OFFSET + i as u8;
            assert_eq!(tok_of(a), Some(t));
            assert_eq!(chr_of(t), Some(a));
            assert!(is_residue(t));
        }
        assert!(!is_residue(PAD));
        assert!(!is_residue(BOS));
        assert!(!is_residue(EOS));
    }
}
