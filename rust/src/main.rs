//! specmer — CLI for the SpecMER serving system.
//!
//! Subcommands:
//!   generate  — generate sequences for a protein, print FASTA
//!   serve     — start the HTTP inference server
//!   score     — score a FASTA file's sequences under the target model
//!   exp       — regenerate a paper table/figure (or `all`)
//!   families  — list the protein families baked into artifacts
//!   info      — runtime/platform/artifact diagnostics
//!
//! Common flags: --artifacts DIR, --cpu-ref, --gamma N, --c N, --temp F,
//! --top-p F, --k 1,3,5, --seed N, --n N, --workers N.

use std::sync::Arc;

use anyhow::{anyhow, Result};
use specmer::config::{Config, Method};
use specmer::coordinator::{build_engine, Metrics, Router, Scheduler};
use specmer::experiments::{self, ExpOpts};
use specmer::util::cli::Args;

const USAGE: &str = "usage: specmer <generate|serve|score|exp|families|info> [flags]
  generate --protein GFP [--method specmer] [--n 5] [--c 3] [--gamma 5]
           [--temp 1.0] [--top-p 0.95] [--k 1,3] [--seed 0] [--out file.fa]
  serve    [--port 7878] [--workers 1] [--max-batch 8] [--max-wait-ms 5]
           [--queue-cap 256] [--max-inflight 0] [--timeout-ms 0]
           [--prefix-cache-mb 32] [--prefill-chunk 0]
  score    --fasta file.fa
  exp      <table1..table10|fig1c|fig2a|fig2b|fig3|figs_sweep|bounds|msadepth|all>
           [--n 20] [--full] [--proteins GFP,GB1] [--results DIR]
  families | info
common:  --artifacts DIR (or $SPECMER_ARTIFACTS)  --cpu-ref";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("quiet") {
        specmer::util::set_log_level(0);
    }
    if args.flag("verbose") {
        specmer::util::set_log_level(2);
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let cfg = Config::from_args(args)?;
    match cmd {
        "generate" => cmd_generate(args, &cfg),
        "serve" => cmd_serve(args, &cfg),
        "score" => cmd_score(args, &cfg),
        "exp" => cmd_exp(args, cfg),
        "families" => cmd_families(&cfg),
        "info" => cmd_info(&cfg),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_generate(args: &Args, cfg: &Config) -> Result<()> {
    let protein = args
        .get("protein")
        .ok_or_else(|| anyhow!("--protein required"))?
        .to_string();
    let method = Method::parse(&args.str_or("method", "specmer"))
        .ok_or_else(|| anyhow!("bad --method"))?;
    let n = args.usize_or("n", 5)?;
    let engine = build_engine(cfg)?;
    let mut fasta = String::new();
    let t0 = std::time::Instant::now();
    let mut tokens = 0usize;
    // resolve the per-sequence scoring plan once; only the seed varies
    let mut spec = engine.spec(&protein, method, &cfg.gen)?;
    for i in 0..n {
        spec.cfg.seed = cfg.gen.seed.wrapping_add(i as u64);
        let out = engine.generate(&spec)?;
        let nll = engine.score_nll(&out.tokens)?;
        tokens += out.new_tokens();
        fasta.push_str(&format!(
            ">{protein}_{i} method={} accept={:.3} nll={nll:.3}\n{}\n",
            method.label(),
            out.acceptance_ratio(),
            specmer::tokenizer::decode(&out.tokens)
        ));
    }
    let dt = t0.elapsed().as_secs_f64();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &fasta)?;
            eprintln!("wrote {n} sequences to {path}");
        }
        None => print!("{fasta}"),
    }
    eprintln!(
        "[specmer] {n} seqs, {tokens} tokens in {dt:.2}s ({:.1} tok/s)",
        tokens as f64 / dt
    );
    Ok(())
}

fn cmd_serve(args: &Args, cfg: &Config) -> Result<()> {
    let _ = args;
    let metrics = Arc::new(Metrics::new());
    // families load once; the router resolves specs from the same
    // Arc<Family> handles the worker engines decode with
    let registry = Arc::new(specmer::coordinator::FamilyRegistry::load(&cfg.artifacts)?);
    let cfg2 = cfg.clone();
    let reg2 = Arc::clone(&registry);
    let factory: specmer::coordinator::EngineFactory =
        Arc::new(move || specmer::coordinator::build_engine_with(&cfg2, reg2.families().to_vec()));
    let opts = specmer::coordinator::SchedulerOpts {
        max_batch: cfg.max_batch,
        max_wait: std::time::Duration::from_millis(cfg.max_wait_ms),
        queue_capacity: cfg.queue_cap,
        fault: specmer::coordinator::FaultPlan::from_env(),
        prefix_cache_mb: cfg.prefix_cache_mb,
        prefill_chunk: cfg.prefill_chunk,
    };
    let sched = Arc::new(Scheduler::start_with(cfg.workers, opts, factory, Arc::clone(&metrics)));
    let router = Arc::new(Router::new(sched, registry).with_max_inflight(cfg.max_inflight));
    let handle = specmer::server::serve(cfg, router, metrics)?;
    println!(
        "specmer serving on http://{} ({} workers, artifacts={})",
        handle.addr,
        cfg.workers,
        cfg.artifacts.display()
    );
    println!(
        "endpoints: POST /generate, GET /metrics, GET /health, GET /ready — ctrl-c to stop"
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_score(args: &Args, cfg: &Config) -> Result<()> {
    let path = args.get("fasta").ok_or_else(|| anyhow!("--fasta required"))?;
    let recs = specmer::msa::fasta::read_path(std::path::Path::new(path))?;
    let engine = build_engine(cfg)?;
    println!("id\tlength\tnll");
    for r in recs {
        let toks = specmer::tokenizer::encode_with_specials(&r.ungapped());
        let nll = engine.score_nll(&toks)?;
        println!("{}\t{}\t{nll:.4}", r.id, r.ungapped().len());
    }
    Ok(())
}

fn cmd_exp(args: &Args, cfg: Config) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("exp needs an id, e.g. `specmer exp table2`"))?
        .clone();
    let opts = ExpOpts {
        n_seqs: args.usize_or("n", 20)?,
        proteins: args
            .get("proteins")
            .map(|p| p.split(',').map(|s| s.trim().to_string()).collect())
            .unwrap_or_default(),
        full: args.flag("full"),
        out_dir: cfg.results_dir.clone(),
        seed: cfg.gen.seed,
    };
    let mut engine = build_engine(&cfg)?;
    experiments::run(&id, &mut engine, &opts)
}

fn cmd_families(cfg: &Config) -> Result<()> {
    let engine = build_engine(cfg)?;
    println!("protein\tfunction\tlength\tcontext\tmsa_depth");
    for f in engine.families() {
        let m = &f.meta;
        println!(
            "{}\t{}\t{}\t{}\t{}",
            m.name, m.function, m.length, m.context, m.msa_depth
        );
    }
    Ok(())
}

fn cmd_info(cfg: &Config) -> Result<()> {
    println!("artifacts: {}", cfg.artifacts.display());
    let manifest = specmer::params::load_manifest(&cfg.artifacts)?;
    println!("maxlen: {}  vocab: {}", manifest.maxlen, manifest.vocab);
    for (name, dims) in &manifest.models {
        println!(
            "model {name}: {} layers, d={}, heads={}, ff={}, params={}",
            dims.n_layer, dims.d_model, dims.n_head, dims.d_ff, dims.n_params
        );
    }
    if !cfg.cpu_ref {
        let rt = specmer::runtime::Runtime::new(&cfg.artifacts)?;
        println!("pjrt platform: {}", rt.platform());
        for prog in ["draft_generate_c3_g5", "target_verify_g5", "target_score"] {
            println!(
                "  artifact {prog}: {}",
                if rt.has_program(prog) { "ok" } else { "MISSING" }
            );
        }
    }
    Ok(())
}
