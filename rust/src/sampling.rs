//! Sampling primitives: temperature, nucleus (top-p), categorical draws,
//! and the token-level maximal coupling of Algorithm 1 (SpecTr).
//!
//! `adjust_dist` mirrors `python/compile/model.py::adjust_dist` exactly —
//! the integration tests check HLO-vs-Rust agreement — but on the serving
//! hot path the adjusted distributions come back from the HLO programs;
//! this module is used for residual sampling, the accept test, evaluation,
//! and the pure-Rust fallback engine.

use crate::util::rng::Pcg64;

/// Softmax with temperature into a fresh Vec.
pub fn softmax(logits: &[f32], temp: f32) -> Vec<f32> {
    let t = temp.max(1e-6);
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut out: Vec<f32> = logits.iter().map(|&l| ((l - m) / t).exp()).collect();
    let s: f32 = out.iter().sum();
    out.iter_mut().for_each(|x| *x /= s);
    out
}

/// Nucleus truncation: keep the smallest descending-prob prefix whose
/// exclusive cumulative sum is < top_p (first token always kept), zero the
/// rest, renormalize. Mirrors model.py::adjust_dist.
pub fn nucleus(probs: &mut [f32], top_p: f32) {
    let mut order: Vec<usize> = (0..probs.len()).collect();
    order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    let mut cum = 0.0f32;
    let mut thresh = f32::INFINITY;
    for &i in &order {
        if cum < top_p {
            thresh = probs[i];
        }
        cum += probs[i];
    }
    let mut total = 0.0f32;
    for p in probs.iter_mut() {
        if *p < thresh {
            *p = 0.0;
        }
        total += *p;
    }
    if total > 0.0 {
        probs.iter_mut().for_each(|p| *p /= total);
    }
}

/// Temperature + nucleus in one step: logits -> adjusted distribution.
pub fn adjust_dist(logits: &[f32], temp: f32, top_p: f32) -> Vec<f32> {
    let mut p = softmax(logits, temp);
    nucleus(&mut p, top_p);
    p
}

/// Inverse-CDF categorical draw: first *positive-probability* index whose
/// inclusive cumsum >= u (matches model.py::sample_from_dist on positive
/// entries). Zero-probability entries are skipped outright — with `u == 0.0`
/// the plain cumsum test would return index 0 even when `dist[0] == 0.0`,
/// committing a token outside the nucleus.
pub fn sample(dist: &[f32], u: f32) -> usize {
    let mut cum = 0.0f32;
    let mut last_positive = None;
    for (i, &p) in dist.iter().enumerate() {
        if p <= 0.0 {
            continue;
        }
        cum += p;
        last_positive = Some(i);
        if cum >= u {
            return i;
        }
    }
    // float undershoot (cum < u): fall back to the last positive entry so
    // the draw still lies in the distribution's support
    last_positive.unwrap_or(dist.len() - 1)
}

/// Residual distribution of Algorithm 1:
///   p_res(x) = (q(x) - min(p(x), q(x))) / (1 - Σ min(p, q))
/// Returns None when p == q (no residual mass; accept was certain).
pub fn residual(p: &[f32], q: &[f32]) -> Option<Vec<f32>> {
    debug_assert_eq!(p.len(), q.len());
    let mut res: Vec<f32> = p
        .iter()
        .zip(q)
        .map(|(&pi, &qi)| (qi - pi.min(qi)).max(0.0))
        .collect();
    let z: f32 = res.iter().sum();
    if z <= 1e-12 {
        return None;
    }
    res.iter_mut().for_each(|x| *x /= z);
    Some(res)
}

/// One step of token-level maximal coupling (Algorithm 1).
///
/// `x` was sampled from `p` (draft); `q` is the target distribution at the
/// same position. Returns `(accepted, token)`: the draft token if accepted,
/// otherwise a corrected token drawn from the residual distribution.
pub fn couple(p: &[f32], q: &[f32], x: usize, rng: &mut Pcg64) -> (bool, usize) {
    let eta = rng.next_f32();
    couple_with_eta(p, q, x, eta, rng)
}

/// Deterministic core of [`couple`], split out so the `eta` edge cases are
/// directly testable. The accept test requires `q[x] > 0`: `rng.next_f32()`
/// is uniform on [0, 1), so `eta` can be exactly 0.0, and the bare
/// `eta <= ratio` test would then accept a draft token the target nucleus
/// assigns zero probability.
pub fn couple_with_eta(p: &[f32], q: &[f32], x: usize, eta: f32, rng: &mut Pcg64) -> (bool, usize) {
    let px = p[x].max(1e-12);
    let qx = q[x];
    let ratio = (qx / px).min(1.0);
    if qx > 0.0 && eta <= ratio {
        return (true, x);
    }
    match residual(p, q) {
        Some(res) => (false, sample(&res, rng.next_f32())),
        // p==q exactly (so q[x] == p[x] > 0 for any sampleable x): the
        // acceptance probability was 1 and the branch above can only be
        // missed by floating-point edge; accept.
        None => (true, x),
    }
}

/// -log q(token) under an adjusted distribution (clamped for zeros).
pub fn nll_of(dist: &[f32], token: usize) -> f64 {
    -(dist[token].max(1e-12) as f64).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg64;

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        let s: f32 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn low_temp_sharpens() {
        let hot = softmax(&[1.0, 2.0], 2.0);
        let cold = softmax(&[1.0, 2.0], 0.5);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn nucleus_keeps_top_mass() {
        let mut p = vec![0.5, 0.3, 0.15, 0.05];
        nucleus(&mut p, 0.8);
        // exclusive cumsums: 0, .5, .8, .95 -> keep first two
        assert!(p[2] == 0.0 && p[3] == 0.0);
        assert!((p[0] + p[1] - 1.0).abs() < 1e-6);
        assert!((p[0] - 0.625).abs() < 1e-6);
    }

    #[test]
    fn nucleus_p1_keeps_everything() {
        let mut p = vec![0.25f32; 4];
        nucleus(&mut p, 1.0);
        assert!(p.iter().all(|&x| (x - 0.25).abs() < 1e-7));
    }

    #[test]
    fn nucleus_always_keeps_argmax() {
        let mut p = vec![0.9, 0.05, 0.05];
        nucleus(&mut p, 0.01);
        assert!((p[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn sample_boundaries() {
        let d = [0.25f32, 0.25, 0.5];
        assert_eq!(sample(&d, 0.0), 0);
        assert_eq!(sample(&d, 0.25), 0); // inclusive cum >= u
        assert_eq!(sample(&d, 0.2500001), 1);
        assert_eq!(sample(&d, 0.9999), 2);
    }

    #[test]
    fn sample_skips_zero_probability_entries() {
        // regression: u == 0.0 must not land on a zero-probability index 0
        let d = [0.0f32, 0.7, 0.3];
        assert_eq!(sample(&d, 0.0), 1);
        assert_eq!(sample(&d, 0.69), 1);
        assert_eq!(sample(&d, 0.71), 2);
        // zero hole in the middle is never selected
        let d2 = [0.5f32, 0.0, 0.5];
        assert_eq!(sample(&d2, 0.5), 0);
        assert_eq!(sample(&d2, 0.5000001), 2);
        // float undershoot falls back to the last positive entry, not the
        // last index (which may have zero probability)
        let d3 = [0.4f32, 0.59, 0.0];
        assert_eq!(sample(&d3, 1.0), 1);
    }

    #[test]
    fn couple_rejects_zero_target_prob_even_at_eta_zero() {
        // regression: eta == 0.0 used to pass `eta <= ratio` with ratio == 0
        let p = [0.5f32, 0.5, 0.0];
        let q = [0.0f32, 0.5, 0.5];
        let mut rng = Pcg64::new(1);
        let (acc, tok) = couple_with_eta(&p, &q, 0, 0.0, &mut rng);
        assert!(!acc, "q[x] == 0 must never be accepted");
        assert!(q[tok] > 0.0, "corrected token must lie in target support");
    }

    /// Support invariant behind spec.rs's committed_tokens_lie_in_target_
    /// nucleus test: whatever the draft proposes, the coupled output has
    /// positive target probability.
    #[test]
    fn coupled_output_always_in_target_support() {
        check("coupled output in q's support", 30, |g| {
            let v = 8;
            let p: Vec<f32> = g.sparse_dist(v).iter().map(|&x| x as f32).collect();
            let q: Vec<f32> = g.sparse_dist(v).iter().map(|&x| x as f32).collect();
            let mut rng = Pcg64::new(g.u64());
            for _ in 0..200 {
                let x = sample(&p, rng.next_f32());
                assert!(p[x] > 0.0, "draw must lie in draft support");
                let (_acc, y) = couple(&p, &q, x, &mut rng);
                assert!(q[y] > 0.0, "token {y} outside target support");
            }
        });
    }

    #[test]
    fn residual_matches_hand_calc() {
        let p = [0.6f32, 0.4, 0.0];
        let q = [0.2f32, 0.4, 0.4];
        let r = residual(&p, &q).unwrap();
        // min(p,q) = [.2,.4,0], 1-sum = .4 ; residual = [0,0,.4]/.4
        assert!((r[2] - 1.0).abs() < 1e-6);
        assert_eq!(r[0], 0.0);
        assert_eq!(r[1], 0.0);
    }

    #[test]
    fn residual_none_when_equal() {
        let p = [0.5f32, 0.5];
        assert!(residual(&p, &p).is_none());
    }

    /// The defining property of maximal coupling: the *output* of
    /// accept/correct is distributed exactly as q, regardless of p.
    #[test]
    fn coupling_output_is_q_distributed() {
        check("coupling marginals equal q", 20, |g| {
            let v = 8;
            let p: Vec<f32> = g.sparse_dist(v).iter().map(|&x| x as f32).collect();
            let q: Vec<f32> = g.sparse_dist(v).iter().map(|&x| x as f32).collect();
            let mut rng = Pcg64::new(g.u64());
            let n = 40_000;
            let mut counts = vec![0f64; v];
            for _ in 0..n {
                let x = sample(&p, rng.next_f32());
                let (_acc, y) = couple(&p, &q, x, &mut rng);
                counts[y] += 1.0;
            }
            for i in 0..v {
                let emp = counts[i] / n as f64;
                assert!(
                    (emp - q[i] as f64).abs() < 0.02,
                    "token {i}: empirical {emp:.4} vs q {:.4}",
                    q[i]
                );
            }
        });
    }

    /// Expected acceptance = 1 - TV(p, q).
    #[test]
    fn acceptance_rate_is_one_minus_tv() {
        let mut g = crate::util::proptest::Gen::new(42);
        for _ in 0..10 {
            let v = 6;
            let p: Vec<f32> = g.dist(v).iter().map(|&x| x as f32).collect();
            let q: Vec<f32> = g.dist(v).iter().map(|&x| x as f32).collect();
            let tv: f64 = p
                .iter()
                .zip(&q)
                .map(|(&a, &b)| ((a - b) as f64).abs())
                .sum::<f64>()
                / 2.0;
            let mut rng = Pcg64::new(g.u64());
            let n = 60_000;
            let mut acc = 0u64;
            for _ in 0..n {
                let x = sample(&p, rng.next_f32());
                if couple(&p, &q, x, &mut rng).0 {
                    acc += 1;
                }
            }
            let rate = acc as f64 / n as f64;
            assert!(
                (rate - (1.0 - tv)).abs() < 0.015,
                "rate {rate:.4} vs 1-TV {:.4}",
                1.0 - tv
            );
        }
    }

    #[test]
    fn nll_clamps_zero() {
        assert!(nll_of(&[0.0, 1.0], 0).is_finite());
        assert!((nll_of(&[0.5, 0.5], 0) - 0.5f64.ln().abs()).abs() < 1e-6);
    }
}
