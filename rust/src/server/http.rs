//! Hand-rolled HTTP/1.1 endpoint over `std::net::TcpListener`, hardened
//! for overload (docs/serving.md).
//!
//! Request path (DESIGN.md §5): a client `POST /generate` with `n`
//! sequences fans out into `n` single-sequence requests through the
//! [`Router`], which resolves each into a per-sequence `SeqSpec` once at
//! submission and places it on a live worker by protein affinity with
//! least-loaded spill. Workers batch by lockstep dispatch shape and run
//! shape groups with continuous batching; per-sequence RNG state keeps
//! every response bitwise-identical to an unbatched run with the same
//! seed. Responses are collected per request and folded into one JSON
//! reply; `GET /metrics` exposes the full counter/gauge dump.
//!
//! Overload semantics — every admission decision surfaces as a *typed*
//! reply, never a hang or an unbounded queue:
//!
//!   * **bounded admission** — worker queues are capacity-bounded and the
//!     router enforces an optional in-flight limit; shed requests answer
//!     `429 Too Many Requests` with a `Retry-After` header.
//!   * **deadlines** — a per-request `timeout_ms` (body field, defaulting
//!     to `--timeout-ms`) becomes a deadline enforced at submission, at
//!     batch pop, and at every lockstep round boundary; expired requests
//!     answer `504 Gateway Timeout`.
//!   * **bounded I/O** — read *and* write timeouts on every connection,
//!     and bodies above [`MAX_BODY_BYTES`] answer `413 Content Too Large`
//!     without being read.
//!   * **liveness** — `GET /health` reports `ok`/`degraded` (degraded =
//!     every worker dead, or every queue at capacity); `GET /ready`
//!     answers `503` while degraded so load balancers stop routing here.
//!   * **graceful shutdown** — [`ServerHandle::stop`] stops accepting,
//!     drains in-flight groups to completion (or their deadlines), and
//!     sheds queued requests with `429`s instead of dropping them.
//!
//! The protocol subset is deliberately small: one request per connection
//! (`Connection: close`), Content-Length bodies only — enough for any HTTP
//! client and for `bench_serve`'s open-loop load generator.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{Config, Method};
use crate::coordinator::{GenError, Metrics, Router};
use crate::decode::GenConfig;
use crate::kmer::KmerSet;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

/// Bodies above this answer `413` without being read into memory.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Per-connection socket read/write timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// How long [`ServerHandle::stop`] waits for in-flight groups to finish.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    router: Arc<Router>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Graceful shutdown: stop accepting, shed everything still queued
    /// (typed `429` replies), let in-flight groups run to completion or
    /// their deadlines, then join the acceptor. Every request that was
    /// ever admitted gets an answer.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.router.scheduler.begin_drain();
        // poke the acceptor loose; its pool joins in-flight connections,
        // which unblock as the drain answers their requests
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        self.router.scheduler.await_idle(DRAIN_TIMEOUT);
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Start the HTTP server on `cfg.port` (0 = ephemeral). Non-blocking:
/// returns a handle; the acceptor runs on its own thread.
pub fn serve(cfg: &Config, router: Arc<Router>, metrics: Arc<Metrics>) -> Result<ServerHandle> {
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let defaults = cfg.gen.clone();
    let default_timeout_ms = cfg.timeout_ms;
    let router2 = Arc::clone(&router);
    let thread = std::thread::Builder::new()
        .name("specmer-http".into())
        .spawn(move || {
            let pool = ThreadPool::new(4);
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let router = Arc::clone(&router2);
                let metrics = Arc::clone(&metrics);
                let defaults = defaults.clone();
                pool.execute(move || {
                    let _ = handle_conn(stream, &router, &metrics, &defaults, default_timeout_ms);
                });
            }
        })?;
    Ok(ServerHandle { addr, stop, router, thread: Some(thread) })
}

/// Degraded = the fleet can make no progress on a new request: every
/// worker is dead, or every bounded queue is at capacity.
fn degraded(router: &Router) -> bool {
    let sched = &router.scheduler;
    let all_dead = sched.alive().iter().all(|a| !a);
    let cap = sched.queue_capacity();
    let all_full = sched.queue_depths().iter().all(|&d| d >= cap);
    all_dead || all_full
}

fn health_json(router: &Router) -> Json {
    let sched = &router.scheduler;
    let alive = sched.alive().iter().filter(|a| **a).count();
    Json::obj(vec![
        ("status", Json::str(if degraded(router) { "degraded" } else { "ok" })),
        ("workers", Json::num(sched.n_workers() as f64)),
        ("workers_alive", Json::num(alive as f64)),
        ("queued", Json::num(sched.queue_depths().iter().sum::<usize>() as f64)),
        ("draining", Json::Bool(sched.draining())),
    ])
}

fn handle_conn(
    mut stream: TcpStream,
    router: &Router,
    metrics: &Metrics,
    defaults: &GenConfig,
    default_timeout_ms: u64,
) -> Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    // body cap before allocation: an oversized declared length is refused
    // without reading a byte of it
    if content_len > MAX_BODY_BYTES {
        let response =
            Json::obj(vec![("error", Json::str("body too large"))]).to_string();
        return write_response(&mut stream, "413 Content Too Large", None, &path, &response);
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    let body = String::from_utf8_lossy(&body).to_string();

    let (status, retry_after_ms, response) = match (method.as_str(), path.as_str()) {
        ("GET", "/health") => ("200 OK", None, health_json(router).to_string()),
        ("GET", "/ready") => {
            let status = if degraded(router) { "503 Service Unavailable" } else { "200 OK" };
            (status, None, health_json(router).to_string())
        }
        ("GET", "/metrics") => ("200 OK", None, metrics.text_dump()),
        ("POST", "/generate") => {
            match handle_generate(&body, router, defaults, default_timeout_ms) {
                Ok(j) => ("200 OK", None, j.to_string()),
                Err(e) => {
                    let (status, retry) = match GenError::of(&e) {
                        Some(GenError::Overloaded { retry_after_ms }) => {
                            ("429 Too Many Requests", Some(retry_after_ms))
                        }
                        Some(GenError::DeadlineExceeded) => ("504 Gateway Timeout", None),
                        None => ("400 Bad Request", None),
                    };
                    let j = Json::obj(vec![("error", Json::str(&format!("{e:#}")))]);
                    (status, retry, j.to_string())
                }
            }
        }
        _ => {
            let j = Json::obj(vec![("error", Json::str("not found"))]);
            ("404 Not Found", None, j.to_string())
        }
    };
    write_response(&mut stream, status, retry_after_ms, &path, &response)
}

fn write_response(
    stream: &mut TcpStream,
    status: &str,
    retry_after_ms: Option<u64>,
    path: &str,
    response: &str,
) -> Result<()> {
    let content_type = if path == "/metrics" { "text/plain" } else { "application/json" };
    // Retry-After is whole seconds, rounded up so clients never retry early
    let extra = match retry_after_ms {
        Some(ms) => format!("Retry-After: {}\r\n", ((ms + 999) / 1000).max(1)),
        None => String::new(),
    };
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n{extra}Connection: close\r\n\r\n{response}",
        response.len()
    )?;
    Ok(())
}

/// POST /generate body:
/// {"protein":"GFP","method":"specmer","n":2,"c":3,"gamma":5,
///  "temp":1.0,"top_p":0.95,"k":"1,3","seed":0,"timeout_ms":2000,
///  "tree_branch":2,"tree_splits":"3"}
///
/// `timeout_ms` (default `--timeout-ms`, 0 = none) sets a completion
/// deadline on every fanned-out request; an expired request answers `504`.
///
/// `tree_branch`/`tree_splits` opt a request into tree-shaped speculation
/// (see `decode::TreePolicy`): `tree_splits` is a comma-separated list of
/// split depths `1 <= d < gamma` and `tree_branch` (default 2 once splits
/// are given) is the children spawned per frontier node at each split.
/// Omitting `tree_splits` keeps the flat-chain path; requests sharing a
/// `(c, gamma, tree)` shape ride one lockstep group.
fn handle_generate(
    body: &str,
    router: &Router,
    defaults: &GenConfig,
    default_timeout_ms: u64,
) -> Result<Json> {
    let req = Json::parse(body).map_err(|e| anyhow!("bad json: {e}"))?;
    let protein = req
        .get("protein")
        .and_then(|p| p.as_str())
        .ok_or_else(|| anyhow!("missing 'protein'"))?
        .to_string();
    let method = Method::parse(req.get("method").and_then(|m| m.as_str()).unwrap_or("specmer"))
        .ok_or_else(|| anyhow!("bad 'method'"))?;
    let n = req.get("n").and_then(|v| v.as_usize()).unwrap_or(1).clamp(1, 512);
    let timeout_ms = req
        .get("timeout_ms")
        .and_then(|v| v.as_f64())
        .map(|v| v as u64)
        .unwrap_or(default_timeout_ms);
    let deadline = (timeout_ms > 0).then(|| Instant::now() + Duration::from_millis(timeout_ms));

    let mut cfg = defaults.clone();
    if let Some(v) = req.get("c").and_then(|v| v.as_usize()) {
        cfg.c = v;
    }
    if let Some(v) = req.get("gamma").and_then(|v| v.as_usize()) {
        cfg.gamma = v;
    }
    if let Some(v) = req.get("temp").and_then(|v| v.as_f64()) {
        cfg.temp = v as f32;
    }
    if let Some(v) = req.get("top_p").and_then(|v| v.as_f64()) {
        cfg.top_p = v as f32;
    }
    if let Some(v) = req.get("seed").and_then(|v| v.as_f64()) {
        cfg.seed = v as u64;
    }
    if let Some(k) = req.get("k").and_then(|v| v.as_str()) {
        cfg.kset = KmerSet::parse(k).ok_or_else(|| anyhow!("bad 'k'"))?;
    }
    if let Some(s) = req.get("tree_splits").and_then(|v| v.as_str()) {
        let mut mask = 0u16;
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let d: u32 = part.parse().map_err(|_| anyhow!("bad 'tree_splits' depth {part:?}"))?;
            if d == 0 || d >= 16 {
                return Err(anyhow!("bad 'tree_splits': depth {d} out of range 1..16"));
            }
            mask |= 1 << d;
        }
        cfg.tree.split_mask = mask;
        if mask != 0 && cfg.tree.branch < 2 {
            cfg.tree.branch = 2;
        }
    }
    if let Some(v) = req.get("tree_branch").and_then(|v| v.as_usize()) {
        cfg.tree.branch = u8::try_from(v).map_err(|_| anyhow!("bad 'tree_branch'"))?;
    }

    // lint:allow(unbounded): fan-out reply channel holds at most n <= 512
    let (tx, rx) = channel();
    for i in 0..n {
        let mut c = cfg.clone();
        c.seed = cfg.seed.wrapping_add(i as u64);
        router.submit_with_deadline(&protein, method, c, deadline, tx.clone());
    }
    drop(tx);

    let mut seqs = Vec::new();
    let mut accept = Vec::new();
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let mut decode_s = 0.0f64;
    for resp in rx.iter() {
        match resp.result {
            Ok(out) => {
                seqs.push(Json::str(&crate::tokenizer::decode(&out.tokens)));
                accept.push(out.acceptance_ratio());
                tokens += out.new_tokens();
                decode_s += resp.decode_seconds;
                latencies.push(resp.latency);
            }
            // context (not anyhow!) so the typed GenError payload survives
            // and the status mapping above can see it
            Err(e) => return Err(e.context("generation failed")),
        }
    }
    Ok(Json::obj(vec![
        ("protein", Json::str(&protein)),
        ("method", Json::str(method.label())),
        ("sequences", Json::Arr(seqs)),
        ("acceptance_ratio", Json::num(crate::util::stats::mean(&accept))),
        ("tokens", Json::num(tokens as f64)),
        (
            "tokens_per_second",
            Json::num(if decode_s > 0.0 { tokens as f64 / decode_s } else { 0.0 }),
        ),
        ("latency_p50", Json::num(crate::util::stats::percentile(&latencies, 50.0))),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{
        synthetic_engine, synthetic_families, FamilyRegistry, GenEngine,
    };
    use crate::coordinator::scheduler::{EngineFactory, SchedulerOpts};
    use crate::coordinator::Scheduler;

    fn start() -> (ServerHandle, Arc<Metrics>) {
        start_cfg(Config { port: 0, ..Default::default() }, Duration::from_millis(1))
    }

    fn start_cfg(cfg: Config, max_wait: Duration) -> (ServerHandle, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        let opts = SchedulerOpts { max_batch: 4, max_wait, ..Default::default() };
        let sched = Arc::new(Scheduler::start_with(1, opts, factory, Arc::clone(&metrics)));
        let registry = Arc::new(FamilyRegistry::new(synthetic_families(3)));
        let router = Arc::new(Router::new(sched, registry));
        let h = serve(&cfg, router, Arc::clone(&metrics)).unwrap();
        (h, metrics)
    }

    fn request(addr: std::net::SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
        request(
            addr,
            &format!(
                "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            ),
        )
    }

    #[test]
    fn health_and_metrics() {
        let (h, _m) = start();
        let r = request(h.addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""));
        let r = request(h.addr, "GET /ready HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        let r = request(h.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("specmer_requests_total"));
        assert!(r.contains("specmer_shed_total"));
        assert!(r.contains("specmer_deadline_exceeded_total"));
        assert!(r.contains("specmer_queue_depth"));
        assert!(r.contains("specmer_prefix_cache_hits_total"));
        assert!(r.contains("specmer_prefix_cache_bytes"));
        assert!(r.contains("specmer_admission_prefill_tokens_avg"));
        h.stop();
    }

    #[test]
    fn generate_endpoint_end_to_end() {
        let (h, m) = start();
        let r = post(
            h.addr,
            "/generate",
            r#"{"protein":"SynA","method":"specmer","n":2,"c":3,"gamma":5,"seed":1}"#,
        );
        assert!(r.contains("200 OK"), "{r}");
        let body = r.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body).unwrap();
        assert_eq!(j.get("sequences").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("tokens").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        h.stop();
    }

    #[test]
    fn generate_with_tree_policy() {
        let (h, m) = start();
        let r = post(
            h.addr,
            "/generate",
            r#"{"protein":"SynA","method":"specmer","n":2,"c":2,"gamma":5,"seed":3,"tree_splits":"3","tree_branch":2}"#,
        );
        assert!(r.contains("200 OK"), "{r}");
        let body = r.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body).unwrap();
        assert_eq!(j.get("sequences").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        // tree rounds feed the per-round gauges
        let dump = m.text_dump();
        assert!(dump.contains("specmer_tree_nodes_per_round_avg"), "{dump}");
        assert!(m.tree_nodes.load(Ordering::Relaxed) > 0);
        h.stop();
    }

    #[test]
    fn bad_requests_rejected() {
        let (h, _m) = start();
        let r = post(h.addr, "/generate", "{notjson");
        assert!(r.contains("400"));
        let r = post(
            h.addr,
            "/generate",
            r#"{"protein":"SynA","tree_splits":"0"}"#,
        );
        assert!(r.contains("400") && r.contains("tree_splits"), "{r}");
        let r = post(h.addr, "/generate", r#"{"method":"specmer"}"#);
        assert!(r.contains("400") && r.contains("protein"));
        let r = request(h.addr, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"));
        h.stop();
    }

    #[test]
    fn unknown_protein_is_400() {
        let (h, _m) = start();
        let r = post(h.addr, "/generate", r#"{"protein":"Zzz","n":1}"#);
        assert!(r.contains("400"), "{r}");
        h.stop();
    }

    #[test]
    fn oversized_body_answers_413_without_reading() {
        let (h, _m) = start();
        // declared length over the cap; only a few bytes actually sent
        let r = request(
            h.addr,
            &format!(
                "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\nxx",
                MAX_BODY_BYTES + 1
            ),
        );
        assert!(r.contains("413"), "{r}");
        assert!(r.contains("body too large"), "{r}");
        h.stop();
    }

    #[test]
    fn expired_timeout_answers_504() {
        // max_wait far above the timeout: the deadline expires while the
        // request sits queued, so the pop refuses it and the client gets 504
        let (h, m) = start_cfg(
            Config { port: 0, ..Default::default() },
            Duration::from_millis(150),
        );
        let r = post(
            h.addr,
            "/generate",
            r#"{"protein":"SynA","n":1,"seed":1,"timeout_ms":1}"#,
        );
        assert!(r.contains("504"), "{r}");
        assert!(r.contains("deadline exceeded"), "{r}");
        assert!(m.deadline_exceeded.load(Ordering::Relaxed) >= 1);
        h.stop();
    }

    #[test]
    fn ready_reports_degraded_when_all_workers_dead() {
        // a fleet whose only worker never builds an engine is degraded:
        // /health says so and /ready answers 503
        let metrics = Arc::new(Metrics::new());
        let factory: EngineFactory = Arc::new(|| Err(anyhow!("no artifacts")));
        let sched = Arc::new(Scheduler::start(
            1,
            4,
            Duration::from_millis(1),
            factory,
            Arc::clone(&metrics),
        ));
        // wait for the worker to come up dead
        let t0 = Instant::now();
        while sched.alive().iter().any(|a| *a) {
            assert!(t0.elapsed() < Duration::from_secs(30), "worker never died");
            std::thread::sleep(Duration::from_millis(1));
        }
        let registry = Arc::new(FamilyRegistry::new(synthetic_families(3)));
        let router = Arc::new(Router::new(sched, registry));
        let cfg = Config { port: 0, ..Default::default() };
        let h = serve(&cfg, router, metrics).unwrap();
        let r = request(h.addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("degraded"), "{r}");
        let r = request(h.addr, "GET /ready HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("503"), "{r}");
        h.stop();
    }

    #[test]
    fn graceful_stop_answers_queued_requests() {
        // huge max_wait keeps the submitted request queued; stop() must
        // shed it (typed 429 with Retry-After) instead of hanging the client
        let (h, m) = start_cfg(
            Config { port: 0, ..Default::default() },
            Duration::from_secs(3600),
        );
        let addr = h.addr;
        let client = std::thread::spawn(move || {
            post(addr, "/generate", r#"{"protein":"SynA","n":1,"seed":1}"#)
        });
        // wait until the request is actually queued before stopping
        let t0 = Instant::now();
        while m.queue_depth.load(Ordering::Relaxed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(30), "request never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        h.stop();
        let r = client.join().unwrap();
        assert!(r.contains("429"), "{r}");
        assert!(r.contains("Retry-After:"), "{r}");
        assert!(m.shed.load(Ordering::Relaxed) >= 1);
    }
}
