//! Hand-rolled HTTP/1.1 endpoint over `std::net::TcpListener`.
//!
//! Request path (DESIGN.md §5, extended by the continuously-batched,
//! shape-keyed serving path): a client `POST /generate` with `n` sequences
//! fans out into `n` single-sequence requests through the [`Router`],
//! which resolves each into a per-sequence `SeqSpec` **once at
//! submission** — family registry lookup, shared `Arc` k-mer table
//! handle, normalized config; unknown proteins are answered immediately —
//! and places it on a *live* worker by protein affinity (spilling to the
//! least-loaded worker — judged on queued *plus* in-flight work — under
//! imbalance; workers whose engine failed to build answer with errors and
//! are skipped). Each worker's `Batcher` groups queued requests purely by
//! **lockstep dispatch shape** `(c, gamma)` — *not* by
//! `(protein, method)` — and shape batches run as an in-flight lockstep
//! group with **continuous batching**: at every draft/verify round
//! boundary the worker re-polls its queue and admits newly-arrived
//! shape-compatible requests into the group, whatever their protein
//! family or speculative method (each sequence scores candidates against
//! its own table riding on its spec; admission soft-prefers the group's
//! majority protein without starving others), while finished sequences
//! are answered the moment they complete. Baselines and probe items stay
//! on their separate non-drafting serial path. Each round issues one
//! batched draft dispatch of `[B·c, D]` rows and one ragged verify over
//! all active sequences; per-sequence RNG state keeps every response
//! bitwise-identical to an unbatched run with the same seed, admissions
//! included. Responses are collected per request and folded into one JSON
//! reply; `GET /metrics` exposes batch occupancy, admission counts
//! (including `cross_key_admitted_total` and the distinct-proteins-per-
//! group gauge), the time-weighted occupancy gauge, queue-wait and decode
//! seconds alongside the acceptance/throughput counters.
//!
//! The protocol subset is deliberately small: one request per connection
//! (`Connection: close`), Content-Length bodies only — enough for any HTTP
//! client and for the screening example's load generator.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::{Config, Method};
use crate::coordinator::{Metrics, Router};
use crate::decode::GenConfig;
use crate::kmer::KmerSet;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the acceptor loose
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Start the HTTP server on `cfg.port` (0 = ephemeral). Non-blocking:
/// returns a handle; the acceptor runs on its own thread.
pub fn serve(cfg: &Config, router: Arc<Router>, metrics: Arc<Metrics>) -> Result<ServerHandle> {
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let defaults = cfg.gen.clone();
    let thread = std::thread::Builder::new()
        .name("specmer-http".into())
        .spawn(move || {
            let pool = ThreadPool::new(4);
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let router = Arc::clone(&router);
                let metrics = Arc::clone(&metrics);
                let defaults = defaults.clone();
                pool.execute(move || {
                    let _ = handle_conn(stream, &router, &metrics, &defaults);
                });
            }
        })?;
    Ok(ServerHandle { addr, stop, thread: Some(thread) })
}

fn handle_conn(
    mut stream: TcpStream,
    router: &Router,
    metrics: &Metrics,
    defaults: &GenConfig,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    let body = String::from_utf8_lossy(&body).to_string();

    let (status, response) = match (method.as_str(), path.as_str()) {
        ("GET", "/health") => ("200 OK", Json::obj(vec![("status", Json::str("ok"))]).to_string()),
        ("GET", "/metrics") => ("200 OK", metrics.text_dump()),
        ("POST", "/generate") => match handle_generate(&body, router, defaults) {
            Ok(j) => ("200 OK", j.to_string()),
            Err(e) => (
                "400 Bad Request",
                Json::obj(vec![("error", Json::str(&format!("{e:#}")))]).to_string(),
            ),
        },
        _ => ("404 Not Found", Json::obj(vec![("error", Json::str("not found"))]).to_string()),
    };

    let content_type = if path == "/metrics" { "text/plain" } else { "application/json" };
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{response}",
        response.len()
    )?;
    Ok(())
}

/// POST /generate body:
/// {"protein":"GFP","method":"specmer","n":2,"c":3,"gamma":5,
///  "temp":1.0,"top_p":0.95,"k":"1,3","seed":0,
///  "tree_branch":2,"tree_splits":"3"}
///
/// `tree_branch`/`tree_splits` opt a request into tree-shaped speculation
/// (see `decode::TreePolicy`): `tree_splits` is a comma-separated list of
/// split depths `1 <= d < gamma` and `tree_branch` (default 2 once splits
/// are given) is the children spawned per frontier node at each split.
/// Omitting `tree_splits` keeps the flat-chain path; requests sharing a
/// `(c, gamma, tree)` shape ride one lockstep group.
fn handle_generate(body: &str, router: &Router, defaults: &GenConfig) -> Result<Json> {
    let req = Json::parse(body).map_err(|e| anyhow!("bad json: {e}"))?;
    let protein = req
        .get("protein")
        .and_then(|p| p.as_str())
        .ok_or_else(|| anyhow!("missing 'protein'"))?
        .to_string();
    let method = Method::parse(req.get("method").and_then(|m| m.as_str()).unwrap_or("specmer"))
        .ok_or_else(|| anyhow!("bad 'method'"))?;
    let n = req.get("n").and_then(|v| v.as_usize()).unwrap_or(1).clamp(1, 512);

    let mut cfg = defaults.clone();
    if let Some(v) = req.get("c").and_then(|v| v.as_usize()) {
        cfg.c = v;
    }
    if let Some(v) = req.get("gamma").and_then(|v| v.as_usize()) {
        cfg.gamma = v;
    }
    if let Some(v) = req.get("temp").and_then(|v| v.as_f64()) {
        cfg.temp = v as f32;
    }
    if let Some(v) = req.get("top_p").and_then(|v| v.as_f64()) {
        cfg.top_p = v as f32;
    }
    if let Some(v) = req.get("seed").and_then(|v| v.as_f64()) {
        cfg.seed = v as u64;
    }
    if let Some(k) = req.get("k").and_then(|v| v.as_str()) {
        cfg.kset = KmerSet::parse(k).ok_or_else(|| anyhow!("bad 'k'"))?;
    }
    if let Some(s) = req.get("tree_splits").and_then(|v| v.as_str()) {
        let mut mask = 0u16;
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let d: u32 = part.parse().map_err(|_| anyhow!("bad 'tree_splits' depth {part:?}"))?;
            if d == 0 || d >= 16 {
                return Err(anyhow!("bad 'tree_splits': depth {d} out of range 1..16"));
            }
            mask |= 1 << d;
        }
        cfg.tree.split_mask = mask;
        if mask != 0 && cfg.tree.branch < 2 {
            cfg.tree.branch = 2;
        }
    }
    if let Some(v) = req.get("tree_branch").and_then(|v| v.as_usize()) {
        cfg.tree.branch = u8::try_from(v).map_err(|_| anyhow!("bad 'tree_branch'"))?;
    }

    let (tx, rx) = channel();
    for i in 0..n {
        let mut c = cfg.clone();
        c.seed = cfg.seed.wrapping_add(i as u64);
        router.submit(&protein, method, c, tx.clone());
    }
    drop(tx);

    let mut seqs = Vec::new();
    let mut accept = Vec::new();
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let mut decode_s = 0.0f64;
    for resp in rx.iter() {
        match resp.result {
            Ok(out) => {
                seqs.push(Json::str(&crate::tokenizer::decode(&out.tokens)));
                accept.push(out.acceptance_ratio());
                tokens += out.new_tokens();
                decode_s += resp.decode_seconds;
                latencies.push(resp.latency);
            }
            Err(e) => return Err(anyhow!("generation failed: {e:#}")),
        }
    }
    Ok(Json::obj(vec![
        ("protein", Json::str(&protein)),
        ("method", Json::str(method.label())),
        ("sequences", Json::Arr(seqs)),
        ("acceptance_ratio", Json::num(crate::util::stats::mean(&accept))),
        ("tokens", Json::num(tokens as f64)),
        (
            "tokens_per_second",
            Json::num(if decode_s > 0.0 { tokens as f64 / decode_s } else { 0.0 }),
        ),
        ("latency_p50", Json::num(crate::util::stats::percentile(&latencies, 50.0))),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{
        synthetic_engine, synthetic_families, FamilyRegistry, GenEngine,
    };
    use crate::coordinator::Scheduler;
    use crate::coordinator::scheduler::EngineFactory;

    fn start() -> (ServerHandle, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        let sched = Arc::new(Scheduler::start(
            1,
            4,
            Duration::from_millis(1),
            factory,
            Arc::clone(&metrics),
        ));
        let registry = Arc::new(FamilyRegistry::new(synthetic_families(3)));
        let router = Arc::new(Router::new(sched, registry));
        let cfg = Config { port: 0, ..Default::default() };
        let h = serve(&cfg, router, Arc::clone(&metrics)).unwrap();
        (h, metrics)
    }

    fn request(addr: std::net::SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
        request(
            addr,
            &format!(
                "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            ),
        )
    }

    #[test]
    fn health_and_metrics() {
        let (h, _m) = start();
        let r = request(h.addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""));
        let r = request(h.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("specmer_requests_total"));
        h.stop();
    }

    #[test]
    fn generate_endpoint_end_to_end() {
        let (h, m) = start();
        let r = post(
            h.addr,
            "/generate",
            r#"{"protein":"SynA","method":"specmer","n":2,"c":3,"gamma":5,"seed":1}"#,
        );
        assert!(r.contains("200 OK"), "{r}");
        let body = r.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body).unwrap();
        assert_eq!(j.get("sequences").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("tokens").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        h.stop();
    }

    #[test]
    fn generate_with_tree_policy() {
        let (h, m) = start();
        let r = post(
            h.addr,
            "/generate",
            r#"{"protein":"SynA","method":"specmer","n":2,"c":2,"gamma":5,"seed":3,"tree_splits":"3","tree_branch":2}"#,
        );
        assert!(r.contains("200 OK"), "{r}");
        let body = r.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body).unwrap();
        assert_eq!(j.get("sequences").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        // tree rounds feed the per-round gauges
        let dump = m.text_dump();
        assert!(dump.contains("specmer_tree_nodes_per_round_avg"), "{dump}");
        assert!(m.tree_nodes.load(Ordering::Relaxed) > 0);
        h.stop();
    }

    #[test]
    fn bad_requests_rejected() {
        let (h, _m) = start();
        let r = post(h.addr, "/generate", "{notjson");
        assert!(r.contains("400"));
        let r = post(
            h.addr,
            "/generate",
            r#"{"protein":"SynA","tree_splits":"0"}"#,
        );
        assert!(r.contains("400") && r.contains("tree_splits"), "{r}");
        let r = post(h.addr, "/generate", r#"{"method":"specmer"}"#);
        assert!(r.contains("400") && r.contains("protein"));
        let r = request(h.addr, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"));
        h.stop();
    }

    #[test]
    fn unknown_protein_is_400() {
        let (h, _m) = start();
        let r = post(h.addr, "/generate", r#"{"protein":"Zzz","n":1}"#);
        assert!(r.contains("400"), "{r}");
        h.stop();
    }
}
