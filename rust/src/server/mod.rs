//! Minimal HTTP/1.1 server (std::net + thread pool) exposing the
//! coordinator: POST /generate, GET /metrics, GET /health, GET /families.

pub mod http;

pub use http::{serve, ServerHandle};
