//! Shared experiment plumbing: run a (protein, method, config) cell,
//! collect sequences + metrics, and write results as markdown/CSV.

use std::fmt::Write as _;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::config::Method;
use crate::coordinator::GenEngine;
use crate::decode::{GenConfig, GenOutput};
use crate::kmer::KmerSet;
use crate::util::stats;

/// Options shared by all experiments.
#[derive(Clone, Debug)]
pub struct ExpOpts {
    /// Sequences per configuration cell (paper: 200; default reduced).
    pub n_seqs: usize,
    /// Restrict to these proteins (empty = all).
    pub proteins: Vec<String>,
    /// Full paper-sized hyperparameter grid instead of the reduced one.
    pub full: bool,
    pub out_dir: PathBuf,
    pub seed: u64,
}

impl Default for ExpOpts {
    fn default() -> ExpOpts {
        ExpOpts {
            n_seqs: 20,
            proteins: vec![],
            full: false,
            out_dir: PathBuf::from("results"),
            seed: 42,
        }
    }
}

impl ExpOpts {
    pub fn protein_list(&self, engine: &dyn GenEngine) -> Vec<String> {
        let all: Vec<String> = engine.families().iter().map(|f| f.meta.name.clone()).collect();
        if self.proteins.is_empty() {
            all
        } else {
            all.into_iter().filter(|p| self.proteins.contains(p)).collect()
        }
    }

    /// Hyperparameter grid (paper App. B.3; reduced by default for the
    /// single-core testbed — the full grid is 36 cells per protein/method).
    pub fn grid(&self) -> Vec<(usize, f32, KmerSet)> {
        let gammas: &[usize] = if self.full { &[5, 10, 15] } else { &[5, 10] };
        let temps: &[f32] = if self.full { &[0.7, 1.0, 1.4] } else { &[0.7, 1.0] };
        let ksets: Vec<KmerSet> = if self.full {
            KmerSet::SWEEP.to_vec()
        } else {
            vec![KmerSet::new(true, true, false), KmerSet::new(true, true, true)]
        };
        let mut out = Vec::new();
        for &g in gammas {
            for &t in temps {
                for &k in &ksets {
                    out.push((g, t, k));
                }
            }
        }
        out
    }
}

/// Everything measured for one configuration cell.
pub struct CellStats {
    pub outputs: Vec<GenOutput>,
    /// Post-hoc length-normalized NLL under the target model per sequence.
    pub nlls: Vec<f64>,
    pub accepts: Vec<f64>,
    pub decode_seconds: f64,
    pub tokens: usize,
}

impl CellStats {
    pub fn toks_per_sec(&self) -> f64 {
        if self.decode_seconds == 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.decode_seconds
        }
    }
    pub fn mean_accept(&self) -> f64 {
        stats::mean(&self.accepts)
    }
    pub fn mean_nll(&self) -> f64 {
        stats::mean(&self.nlls)
    }
    /// Residue sequences (specials stripped) for diversity/pLDDT analysis.
    pub fn residue_seqs(&self) -> Vec<Vec<u8>> {
        self.outputs
            .iter()
            .map(|o| {
                o.tokens
                    .iter()
                    .copied()
                    .filter(|&t| crate::tokenizer::is_residue(t))
                    .collect()
            })
            .collect()
    }
}

/// Generate `n` sequences for one cell and score them.
pub fn run_cell(
    engine: &dyn GenEngine,
    protein: &str,
    method: Method,
    cfg: &GenConfig,
    n: usize,
    base_seed: u64,
) -> Result<CellStats> {
    let mut outputs = Vec::with_capacity(n);
    let mut accepts = Vec::with_capacity(n);
    let mut decode_seconds = 0.0;
    let mut tokens = 0usize;
    // resolve the scoring plan once for the whole cell; per-sequence runs
    // only vary the seed
    let mut spec = engine.spec(protein, method, cfg)?;
    // warmup: first use of a (c, gamma) program pair compiles it (~1s);
    // keep that out of the timed region so toks/sec reflects steady state.
    {
        let mut w = spec.clone();
        w.cfg.seed = base_seed ^ 0xDEAD_BEEF;
        w.cfg.max_len = w.cfg.max_len.min(40);
        let _ = engine.generate(&w)?;
    }
    for i in 0..n {
        spec.cfg.seed = base_seed.wrapping_mul(0x9E37_79B9).wrapping_add(i as u64);
        let t0 = Instant::now();
        let out = engine.generate(&spec)?;
        decode_seconds += t0.elapsed().as_secs_f64();
        tokens += out.new_tokens();
        if method != Method::TargetOnly {
            accepts.push(out.acceptance_ratio());
        }
        outputs.push(out);
    }
    let nlls = outputs
        .iter()
        .map(|o| engine.score_nll(&o.tokens))
        .collect::<Result<Vec<_>>>()?;
    Ok(CellStats { outputs, nlls, accepts, decode_seconds, tokens })
}

/// Markdown + CSV sink under `results/`.
pub struct Sink {
    pub name: String,
    md: String,
    csv: String,
    out_dir: PathBuf,
}

impl Sink {
    pub fn new(out_dir: &PathBuf, name: &str, title: &str) -> Sink {
        let mut md = String::new();
        let _ = writeln!(md, "# {title}\n");
        Sink { name: name.to_string(), md, csv: String::new(), out_dir: out_dir.clone() }
    }

    pub fn line(&mut self, s: &str) {
        println!("{s}");
        self.md.push_str(s);
        self.md.push('\n');
    }

    pub fn csv_row(&mut self, fields: &[String]) {
        self.csv.push_str(&fields.join(","));
        self.csv.push('\n');
    }

    pub fn finish(self) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        std::fs::write(self.out_dir.join(format!("{}.md", self.name)), &self.md)?;
        if !self.csv.is_empty() {
            std::fs::write(self.out_dir.join(format!("{}.csv", self.name)), &self.csv)?;
        }
        Ok(())
    }
}

/// `a ± b` with fixed precision (paper table style).
pub fn pm(mean: f64, std: f64, prec: usize) -> String {
    format!("{mean:.p$} ± {std:.p$}", p = prec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::synthetic_engine;

    #[test]
    fn run_cell_collects_everything() {
        let eng = synthetic_engine(3);
        let cfg = GenConfig { max_len: 24, gamma: 5, c: 2, ..Default::default() };
        let cell = run_cell(&eng, "SynA", Method::SpecMer, &cfg, 3, 1).unwrap();
        assert_eq!(cell.outputs.len(), 3);
        assert_eq!(cell.nlls.len(), 3);
        assert_eq!(cell.accepts.len(), 3);
        assert!(cell.tokens > 0);
        assert!(cell.toks_per_sec() > 0.0);
        assert!(cell.nlls.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn seeds_vary_across_cell() {
        let eng = synthetic_engine(3);
        let cfg = GenConfig { max_len: 24, gamma: 5, c: 1, ..Default::default() };
        let cell = run_cell(&eng, "SynA", Method::Speculative, &cfg, 4, 7).unwrap();
        let distinct: std::collections::HashSet<_> =
            cell.outputs.iter().map(|o| o.tokens.clone()).collect();
        assert!(distinct.len() > 1, "different seeds should give different seqs");
    }

    #[test]
    fn grid_sizes() {
        let mut o = ExpOpts::default();
        assert_eq!(o.grid().len(), 8);
        o.full = true;
        assert_eq!(o.grid().len(), 36);
    }

    #[test]
    fn sink_writes_files() {
        let dir = std::env::temp_dir().join(format!("specmer_sink_{}", std::process::id()));
        let mut s = Sink::new(&dir, "test_table", "Test");
        s.line("| a | b |");
        s.csv_row(&["1".into(), "2".into()]);
        s.finish().unwrap();
        assert!(dir.join("test_table.md").exists());
        assert!(dir.join("test_table.csv").exists());
    }
}
