//! Experiment harness: regenerates every table and figure of the paper
//! (DESIGN.md §6 maps ids to functions). Invoked by `specmer exp <id>` and
//! the cargo bench targets.

pub mod figures;
pub mod runner;
pub mod tables;

pub use runner::{ExpOpts, Sink};

use anyhow::Result;

use crate::coordinator::GenEngine;

/// All experiment ids in run order.
pub const ALL: &[&str] = &[
    "table1", "table2", "table3", "table4", "table5", "table6", "table7",
    "table8", "table9", "fig1c", "fig2a", "fig2b", "fig3", "figs_sweep",
    "bounds",
];

/// Run one experiment by id.
pub fn run(id: &str, engine: &mut Box<dyn GenEngine>, opts: &ExpOpts) -> Result<()> {
    eprintln!("[exp] running {id} (n={}, full={})", opts.n_seqs, opts.full);
    let t0 = std::time::Instant::now();
    match id {
        "table1" => tables::table1(engine.as_ref(), opts)?,
        "table2" => tables::table2(engine.as_ref(), opts)?,
        "table3" | "table10" => tables::table3_10(engine.as_ref(), opts)?,
        "table4" => tables::table4(engine.as_ref(), opts)?,
        "table5" => tables::table5(engine.as_ref(), opts)?,
        "table6" => tables::table6(engine.as_ref(), opts)?,
        "table7" => tables::table7(engine.as_ref(), opts)?,
        "table8" | "msadepth" => tables::table8(engine, opts)?,
        "table9" => tables::table9(engine.as_ref(), opts)?,
        "fig1c" => figures::fig1c(engine.as_ref(), opts)?,
        "fig2a" => figures::fig2a(engine.as_ref(), opts)?,
        "fig2b" => figures::fig2b(engine.as_ref(), opts)?,
        "fig3" => figures::fig3(engine.as_ref(), opts)?,
        "figs_sweep" => figures::figs_sweep(engine.as_ref(), opts)?,
        "bounds" => tables::bounds(engine.as_ref(), opts)?,
        "all" => {
            for id in ALL {
                run(id, engine, opts)?;
            }
        }
        other => anyhow::bail!("unknown experiment '{other}' (known: {ALL:?} or 'all')"),
    }
    eprintln!("[exp] {id} done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// Entry point shared by the `cargo bench` targets (rust/benches/*.rs,
/// `harness = false`): runs the given experiments against the artifacts
/// engine (or the synthetic fallback), honoring SPECMER_BENCH_N /
/// SPECMER_BENCH_FULL / SPECMER_BENCH_PROTEINS env overrides.
pub fn bench_main(ids: &[&str]) {
    let n = std::env::var("SPECMER_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let full = std::env::var("SPECMER_BENCH_FULL").is_ok();
    let proteins: Vec<String> = std::env::var("SPECMER_BENCH_PROTEINS")
        .map(|p| p.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_default();
    let (mut engine, real) = crate::coordinator::engine_for_bench();
    let opts = ExpOpts {
        n_seqs: n,
        proteins,
        full,
        out_dir: if std::path::Path::new("results").exists()
            || std::path::Path::new("rust").exists()
        {
            "results".into()
        } else {
            "../results".into()
        },
        seed: 42,
    };
    eprintln!(
        "[bench] engine={} n={} full={}",
        if real { "artifacts" } else { "synthetic" },
        opts.n_seqs,
        opts.full
    );
    for id in ids {
        if let Err(e) = run(id, &mut engine, &opts) {
            eprintln!("[bench] {id} FAILED: {e:#}");
            std::process::exit(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::synthetic_engine;

    fn opts() -> ExpOpts {
        ExpOpts {
            n_seqs: 3,
            out_dir: std::env::temp_dir().join(format!("specmer_exp_{}", std::process::id())),
            ..Default::default()
        }
    }

    #[test]
    fn every_experiment_runs_on_synthetic_engine() {
        let mut engine: Box<dyn GenEngine> = Box::new(synthetic_engine(3));
        let o = opts();
        for id in ALL {
            run(id, &mut engine, &o).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        }
        // spot-check artifacts were written
        assert!(o.out_dir.join("table2.md").exists());
        assert!(o.out_dir.join("fig3.csv").exists());
    }

    #[test]
    fn unknown_id_errors() {
        let mut engine: Box<dyn GenEngine> = Box::new(synthetic_engine(3));
        assert!(run("table99", &mut engine, &opts()).is_err());
    }
}
