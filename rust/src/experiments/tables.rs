//! Regeneration of the paper's Tables 1–10 (see DESIGN.md §6 for the
//! experiment index). Every function prints the table and writes
//! results/<id>.{md,csv}.

use anyhow::Result;

use super::runner::{pm, run_cell, CellStats, ExpOpts, Sink};
use crate::config::Method;
use crate::coordinator::GenEngine;
use crate::decode::GenConfig;
use crate::eval::diversity;
use crate::kmer::{KmerSet, KmerTable};
use crate::theory;
use crate::tokenizer;
use crate::util::stats;

fn base_cfg(gamma: usize, temp: f32, kset: KmerSet, c: usize) -> GenConfig {
    GenConfig { gamma, c, temp, kset, top_p: 0.95, max_len: 10_000, ..Default::default() }
}

/// Table 1: protein/context/MSA summary (metadata; substitution-scaled).
pub fn table1(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "table1", "Table 1: proteins and contexts");
    sink.line("| Protein | Function | Paper len | Our len | Context | Paper MSA | Our MSA |");
    sink.line("|---|---|---|---|---|---|---|");
    sink.csv_row(&["protein,function,paper_len,len,context,paper_depth,depth".into()]);
    for f in engine.families() {
        let m = &f.meta;
        sink.line(&format!(
            "| {} | {} | {} | {} | {} | {} | {} |",
            m.name, m.function, m.paper_length, m.length, m.context, m.paper_msa_depth, m.msa_depth
        ));
        sink.csv_row(&[format!(
            "{},{},{},{},{},{},{}",
            m.name, m.function, m.paper_length, m.length, m.context, m.paper_msa_depth, m.msa_depth
        )]);
    }
    sink.finish()
}

/// Sweep all grid cells for one (protein, method, c); return the per-cell
/// stats tagged by (gamma, temp, kset-label).
fn sweep_cells(
    engine: &dyn GenEngine,
    protein: &str,
    method: Method,
    c: usize,
    opts: &ExpOpts,
) -> Result<Vec<((usize, f32, KmerSet), CellStats)>> {
    let mut out = Vec::new();
    for (gamma, temp, kset) in opts.grid() {
        let cfg = base_cfg(gamma, temp, kset, c);
        let cell = run_cell(engine, protein, method, &cfg, opts.n_seqs, opts.seed)?;
        out.push(((gamma, temp, kset), cell));
    }
    Ok(out)
}

fn best_by_accept(cells: &[((usize, f32, KmerSet), CellStats)]) -> &CellStats {
    &cells
        .iter()
        .max_by(|a, b| a.1.mean_accept().partial_cmp(&b.1.mean_accept()).unwrap())
        .unwrap()
        .1
}

fn best_by_nll(cells: &[((usize, f32, KmerSet), CellStats)]) -> &((usize, f32, KmerSet), CellStats) {
    cells
        .iter()
        .min_by(|a, b| a.1.mean_nll().partial_cmp(&b.1.mean_nll()).unwrap())
        .unwrap()
}

/// Table 2: acceptance ratio + NLL / top-20 / top-5 NLL for speculative
/// decoding (c=1) vs SpecMER (c=3, c=5), best config per category.
pub fn table2(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "table2", "Table 2: decoding results (best-of-sweep)");
    sink.line("| Method | Protein | Accept ↑ | NLL ↓ | Top-20 NLL ↓ | Top-5 NLL ↓ |");
    sink.line("|---|---|---|---|---|---|");
    sink.csv_row(&["method,protein,accept_mean,accept_std,nll_mean,nll_std,top20,top20_std,top5,top5_std".into()]);
    for (label, method, c) in [
        ("Speculative Decoding", Method::Speculative, 1usize),
        ("SpecMER (c=3)", Method::SpecMer, 3),
        ("SpecMER (c=5)", Method::SpecMer, 5),
    ] {
        for protein in opts.protein_list(engine) {
            let cells = sweep_cells(engine, &protein, method, c, opts)?;
            let acc_cell = best_by_accept(&cells);
            let (_, nll_cell) = best_by_nll(&cells);
            let k20 = opts.n_seqs.min(20).max(1);
            let k5 = opts.n_seqs.min(5).max(1);
            sink.line(&format!(
                "| {label} | {protein} | {} | {} | {} | {} |",
                pm(stats::mean(&acc_cell.accepts), stats::std(&acc_cell.accepts), 3),
                pm(stats::mean(&nll_cell.nlls), stats::std(&nll_cell.nlls), 2),
                pm(stats::mean_smallest(&nll_cell.nlls, k20), stats::std_smallest(&nll_cell.nlls, k20), 2),
                pm(stats::mean_smallest(&nll_cell.nlls, k5), stats::std_smallest(&nll_cell.nlls, k5), 2),
            ));
            sink.csv_row(&[format!(
                "{label},{protein},{},{},{},{},{},{},{},{}",
                stats::mean(&acc_cell.accepts),
                stats::std(&acc_cell.accepts),
                stats::mean(&nll_cell.nlls),
                stats::std(&nll_cell.nlls),
                stats::mean_smallest(&nll_cell.nlls, k20),
                stats::std_smallest(&nll_cell.nlls, k20),
                stats::mean_smallest(&nll_cell.nlls, k5),
                stats::std_smallest(&nll_cell.nlls, k5),
            )]);
        }
    }
    sink.finish()
}

/// Tables 3 & 10: mean and top-5 pLDDT-proxy per c ∈ {1,2,3,5} for the
/// four short proteins, sequences drawn from the best-NLL configurations.
pub fn table3_10(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut s3 = Sink::new(&opts.out_dir, "table3", "Table 3: average pLDDT-proxy");
    let mut s10 = Sink::new(&opts.out_dir, "table10", "Table 10: top-5 pLDDT-proxy");
    let short: Vec<String> = ["GFP", "RBP1", "ParD3", "GB1", "SynA", "SynB"]
        .iter()
        .map(|s| s.to_string())
        .filter(|p| opts.protein_list(engine).contains(p))
        .collect();
    let header = "| Protein | SpecDec (c=1) | SpecMER (c=2) | SpecMER (c=3) | SpecMER (c=5) |";
    for s in [&mut s3, &mut s10] {
        s.line(header);
        s.line("|---|---|---|---|---|");
    }
    s3.csv_row(&["protein,c,plddt_mean,plddt_std".into()]);
    s10.csv_row(&["protein,c,top5_mean,top5_std".into()]);
    for protein in &short {
        let scorer = engine.family(protein)?.plddt_scorer();
        let mut mean_cols = Vec::new();
        let mut top_cols = Vec::new();
        for &c in &[1usize, 2, 3, 5] {
            let method = if c == 1 { Method::Speculative } else { Method::SpecMer };
            let cells = sweep_cells(engine, protein, method, c, opts)?;
            // top-3 configs by mean NLL, pool their sequences (paper: ×100)
            let mut ranked: Vec<_> = cells.iter().collect();
            ranked.sort_by(|a, b| a.1.mean_nll().partial_cmp(&b.1.mean_nll()).unwrap());
            let mut scores: Vec<f64> = Vec::new();
            for (_, cell) in ranked.iter().take(3) {
                for seq in cell.residue_seqs() {
                    scores.push(scorer.score(&seq));
                }
            }
            mean_cols.push(pm(stats::mean(&scores), stats::std(&scores), 3));
            let k = scores.len().min(5).max(1);
            top_cols.push(pm(stats::mean_largest(&scores, k), stats::std_largest(&scores, k), 3));
            s3.csv_row(&[format!("{protein},{c},{},{}", stats::mean(&scores), stats::std(&scores))]);
            s10.csv_row(&[format!(
                "{protein},{c},{},{}",
                stats::mean_largest(&scores, k),
                stats::std_largest(&scores, k)
            )]);
        }
        s3.line(&format!("| {protein} | {} |", mean_cols.join(" | ")));
        s10.line(&format!("| {protein} | {} |", top_cols.join(" | ")));
    }
    s3.finish()?;
    s10.finish()
}

/// Table 4: top-20 NLL, target-only vs SpecMER (c=5), same temperature.
pub fn table4(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "table4", "Table 4: top-20 NLL, target vs SpecMER c=5");
    sink.line("| Method | ".to_string().as_str());
    let proteins = opts.protein_list(engine);
    sink.line(&format!("| Method | {} |", proteins.join(" | ")));
    sink.line(&format!("|---|{}|", proteins.iter().map(|_| "---").collect::<Vec<_>>().join("|")));
    sink.csv_row(&["method,protein,top20_mean,top20_std".into()]);
    let kset = KmerSet::new(true, true, true);
    let k20 = opts.n_seqs.min(20).max(1);
    let mut rows = vec![("Target".to_string(), Vec::new()), ("SpecMER (c=5)".to_string(), Vec::new())];
    for protein in &proteins {
        for (i, (method, c)) in [(Method::TargetOnly, 1usize), (Method::SpecMer, 5)].iter().enumerate() {
            let cfg = base_cfg(5, 1.0, kset, *c);
            let cell = run_cell(engine, protein, *method, &cfg, opts.n_seqs, opts.seed)?;
            let m = stats::mean_smallest(&cell.nlls, k20);
            let s = stats::std_smallest(&cell.nlls, k20);
            rows[i].1.push(pm(m, s, 2));
            sink.csv_row(&[format!("{},{protein},{m},{s}", rows[i].0)]);
        }
    }
    for (label, cols) in rows {
        sink.line(&format!("| {label} | {} |", cols.join(" | ")));
    }
    sink.finish()
}

/// Table 5: generation speed (tokens/sec) and speedup vs target-only,
/// averaged over GFP, RBP1, GB1 at each method's fastest configuration.
pub fn table5(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "table5", "Table 5: generation speed");
    let proteins: Vec<String> = ["GFP", "RBP1", "GB1", "SynA", "SynB"]
        .iter()
        .map(|s| s.to_string())
        .filter(|p| opts.protein_list(engine).contains(p))
        .collect();
    let n = opts.n_seqs;
    // fastest config: the paper found gamma=5..10, T=1.0 fastest; probe both gammas
    let mut report: Vec<(String, f64, f64)> = Vec::new(); // label, toks/s mean, std
    let mut target_tps = 0.0;
    // "Target" is the paper-faithful stepwise AR baseline (one dispatch per
    // token, ar_chunk=1); "Target(fused)" is our stronger scan-fused chunk
    // baseline, reported for honesty (the paper had no such variant).
    for (label, method, c, chunk) in [
        ("Draft", Method::DraftOnly, 1usize, 0usize),
        ("Target", Method::TargetOnly, 1, 1),
        ("Target(fused)", Method::TargetOnly, 1, 0),
        ("Baseline (spec c=1)", Method::Speculative, 1, 0),
        ("SpecMER (c=2)", Method::SpecMer, 2, 0),
        ("SpecMER (c=3)", Method::SpecMer, 3, 0),
        ("SpecMER (c=5)", Method::SpecMer, 5, 0),
    ] {
        let mut best_per_protein: Vec<f64> = Vec::new();
        for protein in &proteins {
            let mut best = 0.0f64;
            for gamma in [5usize, 10] {
                let mut cfg = base_cfg(gamma, 1.0, KmerSet::new(true, true, false), c);
                cfg.ar_chunk = chunk;
                let cell = run_cell(engine, protein, method, &cfg, n, opts.seed)?;
                best = best.max(cell.toks_per_sec());
            }
            best_per_protein.push(best);
        }
        let m = stats::mean(&best_per_protein);
        let s = stats::std(&best_per_protein);
        if label == "Target" {
            target_tps = m;
        }
        report.push((label.to_string(), m, s));
    }
    sink.line("| - | Draft | Target | Target(fused) | Baseline | SpecMER (c=2) | SpecMER (c=3) | SpecMER (c=5) |");
    sink.line("|---|---|---|---|---|---|---|---|");
    let toks: Vec<String> = report.iter().map(|(_, m, s)| pm(*m, *s, 2)).collect();
    sink.line(&format!("| Toks/sec | {} |", toks.join(" | ")));
    let speedups: Vec<String> = report
        .iter()
        .map(|(l, m, _)| {
            if l == "Draft" || l == "Target" || target_tps == 0.0 {
                "-".to_string()
            } else {
                format!("{:.0}%", (m / target_tps - 1.0) * 100.0)
            }
        })
        .collect();
    sink.line(&format!("| Speedup | {} |", speedups.join(" | ")));
    sink.csv_row(&["method,toks_per_sec,std,speedup_vs_target".into()]);
    for (l, m, s) in &report {
        sink.csv_row(&[format!("{l},{m},{s},{}", if target_tps > 0.0 { m / target_tps } else { 0.0 })]);
    }
    sink.finish()
}

/// Table 6: best hyperparameter configuration per protein (by mean NLL,
/// SpecMER c=5 — the paper's final-config table).
pub fn table6(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "table6", "Table 6: best configurations (SpecMER c=5)");
    sink.line("| Protein | Temperature | Draft tokens γ | k | Candidates |");
    sink.line("|---|---|---|---|---|");
    sink.csv_row(&["protein,temp,gamma,k,c".into()]);
    for protein in opts.protein_list(engine) {
        let cells = sweep_cells(engine, &protein, Method::SpecMer, 5, opts)?;
        let ((gamma, temp, kset), _) = best_by_nll(&cells);
        sink.line(&format!("| {protein} | {temp} | {gamma} | {} | 5 |", kset.label()));
        sink.csv_row(&[format!("{protein},{temp},{gamma},\"{}\",5", kset.label())]);
    }
    sink.finish()
}

/// Table 7: wild-type NLL and pLDDT-proxy per protein.
pub fn table7(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "table7", "Table 7: wild-type NLL and pLDDT-proxy");
    sink.line("| Protein | NLL | pLDDT-proxy |");
    sink.line("|---|---|---|");
    sink.csv_row(&["protein,nll,plddt".into()]);
    for f in engine.families() {
        if !opts.protein_list(engine).contains(&f.meta.name) {
            continue;
        }
        let mut toks = vec![tokenizer::BOS];
        toks.extend(&f.wt_tokens);
        toks.push(tokenizer::EOS);
        toks.truncate(190);
        let nll = engine.score_nll(&toks)?;
        let plddt = f.plddt_scorer().score(&f.wt_tokens);
        sink.line(&format!("| {} | {:.2} | {:.2} |", f.meta.name, nll, plddt));
        sink.csv_row(&[format!("{},{nll},{plddt}", f.meta.name)]);
    }
    sink.finish()
}

/// Table 8 + App. C: cross-protein k-mer ablation and MSA-depth ablation.
pub fn table8(engine: &mut Box<dyn GenEngine>, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(
        &opts.out_dir,
        "table8",
        "Table 8 / App. C: cross-protein k-mers and MSA depth ablations",
    );
    let kset = KmerSet::new(true, true, true);
    let cfg = base_cfg(5, 1.0, kset, 5);
    let k20 = opts.n_seqs.min(20).max(1);
    sink.line("| Condition | Mean NLL | Top-20 NLL |");
    sink.line("|---|---|---|");
    sink.csv_row(&["condition,mean_nll,nll_std,top20,top20_std".into()]);

    let all = opts.protein_list(engine.as_ref());
    // pick the ablation pairs from available proteins (paper: GFP+GB1, GB1+Bgl3)
    let pairs: Vec<(String, String)> = if all.contains(&"GFP".to_string()) {
        vec![("GFP".into(), "GB1".into()), ("GB1".into(), "Bgl3".into())]
    } else {
        vec![("SynA".into(), "SynB".into()), ("SynB".into(), "SynA".into())]
    };

    fn run_one(
        engine: &dyn GenEngine,
        cfg: &GenConfig,
        opts: &ExpOpts,
        k20: usize,
        label: String,
        protein: &str,
        sink: &mut Sink,
    ) -> Result<()> {
        let cell = run_cell(engine, protein, Method::SpecMer, cfg, opts.n_seqs, opts.seed)?;
        sink.line(&format!(
            "| {label} | {} | {} |",
            pm(stats::mean(&cell.nlls), stats::std(&cell.nlls), 2),
            pm(stats::mean_smallest(&cell.nlls, k20), stats::std_smallest(&cell.nlls, k20), 2),
        ));
        sink.csv_row(&[format!(
            "{label},{},{},{},{}",
            stats::mean(&cell.nlls),
            stats::std(&cell.nlls),
            stats::mean_smallest(&cell.nlls, k20),
            stats::std_smallest(&cell.nlls, k20)
        )]);
        Ok(())
    }

    for (gen_p, kmer_p) in &pairs {
        // baseline: protein-specific k-mers
        run_one(engine.as_ref(), &cfg, opts, k20, format!("{gen_p} + own k-mers"), gen_p, &mut sink)?;
        // ablation: mismatched k-mers
        let other = engine.family(kmer_p)?.table.clone();
        engine.set_table_override(gen_p, Some(other));
        run_one(engine.as_ref(), &cfg, opts, k20, format!("{gen_p} + {kmer_p} k-mers"), gen_p, &mut sink)?;
        engine.set_table_override(gen_p, None);
    }

    // MSA-depth ablation (paper: Bgl3 at 1k rows vs full)
    let deep = all
        .iter()
        .find(|p| engine.family(p).map(|f| f.msa.depth() >= 1000).unwrap_or(false))
        .cloned();
    if let Some(p) = deep {
        run_one(engine.as_ref(), &cfg, opts, k20, format!("{p} + full-depth MSA"), &p, &mut sink)?;
        let shallow = engine.family(&p)?.msa.subsample(100, 7);
        engine.set_table_override(&p, Some(std::sync::Arc::new(KmerTable::build(&shallow))));
        run_one(engine.as_ref(), &cfg, opts, k20, format!("{p} + depth-100 MSA"), &p, &mut sink)?;
        engine.set_table_override(&p, None);
    }
    sink.finish()
}

/// Table 9: wild-type and inter-sequence Hamming diversity.
pub fn table9(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "table9", "Table 9: sequence diversity (Hamming)");
    sink.line("| Protein | WT Dist (SpecMER) | WT Dist (SpecDec) | Inter-Seq (SpecMER) | Inter-Seq (SpecDec) |");
    sink.line("|---|---|---|---|---|");
    sink.csv_row(&["protein,wt_specmer,wt_specdec,inter_specmer,inter_specdec".into()]);
    let kset = KmerSet::new(true, true, true);
    for protein in opts.protein_list(engine) {
        let fam = engine.family(&protein)?;
        let wt = fam.wt_tokens.clone();
        let mut cols = Vec::new();
        let mut csv = vec![protein.clone()];
        let mut per_method: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
        for (method, c) in [(Method::SpecMer, 5usize), (Method::Speculative, 1)] {
            let cfg = base_cfg(5, 1.0, kset, c);
            let cell = run_cell(engine, &protein, method, &cfg, opts.n_seqs, opts.seed)?;
            let seqs = cell.residue_seqs();
            let wt_d = diversity::wt_distances(&wt, &seqs);
            let inter = diversity::inter_seq_distances(&seqs, 500, opts.seed);
            per_method.push((wt_d, inter));
        }
        for (wt_d, _) in &per_method {
            cols.push(pm(stats::mean(wt_d), stats::std(wt_d), 2));
            csv.push(format!("{}", stats::mean(wt_d)));
        }
        for (_, inter) in &per_method {
            cols.push(pm(stats::mean(inter), stats::std(inter), 2));
            csv.push(format!("{}", stats::mean(inter)));
        }
        sink.line(&format!("| {protein} | {} |", cols.join(" | ")));
        sink.csv_row(&[csv.join(",")]);
    }
    sink.finish()
}

/// Appendix A: speedup bounds (Eq. 1/9/12) vs measured throughput ratios.
pub fn bounds(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "bounds", "Appendix A: speedup bounds vs measured");
    let protein = opts
        .protein_list(engine)
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("no proteins"))?;
    let kset = KmerSet::new(true, true, false);
    // measure target-only throughput (paper-faithful stepwise baseline:
    // one dispatch per token, matching the M_q the bounds are stated in)
    let mut t_cfg = base_cfg(5, 1.0, kset, 1);
    t_cfg.ar_chunk = 1;
    let t_cell = run_cell(engine, &protein, Method::TargetOnly, &t_cfg, opts.n_seqs, opts.seed)?;
    let d_cell = run_cell(engine, &protein, Method::DraftOnly, &base_cfg(5, 1.0, kset, 1), opts.n_seqs, opts.seed)?;
    let target_tps = t_cell.toks_per_sec();
    let c_e = target_tps / d_cell.toks_per_sec().max(1e-9); // M_p/M_q = (1/tps_p)/(1/tps_q)
    sink.line(&format!("protein={protein}  target tok/s={target_tps:.2}  c_e={c_e:.3}\n"));
    sink.line("| γ | c | α measured | S measured | Eq.1 bound | Eq.9 (ξ=1.25) | Eq.12 serial |");
    sink.line("|---|---|---|---|---|---|---|");
    sink.csv_row(&["gamma,c,alpha,s_measured,eq1,eq9,eq12".into()]);
    for &gamma in &[5usize, 10] {
        for &c in &[1usize, 3, 5] {
            let method = if c == 1 { Method::Speculative } else { Method::SpecMer };
            let cell = run_cell(engine, &protein, method, &base_cfg(gamma, 1.0, kset, c), opts.n_seqs, opts.seed)?;
            let alpha = cell.mean_accept();
            let s_meas = cell.toks_per_sec() / target_tps.max(1e-9);
            let xi = 1.0 + 0.25 * ((c - 1) as f64 / 4.0); // paper: ξ≈1.2–1.3 at c=5
            let eq1 = theory::speedup_eq1(alpha, gamma, c_e);
            let eq9 = theory::speedup_eq9(alpha, gamma, theory::c_draft(xi * c_e * gamma as f64, 0.0, 1.0));
            let eq12 = theory::speedup_eq12(alpha, gamma, c, xi, c_e * gamma as f64);
            sink.line(&format!(
                "| {gamma} | {c} | {alpha:.3} | {s_meas:.2}x | {eq1:.2}x | {eq9:.2}x | {eq12:.2}x |"
            ));
            sink.csv_row(&[format!("{gamma},{c},{alpha},{s_meas},{eq1},{eq9},{eq12}")]);
        }
    }
    sink.finish()
}
