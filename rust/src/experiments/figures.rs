//! Regeneration of the paper's figures as data series (CSV + ASCII
//! histograms): Fig. 1c, Fig. 2a/2b, Fig. 3 and the per-protein sweep
//! figures 4–27.

use anyhow::Result;

use super::runner::{run_cell, ExpOpts, Sink};
use crate::config::Method;
use crate::coordinator::GenEngine;
use crate::decode::GenConfig;
use crate::eval::Pca;
use crate::kmer::KmerSet;
use crate::theory;
use crate::util::stats;

fn cfg(gamma: usize, temp: f32, kset: KmerSet, c: usize) -> GenConfig {
    GenConfig { gamma, c, temp, kset, top_p: 0.95, max_len: 10_000, ..Default::default() }
}

fn ascii_hist(sink: &mut Sink, label: &str, xs: &[f64], lo: f64, hi: f64, bins: usize) {
    let h = stats::histogram(xs, lo, hi, bins);
    let max = *h.iter().max().unwrap_or(&1) as f64;
    sink.line(&format!("\n{label}  (n={}, range [{lo:.2},{hi:.2}])", xs.len()));
    for (i, &c) in h.iter().enumerate() {
        let x0 = lo + (hi - lo) * i as f64 / bins as f64;
        let bar = "#".repeat(((c as f64 / max.max(1.0)) * 40.0).round() as usize);
        sink.line(&format!("  {x0:6.2} | {bar} {c}"));
    }
}

/// Fig. 1c: likelihood distribution of generated sequences — target-only
/// vs speculative (c=1) vs SpecMER (c=3,5).
pub fn fig1c(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "fig1c", "Fig 1c: likelihood distributions");
    let protein = pick_protein(engine, opts, &["ParD3", "SynA"]);
    let kset = KmerSet::new(true, true, true);
    sink.csv_row(&["method,seq_idx,nll".into()]);
    let mut all: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, method, c) in [
        ("target", Method::TargetOnly, 1usize),
        ("specdec_c1", Method::Speculative, 1),
        ("specmer_c3", Method::SpecMer, 3),
        ("specmer_c5", Method::SpecMer, 5),
    ] {
        let cell = run_cell(engine, &protein, method, &cfg(5, 1.0, kset, c), opts.n_seqs, opts.seed)?;
        for (i, &nll) in cell.nlls.iter().enumerate() {
            sink.csv_row(&[format!("{label},{i},{nll}")]);
        }
        all.push((label.to_string(), cell.nlls));
    }
    let lo = all.iter().flat_map(|(_, v)| v).cloned().fold(f64::INFINITY, f64::min);
    let hi = all.iter().flat_map(|(_, v)| v).cloned().fold(f64::NEG_INFINITY, f64::max);
    for (label, nlls) in &all {
        ascii_hist(&mut sink, label, nlls, lo, hi, 12);
        sink.line(&format!("  mean NLL = {:.3}", stats::mean(nlls)));
    }
    sink.finish()
}

/// Fig. 2a (and Figs 8/13/18/23): PCA of embeddings — MSA vs generated
/// sequences per c, shaded by likelihood (CSV columns: set, pc1, pc2, nll).
pub fn fig2a(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "fig2a", "Fig 2a: embedding PCA (MSA vs generated)");
    let protein = pick_protein(engine, opts, &["RBP1", "SynA"]);
    let fam = engine.family(&protein)?;
    let kset = KmerSet::new(true, true, true);

    // MSA embeddings (subsample)
    let rows = fam.msa.tokenized_rows();
    let take = rows.len().min(opts.n_seqs.max(30));
    let mut embs: Vec<Vec<f32>> = Vec::new();
    let mut tags: Vec<(String, f64)> = Vec::new();
    for row in rows.iter().take(take) {
        let mut toks = vec![crate::tokenizer::BOS];
        toks.extend(row.iter());
        toks.truncate(engine.families()[0].meta.length.min(190));
        embs.push(engine.embed(&toks)?);
        tags.push(("msa".into(), engine.score_nll(&toks)?));
    }
    for &c in &[1usize, 5] {
        let method = if c == 1 { Method::Speculative } else { Method::SpecMer };
        let cell = run_cell(engine, &protein, method, &cfg(5, 1.0, kset, c), opts.n_seqs, opts.seed)?;
        for (o, &nll) in cell.outputs.iter().zip(&cell.nlls) {
            embs.push(engine.embed(&o.tokens)?);
            tags.push((format!("c{c}"), nll));
        }
    }
    let pca = Pca::fit(&embs, 2);
    sink.line(&format!(
        "protein={protein}; PCA explained variance: {:.2} / {:.2}",
        pca.explained[0], pca.explained.get(1).copied().unwrap_or(0.0)
    ));
    sink.csv_row(&["set,pc1,pc2,nll".into()]);
    // centroid distances: SpecMER should sit closer to the MSA centroid
    let mut centroids: std::collections::BTreeMap<String, (f64, f64, usize)> = Default::default();
    for (e, (tag, nll)) in embs.iter().zip(&tags) {
        let p = pca.transform(e);
        sink.csv_row(&[format!("{tag},{},{},{nll}", p[0], p[1])]);
        let ent = centroids.entry(tag.clone()).or_insert((0.0, 0.0, 0));
        ent.0 += p[0];
        ent.1 += p[1];
        ent.2 += 1;
    }
    let get = |k: &str| {
        centroids
            .get(k)
            .map(|(x, y, n)| (x / *n as f64, y / *n as f64))
            .unwrap_or((0.0, 0.0))
    };
    let msa_c = get("msa");
    for k in ["c1", "c5"] {
        let p = get(k);
        let d = ((p.0 - msa_c.0).powi(2) + (p.1 - msa_c.1).powi(2)).sqrt();
        sink.line(&format!("centroid distance to MSA [{k}]: {d:.3}"));
    }
    sink.finish()
}

/// Fig. 2b: pLDDT-proxy distributions per c (RBP1 in the paper).
pub fn fig2b(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "fig2b", "Fig 2b: pLDDT-proxy distribution per c");
    let protein = pick_protein(engine, opts, &["RBP1", "SynA"]);
    let scorer = engine.family(&protein)?.plddt_scorer();
    let kset = KmerSet::new(true, true, true);
    sink.csv_row(&["c,seq_idx,plddt".into()]);
    for &c in &[1usize, 2, 3, 5] {
        let method = if c == 1 { Method::Speculative } else { Method::SpecMer };
        let cell = run_cell(engine, &protein, method, &cfg(5, 1.0, kset, c), opts.n_seqs, opts.seed)?;
        let scores: Vec<f64> = cell.residue_seqs().iter().map(|s| scorer.score(s)).collect();
        for (i, &s) in scores.iter().enumerate() {
            sink.csv_row(&[format!("{c},{i},{s}")]);
        }
        ascii_hist(&mut sink, &format!("c={c}"), &scores, 0.0, 1.0, 10);
        sink.line(&format!("  mean = {:.3}", stats::mean(&scores)));
    }
    sink.finish()
}

/// Fig. 3: trade-off between candidates c, tokens/sec, NLL and misranking ε.
pub fn fig3(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "fig3", "Fig 3: c vs toks/sec, NLL, misranking ε");
    let protein = pick_protein(engine, opts, &["ParD3", "SynA"]);
    let kset = KmerSet::new(true, true, true);
    sink.line("| c | toks/sec | mean NLL | accept α | ε (probe) | ε (Prop 4.4) |");
    sink.line("|---|---|---|---|---|---|");
    sink.csv_row(&["c,toks_per_sec,nll,alpha,eps_probe,eps_prop44".into()]);
    let mut alpha1 = 0.0;
    for &c in &[1usize, 2, 3, 5] {
        let method = if c == 1 { Method::Speculative } else { Method::SpecMer };
        let mut g = cfg(5, 1.0, kset, c);
        g.probe_rate = if c > 1 { 0.25 } else { 0.0 };
        let cell = run_cell(engine, &protein, method, &g, opts.n_seqs, opts.seed)?;
        let alpha = cell.mean_accept();
        if c == 1 {
            alpha1 = alpha;
        }
        // probe-based ε: P(E ∧ ¬A*)
        let probes: Vec<(bool, bool)> =
            cell.outputs.iter().flat_map(|o| o.probes.clone()).collect();
        let eps_probe = if probes.is_empty() {
            0.0
        } else {
            probes.iter().filter(|(e, a)| *e && !*a).count() as f64 / probes.len() as f64
        };
        let eps_p44 = theory::epsilon_from_acceptance(alpha1, c, alpha).max(0.0);
        sink.line(&format!(
            "| {c} | {:.2} | {:.3} | {alpha:.3} | {eps_probe:.3} | {eps_p44:.3} |",
            cell.toks_per_sec(),
            cell.mean_nll()
        ));
        sink.csv_row(&[format!(
            "{c},{},{},{alpha},{eps_probe},{eps_p44}",
            cell.toks_per_sec(),
            cell.mean_nll()
        )]);
    }
    sink.finish()
}

/// Figures 4–27: per-protein sweep slices — NLL vs k, vs c, vs T, plus the
/// generated-vs-MSA likelihood distributions.
pub fn figs_sweep(engine: &dyn GenEngine, opts: &ExpOpts) -> Result<()> {
    let mut sink = Sink::new(&opts.out_dir, "figs_sweep", "Figs 4-27: sweep slices per protein");
    sink.csv_row(&["protein,axis,value,nll_mean,nll_std".into()]);
    for protein in opts.protein_list(engine) {
        sink.line(&format!("\n## {protein}"));
        // NLL vs k (Figs 4, 9, 14, 19, 24)
        sink.line("| k | mean NLL |");
        sink.line("|---|---|");
        for kset in KmerSet::SWEEP {
            let cell = run_cell(engine, &protein, Method::SpecMer, &cfg(5, 1.0, kset, 5), opts.n_seqs, opts.seed)?;
            sink.line(&format!("| {} | {:.3} |", kset.label(), cell.mean_nll()));
            sink.csv_row(&[format!(
                "{protein},k,\"{}\",{},{}",
                kset.label(),
                cell.mean_nll(),
                stats::std(&cell.nlls)
            )]);
        }
        // NLL vs c (Figs 5, 10, 15, 20, 25)
        sink.line("| c | mean NLL |");
        sink.line("|---|---|");
        for &c in &[1usize, 2, 3, 5] {
            let method = if c == 1 { Method::Speculative } else { Method::SpecMer };
            let cell = run_cell(engine, &protein, method, &cfg(5, 1.0, KmerSet::new(true, true, true), c), opts.n_seqs, opts.seed)?;
            sink.line(&format!("| {c} | {:.3} |", cell.mean_nll()));
            sink.csv_row(&[format!("{protein},c,{c},{},{}", cell.mean_nll(), stats::std(&cell.nlls))]);
        }
        // NLL vs T (Figs 6, 11, 16, 21, 26)
        sink.line("| T | mean NLL |");
        sink.line("|---|---|");
        for &t in &[0.7f32, 1.0, 1.4] {
            let cell = run_cell(engine, &protein, Method::SpecMer, &cfg(5, t, KmerSet::new(true, true, true), 5), opts.n_seqs, opts.seed)?;
            sink.line(&format!("| {t} | {:.3} |", cell.mean_nll()));
            sink.csv_row(&[format!("{protein},T,{t},{},{}", cell.mean_nll(), stats::std(&cell.nlls))]);
        }
        // generated vs MSA likelihood distribution (Figs 7, 12, 17, 22, 27)
        let fam = engine.family(&protein)?;
        let mut msa_nlls = Vec::new();
        for row in fam.msa.tokenized_rows().iter().take(opts.n_seqs) {
            let mut toks = vec![crate::tokenizer::BOS];
            toks.extend(row.iter());
            toks.truncate(190);
            msa_nlls.push(engine.score_nll(&toks)?);
        }
        let cell = run_cell(engine, &protein, Method::SpecMer, &cfg(5, 1.0, KmerSet::new(true, true, true), 5), opts.n_seqs, opts.seed)?;
        ascii_hist(&mut sink, &format!("{protein} MSA NLL"), &msa_nlls, 0.0, 4.0, 10);
        ascii_hist(&mut sink, &format!("{protein} SpecMER NLL"), &cell.nlls, 0.0, 4.0, 10);
        for &v in &msa_nlls {
            sink.csv_row(&[format!("{protein},msa_nll,{v},,")]);
        }
        for &v in &cell.nlls {
            sink.csv_row(&[format!("{protein},gen_nll,{v},,")]);
        }
    }
    sink.finish()
}

fn pick_protein(engine: &dyn GenEngine, opts: &ExpOpts, prefs: &[&str]) -> String {
    let avail = opts.protein_list(engine);
    for p in prefs {
        if avail.contains(&p.to_string()) {
            return p.to_string();
        }
    }
    avail.first().cloned().unwrap_or_default()
}
