//! Latency probe for the serving hot paths: prefill, verify rounds and
//! draft rounds. Uses the HLO/PJRT backend when artifacts (and a PJRT
//! runtime) are available, otherwise probes the pure-Rust batched backend
//! against the seed reference implementation so the tool always runs.

use std::sync::Arc;
use std::time::Instant;

use specmer::runtime::cpu_ref::{reference, CpuModel};
use specmer::runtime::{HloModel, ModelBackend, Runtime};
use specmer::tokenizer::BOS;

fn main() {
    let dir = std::path::PathBuf::from(
        std::env::var("SPECMER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    match Runtime::new(&dir) {
        Ok(rt) => hlo_probe(Arc::new(rt), &dir),
        Err(e) => {
            eprintln!("[perf_probe] no PJRT/artifacts ({e}); probing the cpu_ref backend");
            cpu_probe();
        }
    }
}

fn hlo_probe(rt: Arc<Runtime>, dir: &std::path::Path) {
    let draft = HloModel::load(Arc::clone(&rt), dir, "draft").unwrap();
    let target = HloModel::load(Arc::clone(&rt), dir, "target").unwrap();
    let mut ctx = vec![BOS];
    ctx.extend(specmer::tokenizer::encode("MKTAYIAKQR"));
    // prefill timing
    let t0 = Instant::now();
    let mut tc = target.prefill(&ctx).unwrap();
    println!("target prefill (compile excl?) first: {:?}", t0.elapsed());
    let t0 = Instant::now();
    for _ in 0..20 {
        let _ = target.prefill(&ctx).unwrap();
    }
    println!("target prefill: {:.2} ms", t0.elapsed().as_secs_f64() * 50.0);
    let mut dc = draft.prefill(&ctx).unwrap();
    // verify timing
    let toks: Vec<u8> = vec![ctx[10], 5, 6, 7, 8, 9];
    let _ = target.verify(&mut tc, &toks, 10, 1.0, 0.95).unwrap();
    let t0 = Instant::now();
    for _ in 0..50 {
        let _ = target.verify(&mut tc, &toks, 10, 1.0, 0.95).unwrap();
    }
    println!("target verify g5: {:.2} ms", t0.elapsed().as_secs_f64() * 20.0);
    // draft generate timing c=3 g=5
    let u: Vec<f32> = (0..15).map(|i| (i as f32 * 0.3) % 1.0).collect();
    let feed = vec![ctx[10]];
    let _ = draft.generate(&mut dc, &feed, 10, 3, 5, &u, 1.0, 0.95).unwrap();
    let t0 = Instant::now();
    for _ in 0..50 {
        let _ = draft.generate(&mut dc, &feed, 10, 3, 5, &u, 1.0, 0.95).unwrap();
    }
    println!("draft generate c3 g5: {:.2} ms", t0.elapsed().as_secs_f64() * 20.0);
    let _ = draft.generate(&mut dc, &feed, 10, 1, 5, &u[..5], 1.0, 0.95).unwrap();
    let t0 = Instant::now();
    for _ in 0..50 {
        let _ = draft.generate(&mut dc, &feed, 10, 1, 5, &u[..5], 1.0, 0.95).unwrap();
    }
    println!("draft generate c1 g5: {:.2} ms", t0.elapsed().as_secs_f64() * 20.0);
    // target generate chunk16
    let t0 = Instant::now();
    let u16: Vec<f32> = (0..16).map(|i| (i as f32 * 0.17) % 1.0).collect();
    for _ in 0..50 {
        let _ = target.generate(&mut tc, &feed, 10, 1, 16, &u16, 1.0, 0.95).unwrap();
    }
    println!("target generate c1 g16 (baseline chunk): {:.2} ms", t0.elapsed().as_secs_f64() * 20.0);
    // score
    let t0 = Instant::now();
    for _ in 0..20 {
        let _ = target.score(&ctx).unwrap();
    }
    println!("target score (full 192): {:.2} ms", t0.elapsed().as_secs_f64() * 50.0);
}

/// Pure-Rust probe: batched/branched runtime vs the seed implementation,
/// per round, on a synthetic model (L=4, d=64, H=4, S=256).
fn cpu_probe() {
    let m = CpuModel::synthetic(4, 64, 4, 256, 42);
    let mut ctx = vec![BOS];
    ctx.extend((0..40).map(|i| 3 + ((i * 11) % 20) as u8));
    let pos = ctx.len() - 1;
    let feed = vec![ctx[pos]];
    let u: Vec<f32> = (0..15).map(|i| (i as f32 * 0.3) % 1.0).collect();
    let n = 20u32;

    // prefill
    let t0 = Instant::now();
    let mut cache = m.prefill(&ctx).unwrap();
    for _ in 1..n {
        let _ = m.prefill(&ctx).unwrap();
    }
    println!("cpu prefill ({} toks):        {:.3} ms", ctx.len() - 1, ms_per(t0, n));

    // draft rounds: batched vs seed
    let _ = m.generate(&mut cache, &feed, pos, 3, 5, &u, 1.0, 0.95).unwrap();
    let t0 = Instant::now();
    for _ in 0..n {
        let _ = m.generate(&mut cache, &feed, pos, 3, 5, &u, 1.0, 0.95).unwrap();
    }
    let batched = ms_per(t0, n);
    println!("cpu draft round c3 γ5:        {batched:.3} ms (batched/branched)");

    let t0 = Instant::now();
    for _ in 0..n {
        let _ = reference::generate(&m, &mut cache, &feed, pos, 3, 5, &u, 1.0, 0.95);
    }
    let seed = ms_per(t0, n);
    println!("cpu draft round c3 γ5:        {seed:.3} ms (seed clone-per-cand)");
    println!("cpu draft round speedup:      {:.2}x", seed / batched);

    // verify round
    let vtoks: Vec<u8> = vec![ctx[pos], 4, 7, 9, 12, 15];
    let _ = m.verify(&mut cache, &vtoks, pos, 1.0, 0.95).unwrap();
    let t0 = Instant::now();
    for _ in 0..n {
        let _ = m.verify(&mut cache, &vtoks, pos, 1.0, 0.95).unwrap();
    }
    println!("cpu verify round γ5:          {:.3} ms", ms_per(t0, n));
}

fn ms_per(t0: Instant, iters: u32) -> f64 {
    t0.elapsed().as_secs_f64() * 1000.0 / iters as f64
}
