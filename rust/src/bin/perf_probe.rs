use std::rc::Rc;
use specmer::runtime::*;
use specmer::tokenizer::BOS;
use std::time::Instant;
fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    let rt = Rc::new(Runtime::new(&dir).unwrap());
    let draft = HloModel::load(Rc::clone(&rt), &dir, "draft").unwrap();
    let target = HloModel::load(Rc::clone(&rt), &dir, "target").unwrap();
    let mut ctx = vec![BOS];
    ctx.extend(specmer::tokenizer::encode("MKTAYIAKQR"));
    // prefill timing
    let t0 = Instant::now();
    let mut tc = target.prefill(&ctx).unwrap();
    println!("target prefill (compile excl?) first: {:?}", t0.elapsed());
    let t0 = Instant::now();
    for _ in 0..20 { let _ = target.prefill(&ctx).unwrap(); }
    println!("target prefill: {:.2} ms", t0.elapsed().as_secs_f64()*50.0);
    let mut dc = draft.prefill(&ctx).unwrap();
    // verify timing
    let toks: Vec<u8> = vec![ctx[10], 5,6,7,8,9];
    let _ = target.verify(&mut tc, &toks, 10, 1.0, 0.95).unwrap();
    let t0 = Instant::now();
    for _ in 0..50 { let _ = target.verify(&mut tc, &toks, 10, 1.0, 0.95).unwrap(); }
    println!("target verify g5: {:.2} ms", t0.elapsed().as_secs_f64()*20.0);
    // draft generate timing c=3 g=5
    let u: Vec<f32> = (0..15).map(|i| (i as f32*0.3)%1.0).collect();
    let feed = vec![ctx[10]];
    let _ = draft.generate(&mut dc, &feed, 10, 3, 5, &u, 1.0, 0.95).unwrap();
    let t0 = Instant::now();
    for _ in 0..50 { let _ = draft.generate(&mut dc, &feed, 10, 3, 5, &u, 1.0, 0.95).unwrap(); }
    println!("draft generate c3 g5: {:.2} ms", t0.elapsed().as_secs_f64()*20.0);
    let _ = draft.generate(&mut dc, &feed, 10, 1, 5, &u[..5], 1.0, 0.95).unwrap();
    let t0 = Instant::now();
    for _ in 0..50 { let _ = draft.generate(&mut dc, &feed, 10, 1, 5, &u[..5], 1.0, 0.95).unwrap(); }
    println!("draft generate c1 g5: {:.2} ms", t0.elapsed().as_secs_f64()*20.0);
    // target generate chunk16
    let t0 = Instant::now();
    let u16: Vec<f32> = (0..16).map(|i| (i as f32*0.17)%1.0).collect();
    for _ in 0..50 { let _ = target.generate(&mut tc, &feed, 10, 1, 16, &u16, 1.0, 0.95).unwrap(); }
    println!("target generate c1 g16 (baseline chunk): {:.2} ms", t0.elapsed().as_secs_f64()*20.0);
    // score
    let t0 = Instant::now();
    for _ in 0..20 { let _ = target.score(&ctx).unwrap(); }
    println!("target score (full 192): {:.2} ms", t0.elapsed().as_secs_f64()*50.0);
}
