//! Request/response types flowing through the serving stack.

use crate::config::Method;
use crate::decode::{GenConfig, GenOutput};
use std::sync::mpsc::Sender;
use std::time::Instant;

/// A single generation request (one sequence). Clients wanting N sequences
/// submit N requests — the batcher groups them.
pub struct GenRequest {
    pub id: u64,
    pub protein: String,
    pub method: Method,
    pub cfg: GenConfig,
    /// Where to deliver the result.
    pub reply: Sender<GenResponse>,
    pub submitted: Instant,
}

/// Result of one request.
pub struct GenResponse {
    pub id: u64,
    pub protein: String,
    pub method: Method,
    pub result: anyhow::Result<GenOutput>,
    /// End-to-end latency in seconds (queue + decode).
    pub latency: f64,
    /// Decode-only seconds (inside the worker).
    pub decode_seconds: f64,
}

impl GenResponse {
    /// Decoded amino-acid string (empty on error).
    pub fn sequence(&self) -> String {
        match &self.result {
            Ok(out) => crate::tokenizer::decode(&out.tokens),
            Err(_) => String::new(),
        }
    }
}
