//! Request/response types flowing through the serving stack, built around
//! the per-sequence [`SeqSpec`] scoring plan: everything a worker needs to
//! decode one sequence — family name, method, context tokens and the
//! family's k-mer table as shared `Arc` handles, and the normalized
//! decode config — resolved **once at submission** instead of re-looked-up
//! stringly by `(protein, method)` at every layer. Because the table and
//! context ride per sequence, batching and continuous admission key on the
//! lockstep dispatch shape alone: requests for different proteins (and
//! mixed SpecMER / vanilla-speculative methods) share decode rounds.

use std::sync::Arc;

use crate::config::Method;
use crate::coordinator::engine::Family;
use crate::decode::{GenConfig, GenOutput, LockstepShape};
use crate::kmer::KmerTable;
use std::sync::mpsc::Sender;
use std::time::Instant;

/// The fully-resolved per-sequence scoring plan. Constructed by
/// [`SeqSpec::resolve`] (or the registry / engine helpers wrapping it);
/// after that no layer needs the family registry again: the engine decodes
/// straight from the spec, and the response shares the `Arc<str>` name
/// instead of cloning a `String`.
#[derive(Clone)]
pub struct SeqSpec {
    /// Family name (affinity routing, metrics, display). Shared handle —
    /// cloning a spec or a response never copies the string.
    pub protein: Arc<str>,
    pub method: Method,
    /// Context tokens (BOS + family context prefix) — a shared handle to
    /// the family's immutable context, so cloning a spec (submission,
    /// batch dispatch, admission) never copies the token buffer.
    pub context: Arc<[u8]>,
    /// This sequence's k-mer guidance table, resolved once at submission
    /// (`None` for every non-SpecMER method).
    pub table: Option<Arc<KmerTable>>,
    /// Normalized decode config: `max_len` clamped to the family cap and
    /// `Speculative` degraded to single-candidate drafting (`c = 1`).
    pub cfg: GenConfig,
}

impl SeqSpec {
    /// Resolve `(family, method, cfg)` into a spec: clamp `max_len` to the
    /// family, normalize `Speculative` to `c = 1`, and pin the k-mer table
    /// handle (`table_override` wins over the family's own table — the
    /// App. C ablation hook).
    pub fn resolve(
        fam: &Family,
        method: Method,
        cfg: &GenConfig,
        table_override: Option<&Arc<KmerTable>>,
    ) -> SeqSpec {
        let mut cfg = cfg.clone();
        cfg.max_len = cfg.max_len.min(fam.max_len());
        if method == Method::Speculative {
            cfg.c = 1;
        }
        let table = match method {
            Method::SpecMer => {
                Some(table_override.cloned().unwrap_or_else(|| Arc::clone(&fam.table)))
            }
            _ => None,
        };
        SeqSpec {
            protein: Arc::clone(&fam.name),
            method,
            context: Arc::clone(&fam.context),
            table,
            cfg,
        }
    }

    /// The lockstep dispatch shape this sequence decodes under, if it can
    /// ride the shared draft/verify pipeline at all: only the speculative
    /// methods have a lockstep decode, and probe items interleave extra
    /// dispatches so they must take the sequential path. This is the
    /// batcher's *entire* grouping key — protein and method do not
    /// partition traffic anymore.
    pub fn lockstep_shape(&self) -> Option<LockstepShape> {
        if !matches!(self.method, Method::Speculative | Method::SpecMer)
            || self.cfg.probe_rate > 0.0
        {
            return None;
        }
        Some(LockstepShape::of(&self.cfg))
    }
}

/// A single generation request (one sequence). Clients wanting N sequences
/// submit N requests — the batcher groups them by dispatch shape.
pub struct GenRequest {
    pub id: u64,
    pub spec: SeqSpec,
    /// Where to deliver the result.
    pub reply: Sender<GenResponse>,
    pub submitted: Instant,
    /// Latest instant by which the request must complete. Checked at
    /// submission, at batch pop, and at every lockstep round boundary;
    /// past it the request is answered with `GenError::DeadlineExceeded`.
    pub deadline: Option<Instant>,
}

impl GenRequest {
    /// Whether the deadline (if any) has passed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// Result of one request.
pub struct GenResponse {
    pub id: u64,
    /// Shared family-name handle (no per-response `String` clone).
    pub protein: Arc<str>,
    pub method: Method,
    pub result: anyhow::Result<GenOutput>,
    /// End-to-end latency in seconds (queue + decode).
    pub latency: f64,
    /// Decode-only seconds (inside the worker).
    pub decode_seconds: f64,
}

impl GenResponse {
    /// Decoded amino-acid string (empty on error).
    pub fn sequence(&self) -> String {
        match &self.result {
            Ok(out) => crate::tokenizer::decode(&out.tokens),
            Err(_) => String::new(),
        }
    }
}
