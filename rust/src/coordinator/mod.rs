//! L3 coordinator: the serving system around the decode engines —
//! per-worker engines, shape-keyed dynamic batching over per-sequence
//! [`SeqSpec`] scoring plans, protein-affinity routing, metrics. See
//! DESIGN.md §5 for the request path and docs/serving.md for the
//! overload semantics.
//!
//! The request path is hardened end to end: admission is bounded (each
//! worker queue has a capacity, the router an in-flight concurrency
//! limit) and refusals travel as a typed [`GenError::Overloaded`] rather
//! than queueing without limit; every [`GenRequest`] may carry a
//! deadline, enforced at submission, at batch pop, and at each lockstep
//! round boundary (mid-group cancellation that leaves batchmates'
//! streams bitwise untouched); a dying worker requeues its *queued*
//! requests to surviving workers; and a seeded [`FaultPlan`] can inject
//! engine-build failures, round errors, and round latency for
//! deterministic chaos tests.

pub mod batcher;
pub mod engine;
pub mod error;
pub mod fault;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;

pub use engine::{
    build_engine, build_engine_with, engine_for_bench, load_families, synthetic_engine,
    synthetic_families, Engine, Family, FamilyRegistry, GenEngine, PrefixCacheOpts, RequestSource,
};
pub use error::GenError;
pub use fault::{FaultPlan, FaultState};
pub use metrics::Metrics;
pub use request::{GenRequest, GenResponse, SeqSpec};
pub use router::Router;
pub use scheduler::{EngineFactory, Scheduler, SchedulerOpts};
