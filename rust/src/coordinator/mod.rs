//! L3 coordinator: the serving system around the decode engines —
//! per-worker engines, dynamic batching, protein-affinity routing,
//! metrics. See DESIGN.md §5 for the request path.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;

pub use engine::{
    build_engine, engine_for_bench, load_families, synthetic_engine, Engine, Family, GenEngine,
    RequestSource,
};
pub use metrics::Metrics;
pub use request::{GenRequest, GenResponse};
pub use router::Router;
pub use scheduler::{EngineFactory, Scheduler};
