//! L3 coordinator: the serving system around the decode engines —
//! per-worker engines, shape-keyed dynamic batching over per-sequence
//! [`SeqSpec`] scoring plans, protein-affinity routing, metrics. See
//! DESIGN.md §5 for the request path.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;

pub use engine::{
    build_engine, build_engine_with, engine_for_bench, load_families, synthetic_engine,
    synthetic_families, Engine, Family, FamilyRegistry, GenEngine, RequestSource,
};
pub use metrics::Metrics;
pub use request::{GenRequest, GenResponse, SeqSpec};
pub use router::Router;
pub use scheduler::{EngineFactory, Scheduler};
