//! Serving metrics: counters, token throughput, latency percentiles.
//! Thread-safe; `text_dump` renders a Prometheus-style exposition used by
//! GET /metrics and the experiment harness.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::runtime::PrefixStats;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub tokens_out: AtomicU64,
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    pub bonus: AtomicU64,
    pub draft_calls: AtomicU64,
    pub target_calls: AtomicU64,
    /// Decode rounds across completed requests (denominator of the
    /// per-round gauges below).
    pub rounds: AtomicU64,
    /// Candidate tokens drafted across completed requests (`c · γ` per
    /// flat round; the forest's node count per tree round).
    pub tree_nodes: AtomicU64,
    pub prefill_hits: AtomicU64,
    /// Worker batch dispatches (one lockstep decode run each).
    pub batches: AtomicU64,
    /// Requests served through batch dispatches (occupancy numerator),
    /// including requests admitted into an in-flight group mid-decode.
    pub batched_requests: AtomicU64,
    /// Requests spliced into an in-flight lockstep group at a round
    /// boundary (the continuous-batching path).
    pub admitted: AtomicU64,
    /// Requests that rode a lockstep group whose anchor (first member) had
    /// a *different* `(protein, method)` — the cross-tenant batching the
    /// shape-keyed admission redesign unlocked. Under the old
    /// `(protein, method)`-keyed batcher this counter could never move.
    pub cross_key_admitted: AtomicU64,
    /// Worker engine-construction failures (each marks a dead worker whose
    /// queued requests are requeued to survivors).
    pub engine_failures: AtomicU64,
    /// Requests refused at admission (queue at capacity, concurrency limit
    /// reached, or draining) — answered with `GenError::Overloaded`.
    pub shed: AtomicU64,
    /// Requests answered with `GenError::DeadlineExceeded` (at submission,
    /// batch pop, or mid-group at a round boundary).
    pub deadline_exceeded: AtomicU64,
    /// Queued requests moved from a dead worker to a survivor.
    pub requeued: AtomicU64,
    /// Gauge: requests currently queued across all workers (the scheduler
    /// keeps it in step with every enqueue/pop).
    pub queue_depth: AtomicU64,
    /// Context-prefill positions actually computed at admission, summed
    /// over completed requests (a prefix-store copy-on-write hit
    /// contributes 0 for its side — the savings this gauge makes visible).
    pub prefill_tokens: AtomicU64,
    /// Per-worker prefix-store snapshots, refreshed by each worker after
    /// every dispatch; `text_dump` sums them fleet-wide.
    prefix: Mutex<BTreeMap<usize, PrefixStats>>,
    // lint:allow(unbounded): full-history latency reservoir for percentile
    // gauges; reset with the process, same lifecycle as the counters
    latencies: Mutex<Vec<f64>>,
    decode_seconds: Mutex<f64>,
    queue_wait_seconds: Mutex<f64>,
    /// (Σ round seconds, Σ in-flight-sequences · round seconds) — the
    /// time-weighted occupancy gauge's denominator and numerator.
    round_time: Mutex<(f64, f64)>,
    /// (finished lockstep groups, Σ distinct proteins per group) — the
    /// distinct-proteins-per-group gauge's denominator and numerator.
    group_mix: Mutex<(u64, u64)>,
    started: Mutex<Option<Instant>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { started: Mutex::new(Some(Instant::now())), ..Default::default() }
    }

    pub fn record(&self, out: &crate::decode::GenOutput, latency: f64, decode_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_out
            .fetch_add(out.new_tokens() as u64, Ordering::Relaxed);
        self.accepted.fetch_add(out.accepted, Ordering::Relaxed);
        self.rejected.fetch_add(out.rejected, Ordering::Relaxed);
        self.bonus.fetch_add(out.bonus, Ordering::Relaxed);
        self.draft_calls.fetch_add(out.draft_calls, Ordering::Relaxed);
        self.target_calls.fetch_add(out.target_calls, Ordering::Relaxed);
        self.rounds.fetch_add(out.rounds, Ordering::Relaxed);
        self.tree_nodes.fetch_add(out.tree_nodes, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(out.prefill_tokens, Ordering::Relaxed);
        // lint:allow(unbounded): full-history latency reservoir; growth is one
        // f64 per completed request and is read back for end-of-run percentiles
        self.latencies.lock().unwrap().push(latency);
        *self.decode_seconds.lock().unwrap() += decode_s;
    }

    pub fn record_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one worker batch dispatch: how many requests rode it and the
    /// summed queue wait (submit → dispatch) of its members, in seconds.
    pub fn record_batch(&self, occupancy: usize, queue_wait_s: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(occupancy as u64, Ordering::Relaxed);
        *self.queue_wait_seconds.lock().unwrap() += queue_wait_s;
    }

    /// Record one request admitted into an in-flight lockstep group at a
    /// round boundary (continuous batching) and its queue wait in seconds.
    pub fn record_admission(&self, queue_wait_s: f64) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(1, Ordering::Relaxed);
        *self.queue_wait_seconds.lock().unwrap() += queue_wait_s;
    }

    /// Record one request that rode a lockstep group under a different
    /// `(protein, method)` than the group's first member.
    pub fn record_cross_key_admission(&self) {
        self.cross_key_admitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one finished lockstep group and how many distinct proteins
    /// rode it over its lifetime (feeds the mix gauge).
    pub fn record_group_mix(&self, distinct_proteins: usize) {
        if distinct_proteins == 0 {
            return;
        }
        let mut gm = self.group_mix.lock().unwrap();
        gm.0 += 1;
        gm.1 += distinct_proteins as u64;
    }

    /// Mean distinct proteins per lockstep group — 1.0 means groups are
    /// still single-family; above 1.0 is cross-tenant batching at work.
    pub fn group_distinct_proteins_avg(&self) -> f64 {
        let gm = self.group_mix.lock().unwrap();
        if gm.0 == 0 {
            0.0
        } else {
            gm.1 as f64 / gm.0 as f64
        }
    }

    /// Record a worker whose engine factory failed.
    pub fn record_engine_failure(&self) {
        self.engine_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request refused at admission (also counts as failed —
    /// shed requests are answered with an error).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request whose deadline passed before it completed.
    /// Callers on the worker path also run the normal failure accounting;
    /// this only moves the deadline counter.
    pub fn record_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one queued request moved off a dead worker to a survivor.
    pub fn record_requeue(&self) {
        self.requeued.fetch_add(1, Ordering::Relaxed);
    }

    /// Move the queued-requests gauge (+delta on enqueue, -delta on pop).
    pub fn queue_depth_add(&self, delta: i64) {
        if delta >= 0 {
            self.queue_depth.fetch_add(delta as u64, Ordering::Relaxed);
        } else {
            self.queue_depth.fetch_sub((-delta) as u64, Ordering::Relaxed);
        }
    }

    /// Record one decode round: how many sequences were in flight and how
    /// long the round took (feeds the time-weighted occupancy gauge).
    pub fn record_round(&self, active: usize, dt_s: f64) {
        let mut rt = self.round_time.lock().unwrap();
        rt.0 += dt_s;
        rt.1 += active as f64 * dt_s;
    }

    /// Time-weighted mean of in-flight sequences per decode round — unlike
    /// [`Self::batch_occupancy`] (a per-dispatch head count) this weights
    /// by how long each round actually ran, so it reflects how full the
    /// `[B·c, D]` dispatches were over wall time under streaming arrivals.
    pub fn occupancy_time_weighted(&self) -> f64 {
        let rt = self.round_time.lock().unwrap();
        if rt.0 == 0.0 {
            0.0
        } else {
            rt.1 / rt.0
        }
    }

    /// Mean requests per worker dispatch — how well the batcher is filling
    /// lockstep rounds (1.0 = no cross-request batching happening).
    pub fn batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed) as f64;
        if b == 0.0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b
        }
    }

    /// Total seconds requests spent queued before their batch dispatched.
    pub fn queue_wait_total(&self) -> f64 {
        *self.queue_wait_seconds.lock().unwrap()
    }

    /// Total seconds workers spent inside decode dispatches.
    pub fn decode_seconds_total(&self) -> f64 {
        *self.decode_seconds.lock().unwrap()
    }

    /// Mean candidate-tree size per decode round — `c · γ` while every
    /// request runs flat chains; diverges from it once tree-shaped
    /// speculation (branching `TreePolicy`) is in play.
    pub fn tree_nodes_per_round_avg(&self) -> f64 {
        let r = self.rounds.load(Ordering::Relaxed) as f64;
        if r == 0.0 {
            0.0
        } else {
            self.tree_nodes.load(Ordering::Relaxed) as f64 / r
        }
    }

    /// Mean committed tokens per decode round (accept + reject-resample +
    /// bonus) — the per-round speedup gauge the tree-vs-flat comparison
    /// reads.
    pub fn accepted_len_avg(&self) -> f64 {
        let r = self.rounds.load(Ordering::Relaxed) as f64;
        if r == 0.0 {
            0.0
        } else {
            self.tokens_out.load(Ordering::Relaxed) as f64 / r
        }
    }

    /// Overall acceptance ratio (Eq. 6) across all completed requests.
    pub fn acceptance_ratio(&self) -> f64 {
        let a = self.accepted.load(Ordering::Relaxed) as f64;
        let r = self.rejected.load(Ordering::Relaxed) as f64;
        if a + r == 0.0 {
            0.0
        } else {
            a / (a + r)
        }
    }

    /// Committed tokens per decode-second (the paper's toks/sec).
    pub fn tokens_per_second(&self) -> f64 {
        let secs = *self.decode_seconds.lock().unwrap();
        if secs == 0.0 {
            0.0
        } else {
            self.tokens_out.load(Ordering::Relaxed) as f64 / secs
        }
    }

    pub fn latency_percentile(&self, q: f64) -> f64 {
        crate::util::stats::percentile(&self.latencies.lock().unwrap(), q)
    }

    /// Publish one worker's prefix-store snapshot (replaces the previous
    /// snapshot for that worker — stats are cumulative per store).
    pub fn set_prefix(&self, worker: usize, stats: PrefixStats) {
        self.prefix.lock().unwrap().insert(worker, stats);
    }

    /// Fleet-wide sum of the per-worker prefix-store snapshots.
    pub fn prefix_totals(&self) -> PrefixStats {
        let mut total = PrefixStats::default();
        for st in self.prefix.lock().unwrap().values() {
            total = total.merge(*st);
        }
        total
    }

    /// Mean context-prefill positions computed per completed request —
    /// drops toward 0 as warm admissions attach cached prefixes instead
    /// of recomputing them.
    pub fn admission_prefill_tokens_avg(&self) -> f64 {
        let done = self.completed.load(Ordering::Relaxed) as f64;
        if done == 0.0 {
            0.0
        } else {
            self.prefill_tokens.load(Ordering::Relaxed) as f64 / done
        }
    }

    pub fn text_dump(&self) -> String {
        let lat = self.latencies.lock().unwrap();
        let p50 = crate::util::stats::percentile(&lat, 50.0);
        let p99 = crate::util::stats::percentile(&lat, 99.0);
        drop(lat);
        let uptime = self
            .started
            .lock()
            .unwrap()
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        // Resolved dispatch configuration (kernel tier, weight dtype, fast
        // tier), so perf trajectories scraped from /metrics are attributable
        // to the configuration that produced them.
        let kernel = crate::runtime::simd::active().name();
        let dtype = crate::runtime::simd::weight_dtype().name();
        let fast = crate::runtime::simd::fast_tier() as u8;
        let px = self.prefix_totals();
        format!(
            "specmer_kernel_info{{kernel=\"{kernel}\",weight_dtype=\"{dtype}\"}} 1\n\
             specmer_fast_tier {fast}\n\
             specmer_uptime_seconds {uptime:.1}\n\
             specmer_requests_total {}\n\
             specmer_completed_total {}\n\
             specmer_failed_total {}\n\
             specmer_tokens_out_total {}\n\
             specmer_accepted_total {}\n\
             specmer_rejected_total {}\n\
             specmer_bonus_total {}\n\
             specmer_acceptance_ratio {:.4}\n\
             specmer_tokens_per_second {:.2}\n\
             specmer_draft_calls_total {}\n\
             specmer_target_calls_total {}\n\
             specmer_rounds_total {}\n\
             specmer_tree_nodes_per_round_avg {:.3}\n\
             specmer_accepted_len_avg {:.3}\n\
             specmer_prefill_cache_hits_total {}\n\
             specmer_prefix_cache_hits_total {}\n\
             specmer_prefix_cache_misses_total {}\n\
             specmer_prefix_cache_evictions_total {}\n\
             specmer_prefix_cache_bytes {}\n\
             specmer_admission_prefill_tokens_avg {:.3}\n\
             specmer_batches_total {}\n\
             specmer_batch_occupancy_avg {:.3}\n\
             specmer_admitted_total {}\n\
             specmer_cross_key_admitted_total {}\n\
             specmer_group_distinct_proteins_avg {:.3}\n\
             specmer_engine_failures_total {}\n\
             specmer_shed_total {}\n\
             specmer_deadline_exceeded_total {}\n\
             specmer_requeued_total {}\n\
             specmer_queue_depth {}\n\
             specmer_occupancy_time_weighted {:.3}\n\
             specmer_queue_wait_seconds_total {:.4}\n\
             specmer_decode_seconds_total {:.4}\n\
             specmer_latency_p50_seconds {p50:.4}\n\
             specmer_latency_p99_seconds {p99:.4}\n",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.accepted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.bonus.load(Ordering::Relaxed),
            self.acceptance_ratio(),
            self.tokens_per_second(),
            self.draft_calls.load(Ordering::Relaxed),
            self.target_calls.load(Ordering::Relaxed),
            self.rounds.load(Ordering::Relaxed),
            self.tree_nodes_per_round_avg(),
            self.accepted_len_avg(),
            self.prefill_hits.load(Ordering::Relaxed),
            px.hits,
            px.misses,
            px.evictions,
            px.bytes,
            self.admission_prefill_tokens_avg(),
            self.batches.load(Ordering::Relaxed),
            self.batch_occupancy(),
            self.admitted.load(Ordering::Relaxed),
            self.cross_key_admitted.load(Ordering::Relaxed),
            self.group_distinct_proteins_avg(),
            self.engine_failures.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.deadline_exceeded.load(Ordering::Relaxed),
            self.requeued.load(Ordering::Relaxed),
            self.queue_depth.load(Ordering::Relaxed),
            self.occupancy_time_weighted(),
            self.queue_wait_total(),
            self.decode_seconds_total(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::GenOutput;

    fn out(accepted: u64, rejected: u64, n_tokens: usize) -> GenOutput {
        GenOutput {
            tokens: vec![1; n_tokens + 2],
            context_len: 2,
            accepted,
            rejected,
            ..Default::default()
        }
    }

    #[test]
    fn records_and_aggregates() {
        let m = Metrics::new();
        m.requests.fetch_add(2, Ordering::Relaxed);
        m.record(&out(9, 1, 10), 0.5, 0.4);
        m.record(&out(8, 2, 10), 0.7, 0.6);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert!((m.acceptance_ratio() - 0.85).abs() < 1e-12);
        assert!((m.tokens_per_second() - 20.0).abs() < 1e-9);
        let dump = m.text_dump();
        assert!(dump.contains("specmer_tokens_out_total 20"));
        assert!(dump.contains("specmer_acceptance_ratio 0.85"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.acceptance_ratio(), 0.0);
        assert_eq!(m.tokens_per_second(), 0.0);
        assert_eq!(m.batch_occupancy(), 0.0);
        assert!(m.text_dump().contains("specmer_requests_total 0"));
    }

    #[test]
    fn dump_names_dispatch_config() {
        let dump = Metrics::new().text_dump();
        // the exact kernel/dtype depend on host + env; the labels must be
        // present and drawn from the known vocabularies either way
        assert!(dump.contains("specmer_kernel_info{kernel=\""));
        assert!(dump.contains("weight_dtype=\""));
        assert!(dump.contains("specmer_fast_tier "));
    }

    #[test]
    fn batch_dispatches_tracked() {
        let m = Metrics::new();
        m.record_batch(4, 0.2);
        m.record_batch(2, 0.1);
        assert_eq!(m.batches.load(Ordering::Relaxed), 2);
        assert!((m.batch_occupancy() - 3.0).abs() < 1e-12);
        assert!((m.queue_wait_total() - 0.3).abs() < 1e-12);
        let dump = m.text_dump();
        assert!(dump.contains("specmer_batches_total 2"));
        assert!(dump.contains("specmer_batch_occupancy_avg 3.000"));
    }

    #[test]
    fn admissions_count_toward_occupancy() {
        let m = Metrics::new();
        m.record_batch(2, 0.2);
        m.record_admission(0.05);
        m.record_admission(0.15);
        assert_eq!(m.admitted.load(Ordering::Relaxed), 2);
        // admitted requests rode the existing dispatch: 4 requests, 1 batch
        assert!((m.batch_occupancy() - 4.0).abs() < 1e-12);
        assert!((m.queue_wait_total() - 0.4).abs() < 1e-12);
        assert!(m.text_dump().contains("specmer_admitted_total 2"));
    }

    #[test]
    fn cross_key_and_group_mix_gauges() {
        let m = Metrics::new();
        assert_eq!(m.group_distinct_proteins_avg(), 0.0);
        m.record_cross_key_admission();
        m.record_cross_key_admission();
        m.record_group_mix(3); // one group saw 3 distinct proteins
        m.record_group_mix(1); // one stayed single-family
        m.record_group_mix(0); // empty groups don't skew the gauge
        assert_eq!(m.cross_key_admitted.load(Ordering::Relaxed), 2);
        assert!((m.group_distinct_proteins_avg() - 2.0).abs() < 1e-12);
        let dump = m.text_dump();
        assert!(dump.contains("specmer_cross_key_admitted_total 2"));
        assert!(dump.contains("specmer_group_distinct_proteins_avg 2.000"));
    }

    #[test]
    fn tree_gauges_per_round() {
        let m = Metrics::new();
        assert_eq!(m.tree_nodes_per_round_avg(), 0.0);
        assert_eq!(m.accepted_len_avg(), 0.0);
        let mut a = out(9, 1, 12);
        a.rounds = 3;
        a.tree_nodes = 45; // flat c=3 γ=5: 15 nodes/round
        let mut b = out(6, 2, 8);
        b.rounds = 2;
        b.tree_nodes = 28; // tree policy drafting 14 nodes/round
        m.record(&a, 0.5, 0.4);
        m.record(&b, 0.7, 0.6);
        assert!((m.tree_nodes_per_round_avg() - 73.0 / 5.0).abs() < 1e-12);
        assert!((m.accepted_len_avg() - 4.0).abs() < 1e-12);
        let dump = m.text_dump();
        assert!(dump.contains("specmer_rounds_total 5"));
        assert!(dump.contains("specmer_tree_nodes_per_round_avg 14.600"));
        assert!(dump.contains("specmer_accepted_len_avg 4.000"));
    }

    #[test]
    fn overload_counters_and_queue_gauge() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_deadline_exceeded();
        m.record_requeue();
        m.queue_depth_add(3);
        m.queue_depth_add(-2);
        assert_eq!(m.shed.load(Ordering::Relaxed), 2);
        // shed requests are answered with errors, so they count as failed
        assert_eq!(m.failed.load(Ordering::Relaxed), 2);
        assert_eq!(m.deadline_exceeded.load(Ordering::Relaxed), 1);
        let dump = m.text_dump();
        assert!(dump.contains("specmer_shed_total 2"));
        assert!(dump.contains("specmer_deadline_exceeded_total 1"));
        assert!(dump.contains("specmer_requeued_total 1"));
        assert!(dump.contains("specmer_queue_depth 1"));
    }

    #[test]
    fn prefix_cache_gauges_sum_across_workers() {
        let m = Metrics::new();
        // no snapshots yet: totals are zero and the dump is still well-formed
        assert_eq!(m.prefix_totals(), PrefixStats::default());
        assert!(m.text_dump().contains("specmer_prefix_cache_hits_total 0"));
        let w0 = PrefixStats { hits: 3, misses: 2, evictions: 1, bytes: 256, entries: 2 };
        let w1 = PrefixStats { hits: 1, misses: 4, evictions: 0, bytes: 128, entries: 1 };
        m.set_prefix(0, w0);
        m.set_prefix(1, w1);
        // re-publishing a worker replaces its snapshot (cumulative stats),
        // it must not double-count
        m.set_prefix(0, w0);
        let total = m.prefix_totals();
        assert_eq!((total.hits, total.misses, total.evictions), (4, 6, 1));
        assert_eq!(total.bytes, 384);
        let dump = m.text_dump();
        assert!(dump.contains("specmer_prefix_cache_hits_total 4"));
        assert!(dump.contains("specmer_prefix_cache_misses_total 6"));
        assert!(dump.contains("specmer_prefix_cache_evictions_total 1"));
        assert!(dump.contains("specmer_prefix_cache_bytes 384"));
    }

    #[test]
    fn admission_prefill_tokens_gauge() {
        let m = Metrics::new();
        assert_eq!(m.admission_prefill_tokens_avg(), 0.0);
        let mut a = out(9, 1, 10);
        a.prefill_tokens = 22; // cold: both models prefilled the context
        let mut b = out(8, 2, 10);
        b.prefill_tokens = 0; // warm: both sides attached cached prefixes
        m.record(&a, 0.5, 0.4);
        m.record(&b, 0.7, 0.6);
        assert!((m.admission_prefill_tokens_avg() - 11.0).abs() < 1e-12);
        assert!(m.text_dump().contains("specmer_admission_prefill_tokens_avg 11.000"));
    }

    #[test]
    fn time_weighted_occupancy_gauge() {
        let m = Metrics::new();
        assert_eq!(m.occupancy_time_weighted(), 0.0);
        m.record_round(4, 1.0); // 4 sequences for 1s
        m.record_round(1, 3.0); // 1 sequence for 3s
        assert!((m.occupancy_time_weighted() - 7.0 / 4.0).abs() < 1e-12);
        assert!(m.text_dump().contains("specmer_occupancy_time_weighted 1.750"));
    }
}
