//! Request router: resolves every request into its per-sequence
//! [`SeqSpec`] **once at submission** (family registry lookup, k-mer table
//! `Arc` handle, config normalization — unknown proteins are answered
//! immediately instead of occupying a worker), then places it by
//! protein-affinity with least-loaded fallback.
//!
//! Affinity keeps a protein's requests on the same worker so its k-mer
//! table stays hot and the prefill memo hits (vLLM-router's cache-aware
//! routing, adapted to per-family state) — it is a *placement* preference
//! only: once queued, batching and admission are shape-keyed, so a
//! worker's in-flight group happily mixes whatever proteins land on it.
//! Placement consults the prefix-store
//! [`Residency`](crate::runtime::Residency) table first: a live
//! worker already holding this family's prefilled context (a **warm**
//! worker, where admission attaches the cached KV copy-on-write instead
//! of recomputing prefill) is preferred, least-loaded among holders.
//! Warmth never overrides overload protection — when the affinity target
//! (warm or hashed) is loaded past `spill_threshold` relative to the
//! least-loaded worker, the router spills.
//!
//! Overload hardening: submission enforces a router-level **in-flight
//! concurrency limit** (`max_inflight`; on top of the per-worker queue
//! bounds) — requests past it are shed with a typed
//! [`GenError::Overloaded`](crate::coordinator::GenError) reply — and an
//! optional per-request **deadline**, refused right here when already
//! expired. See docs/serving.md.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::config::Method;
use crate::coordinator::engine::FamilyRegistry;
use crate::coordinator::error::GenError;
use crate::coordinator::request::{GenRequest, GenResponse};
use crate::coordinator::scheduler::Scheduler;
use crate::decode::GenConfig;
use crate::runtime::context_key;

pub struct Router {
    pub scheduler: Arc<Scheduler>,
    /// Submission-side spec resolver (shared with the worker engines).
    pub registry: Arc<FamilyRegistry>,
    next_id: AtomicU64,
    /// Spill when affinity worker has this many more queued than the min.
    pub spill_threshold: usize,
    /// Concurrency limit: total outstanding (queued + in-flight) requests
    /// across all workers; submissions past it are shed. 0 = unlimited.
    pub max_inflight: usize,
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl Router {
    pub fn new(scheduler: Arc<Scheduler>, registry: Arc<FamilyRegistry>) -> Router {
        Router {
            scheduler,
            registry,
            next_id: AtomicU64::new(1),
            spill_threshold: 4,
            max_inflight: 0,
        }
    }

    /// Builder-style concurrency limit (0 = unlimited).
    pub fn with_max_inflight(mut self, max_inflight: usize) -> Router {
        self.max_inflight = max_inflight;
        self
    }

    /// Pick a worker for `protein` (exposed for tests). Dead workers (a
    /// failed engine factory) are never selected while any live worker
    /// exists; if all are dead we fall back to affinity — the dead worker's
    /// drain loop still answers with errors rather than hanging clients.
    ///
    /// Soft family-affinity: a live worker whose prefix store already
    /// holds this family's prefilled context wins over the hash target
    /// (warm admission attaches the cached KV copy-on-write), least-loaded
    /// among holders — but only while it sits within `spill_threshold` of
    /// the least-loaded worker: warmth never overrides load shedding.
    pub fn place(&self, protein: &str) -> usize {
        let n = self.scheduler.n_workers();
        if n == 1 {
            return 0;
        }
        let affinity = (fnv1a(protein) % n as u64) as usize;
        let alive = self.scheduler.alive();
        let loads = self.scheduler.loads();
        let live_min = loads
            .iter()
            .enumerate()
            .filter(|(i, _)| alive[*i])
            .min_by_key(|(_, &l)| l)
            .map(|(i, &l)| (i, l));
        let Some((min_w, min_load)) = live_min else {
            return affinity; // every worker is dead
        };
        if let Some(w) = self.warm_worker(protein, &alive, &loads) {
            if loads[w] <= min_load + self.spill_threshold {
                return w;
            }
        }
        if !alive[affinity] || loads[affinity] > min_load + self.spill_threshold {
            min_w
        } else {
            affinity
        }
    }

    /// Least-loaded live worker whose prefix store holds `protein`'s
    /// family context ([`crate::runtime::Residency`] lookup); ties break toward the lowest
    /// worker index (holders are listed ascending). `None` when the
    /// protein is unknown or no live worker is warm.
    fn warm_worker(&self, protein: &str, alive: &[bool], loads: &[usize]) -> Option<usize> {
        let fam = self.registry.get(protein).ok()?;
        let key = context_key(&fam.context);
        self.scheduler
            .residency()
            .holders(key)
            .into_iter()
            .filter(|&w| w < loads.len() && alive[w])
            .min_by_key(|&w| loads[w])
    }

    /// Submit one request; returns its id. Resolution happens here —
    /// workers receive a ready-to-decode [`crate::coordinator::SeqSpec`];
    /// an unknown protein is answered with an error immediately.
    pub fn submit(
        &self,
        protein: &str,
        method: Method,
        cfg: GenConfig,
        reply: std::sync::mpsc::Sender<GenResponse>,
    ) -> u64 {
        self.submit_with_deadline(protein, method, cfg, None, reply)
    }

    /// [`Self::submit`] with a completion deadline. An already-expired
    /// deadline is refused here (typed `DeadlineExceeded`, no worker
    /// touched); the concurrency limit sheds here too. Later enforcement
    /// (batch pop, round boundaries) happens inside the scheduler.
    pub fn submit_with_deadline(
        &self,
        protein: &str,
        method: Method,
        cfg: GenConfig,
        deadline: Option<Instant>,
        reply: std::sync::mpsc::Sender<GenResponse>,
    ) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        match self.registry.spec(protein, method, &cfg) {
            Ok(spec) => {
                let req = GenRequest { id, spec, reply, submitted: Instant::now(), deadline };
                let metrics = &self.scheduler.metrics;
                if req.expired(Instant::now()) {
                    metrics.requests.fetch_add(1, Ordering::Relaxed);
                    metrics.record_deadline_exceeded();
                    metrics.record_failure();
                    Self::answer(req, GenError::DeadlineExceeded.into());
                } else if self.max_inflight > 0
                    && self.scheduler.loads().iter().sum::<usize>() >= self.max_inflight
                {
                    metrics.requests.fetch_add(1, Ordering::Relaxed);
                    self.scheduler.shed(req);
                } else {
                    let w = self.place(protein);
                    // bounded admission: submit_to sheds internally at
                    // queue capacity, so the client is answered either way
                    self.scheduler.submit_to(w, req);
                }
            }
            Err(e) => {
                self.scheduler.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.scheduler.metrics.record_failure();
                let _ = reply.send(GenResponse {
                    id,
                    protein: Arc::from(protein),
                    method,
                    result: Err(e),
                    latency: 0.0,
                    decode_seconds: 0.0,
                });
            }
        }
        id
    }

    fn answer(req: GenRequest, err: anyhow::Error) {
        let _ = req.reply.send(GenResponse {
            id: req.id,
            protein: req.spec.protein,
            method: req.spec.method,
            result: Err(err),
            latency: 0.0,
            decode_seconds: 0.0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{synthetic_engine, synthetic_families, GenEngine};
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::scheduler::EngineFactory;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn router(workers: usize) -> Router {
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        let sched = Arc::new(Scheduler::start(
            workers,
            4,
            Duration::from_millis(1),
            factory,
            Arc::new(Metrics::new()),
        ));
        Router::new(sched, Arc::new(FamilyRegistry::new(synthetic_families(3))))
    }

    #[test]
    fn affinity_is_stable() {
        let r = router(4);
        let w1 = r.place("GFP");
        let w2 = r.place("GFP");
        assert_eq!(w1, w2);
    }

    #[test]
    fn single_worker_always_zero() {
        let r = router(1);
        assert_eq!(r.place("anything"), 0);
    }

    #[test]
    fn submit_roundtrip() {
        let r = router(2);
        let (tx, rx) = channel();
        let mut ids = Vec::new();
        for seed in 0..4u64 {
            ids.push(r.submit(
                "SynA",
                Method::SpecMer,
                GenConfig { max_len: 20, seed, ..Default::default() },
                tx.clone(),
            ));
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4, "ids unique");
        for _ in 0..4 {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.result.is_ok());
        }
    }

    #[test]
    fn unknown_protein_answered_at_submission() {
        // spec resolution happens in the router now: a bad protein never
        // occupies a worker and still gets exactly one error response
        let r = router(1);
        let (tx, rx) = channel();
        r.submit("Nope", Method::SpecMer, GenConfig::default(), tx);
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.result.is_err());
        assert_eq!(&*resp.protein, "Nope");
        assert_eq!(r.scheduler.metrics.failed.load(Ordering::Relaxed), 1);
        assert_eq!(r.scheduler.loads(), vec![0], "nothing was enqueued");
    }

    #[test]
    fn dead_workers_are_not_selected() {
        use std::sync::atomic::AtomicUsize;

        // one of the two workers fails to build its engine; once marked
        // dead, placement must always pick the live one
        let ctr = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&ctr);
        let factory: EngineFactory = Arc::new(move || {
            if c2.fetch_add(1, Ordering::SeqCst) == 0 {
                Err(anyhow::anyhow!("boom"))
            } else {
                Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>)
            }
        });
        let sched = Arc::new(Scheduler::start(
            2,
            4,
            Duration::from_millis(1),
            factory,
            Arc::new(Metrics::new()),
        ));
        // wait for exactly one worker to come up dead (factory call order
        // across worker threads is racy, which worker is dead is not fixed)
        let mut dead = 0;
        for _ in 0..500 {
            dead = sched.alive().iter().filter(|a| !**a).count();
            if dead == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(dead, 1, "exactly one worker should be dead: {:?}", sched.alive());
        let live = sched.alive().iter().position(|&a| a).unwrap();
        let r = Router::new(sched, Arc::new(FamilyRegistry::new(synthetic_families(3))));
        for protein in ["GFP", "GB1", "TEM1", "SynA", "SynB"] {
            assert_eq!(r.place(protein), live, "{protein} routed to a dead worker");
        }
    }

    #[test]
    fn concurrency_limit_sheds_at_submission() {
        use crate::coordinator::scheduler::SchedulerOpts;
        // huge max_wait keeps the accepted submissions queued (batch never
        // fires), so the third deterministically sees the in-flight limit
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        let opts = SchedulerOpts { max_wait: Duration::from_secs(3600), ..Default::default() };
        let sched = Arc::new(Scheduler::start_with(1, opts, factory, Arc::new(Metrics::new())));
        let r = Router::new(sched, Arc::new(FamilyRegistry::new(synthetic_families(3))))
            .with_max_inflight(2);
        let (tx, rx) = channel();
        for seed in 0..3u64 {
            r.submit(
                "SynA",
                Method::SpecMer,
                GenConfig { max_len: 16, seed, ..Default::default() },
                tx.clone(),
            );
        }
        // the shed reply is synchronous; the two accepted are still queued
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = resp.result.unwrap_err();
        assert!(
            matches!(GenError::of(&err), Some(GenError::Overloaded { .. })),
            "expected typed Overloaded, got {err:#}"
        );
        assert_eq!(r.scheduler.metrics.shed.load(Ordering::Relaxed), 1);
        assert_eq!(r.scheduler.loads(), vec![2]);
        drop(tx);
        drop(r); // scheduler shutdown flush serves the two queued requests
        assert_eq!(rx.iter().filter(|resp| resp.result.is_ok()).count(), 2);
    }

    #[test]
    fn expired_deadline_refused_at_submission() {
        let r = router(1);
        let (tx, rx) = channel();
        r.submit_with_deadline(
            "SynA",
            Method::SpecMer,
            GenConfig { max_len: 16, ..Default::default() },
            Some(Instant::now() - Duration::from_millis(5)),
            tx,
        );
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = resp.result.unwrap_err();
        assert_eq!(GenError::of(&err), Some(GenError::DeadlineExceeded), "{err:#}");
        assert_eq!(r.scheduler.loads(), vec![0], "nothing was enqueued");
        assert_eq!(r.scheduler.metrics.deadline_exceeded.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn warm_prefix_worker_preferred_over_hash_affinity() {
        let r = router(4);
        let fam = r.registry.get("SynA").unwrap();
        let key = context_key(&fam.context);
        let hashed = r.place("SynA");
        // mark a *different* worker as holding SynA's prefilled context
        let warm = (hashed + 1) % 4;
        r.scheduler.residency().publish(key, warm);
        assert_eq!(r.place("SynA"), warm, "idle warm worker must win placement");
        // with two warm holders, the least-loaded (here: tied, lowest
        // index) wins deterministically
        let warm2 = (hashed + 2) % 4;
        r.scheduler.residency().publish(key, warm2);
        assert_eq!(r.place("SynA"), warm.min(warm2));
        // unknown proteins never consult residency (and still place)
        let w = r.place("NotAFamily");
        assert!(w < 4);
    }

    #[test]
    fn warm_affinity_does_not_override_load_shedding() {
        use crate::coordinator::scheduler::SchedulerOpts;
        // a warm worker loaded past the spill threshold must not attract
        // placement: cache affinity is a preference, overload wins
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        // huge max_wait + max_batch keep submissions queued (nothing
        // dispatches before shutdown) so loads are deterministic
        let opts = SchedulerOpts {
            max_batch: 64,
            max_wait: Duration::from_secs(3600),
            queue_capacity: 64,
            ..Default::default()
        };
        let sched = Arc::new(Scheduler::start_with(3, opts, factory, Arc::new(Metrics::new())));
        let r = Router::new(sched, Arc::new(FamilyRegistry::new(synthetic_families(3))));
        let fam = r.registry.get("SynA").unwrap();
        let key = context_key(&fam.context);
        let warm = 2;
        r.scheduler.residency().publish(key, warm);
        assert_eq!(r.place("SynA"), warm, "idle warm worker wins first");
        let flood = r.spill_threshold as u64 + 2;
        let (tx, rx) = channel();
        for seed in 0..flood {
            let spec = r
                .registry
                .spec(
                    "SynA",
                    Method::SpecMer,
                    &GenConfig { max_len: 16, seed, ..Default::default() },
                )
                .unwrap();
            r.scheduler.submit_to(
                warm,
                GenRequest {
                    id: 900 + seed,
                    spec,
                    reply: tx.clone(),
                    submitted: Instant::now(),
                    deadline: None,
                },
            );
        }
        let placed = r.place("SynA");
        assert_ne!(placed, warm, "overloaded warm worker must be spilled away from");
        drop(tx);
        drop(r); // scheduler shutdown flush answers the queued requests
        assert_eq!(rx.iter().count() as u64, flood);
    }

    /// Property: placement spills away from a hot worker.
    #[test]
    fn spills_when_overloaded() {
        // emulate load imbalance by submitting many requests to the
        // affinity worker without waiting
        let r = router(3);
        let (tx, rx) = channel();
        let affinity = r.place("SynA");
        // flood that worker directly
        for seed in 0..12u64 {
            let spec = r
                .registry
                .spec(
                    "SynA",
                    Method::SpecMer,
                    &GenConfig { max_len: 30, seed, ..Default::default() },
                )
                .unwrap();
            r.scheduler.submit_to(
                affinity,
                GenRequest {
                    id: 1000 + seed,
                    spec,
                    reply: tx.clone(),
                    submitted: Instant::now(),
                    deadline: None,
                },
            );
        }
        // placement may now pick a different worker (can't assert strictly:
        // the worker might drain fast; just exercise the code path)
        let _ = r.place("SynA");
        for _ in 0..12 {
            let _ = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
    }
}
