//! The per-worker generation engine: backends + family registry + k-mer
//! tables behind one object the scheduler and examples drive directly.

use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::config::{Config, Method};
use crate::decode::{self, AdmissionHook, AdmitItem, GenConfig, GenOutput, LockstepShape};
use crate::eval::PlddtScorer;
use crate::kmer::KmerTable;
use crate::msa::{self, FamilyMeta, Msa};
use crate::runtime::prefill_cache::PrefillCached;
use crate::runtime::{CpuModel, HloModel, ModelBackend, Runtime};
use crate::tokenizer::{self, BOS};

/// Per-family state: metadata, MSA-derived k-mer table, context tokens.
pub struct Family {
    pub meta: FamilyMeta,
    pub table: KmerTable,
    pub context: Vec<u8>,
    pub wt_tokens: Vec<u8>,
    pub msa: Msa,
}

impl Family {
    pub fn from_msa(meta: FamilyMeta, msa: Msa) -> Family {
        let wt_tokens = tokenizer::encode(&meta.wild_type);
        let mut context = vec![BOS];
        context.extend(&wt_tokens[..meta.context.min(wt_tokens.len())]);
        Family { table: KmerTable::build(&msa), context, wt_tokens, meta, msa }
    }

    /// Max total token length for generation: BOS + wild-type + EOS.
    pub fn max_len(&self) -> usize {
        self.wt_tokens.len() + 2
    }

    pub fn plddt_scorer(&self) -> PlddtScorer {
        PlddtScorer::from_msa(&self.msa)
    }
}

/// Load every family from artifacts (families.json + msa/*.a2m).
pub fn load_families(artifacts: &Path) -> Result<Vec<Family>> {
    let metas = msa::load_families(&artifacts.join("families.json"))
        .map_err(|e| anyhow!("loading families.json from {}: {e:#}", artifacts.display()))?;
    metas
        .into_iter()
        .map(|meta| {
            let m = Msa::load(&artifacts.join("msa").join(format!("{}.a2m", meta.name)), &meta.name)?;
            Ok(Family::from_msa(meta, m))
        })
        .collect()
}

/// Where the worker's continuous-batching dispatch pulls new requests from
/// and delivers finished ones to. The worker implements this over its
/// batcher: `admit` is called at every draft/verify round boundary and may
/// pop newly-queued compatible requests; `complete` fires the moment any
/// sequence finishes, so clients are answered mid-flight.
pub trait RequestSource {
    /// Called at each round boundary with the number of sequences still in
    /// flight; returns `(ticket, cfg)` pairs to admit into the group.
    fn admit(&mut self, active: usize) -> Vec<(u64, GenConfig)>;
    /// Delivers one request's final result (exactly once per ticket).
    fn complete(&mut self, ticket: u64, result: Result<GenOutput>);
}

/// Object-safe engine interface used by the scheduler, server and benches.
pub trait GenEngine {
    /// Generate one sequence for `protein` with `method`.
    fn generate(&self, protein: &str, method: Method, cfg: &GenConfig) -> Result<GenOutput>;
    /// Generate a whole batcher batch (one `(protein, method)` key, one
    /// config per request) in a single call, returning per-request results
    /// in order. The default loops [`GenEngine::generate`]; `Engine`
    /// overrides it to run lockstep-compatible requests (equal `(c, gamma)`
    /// — sampling params are per-sequence) through
    /// [`decode::speculative_generate_batch`] so one decode round serves
    /// the whole batch.
    fn generate_batch(
        &self,
        protein: &str,
        method: Method,
        cfgs: &[GenConfig],
    ) -> Vec<Result<GenOutput>> {
        cfgs.iter().map(|cfg| self.generate(protein, method, cfg)).collect()
    }
    /// The lockstep dispatch shape `(protein, method, cfg)` would decode
    /// under, if the engine can serve it on the continuous-batching path
    /// (None → the request must go through [`GenEngine::generate_batch`]).
    fn lockstep_shape(
        &self,
        protein: &str,
        method: Method,
        cfg: &GenConfig,
    ) -> Option<LockstepShape> {
        let _ = (protein, method, cfg);
        None
    }
    /// Continuous batching: run one in-flight lockstep group of shape
    /// `shape`, consulting `source` at every round boundary for newly
    /// arrived compatible requests and completing each the moment it
    /// finishes. Returns when a boundary finds the group empty and the
    /// source has nothing to admit. The default serves requests serially
    /// (still re-polling the source between requests) for engines without
    /// a lockstep decode path.
    fn generate_continuous(
        &self,
        protein: &str,
        method: Method,
        shape: &LockstepShape,
        source: &mut dyn RequestSource,
    ) {
        let _ = shape;
        loop {
            let items = source.admit(0);
            if items.is_empty() {
                return;
            }
            for (ticket, cfg) in items {
                source.complete(ticket, self.generate(protein, method, &cfg));
            }
        }
    }
    /// Length-normalized NLL of a token sequence under the target model.
    fn score_nll(&self, tokens: &[u8]) -> Result<f64>;
    /// Target-model embedding of a token sequence.
    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>>;
    /// Family registry.
    fn families(&self) -> &[Family];
    fn family(&self, name: &str) -> Result<&Family> {
        self.families()
            .iter()
            .find(|f| f.meta.name == name)
            .ok_or_else(|| anyhow!("unknown protein {name}"))
    }
    /// Override the k-mer table used for a protein (App. C ablations).
    fn set_table_override(&mut self, protein: &str, table: Option<KmerTable>);
}

/// Generic engine over any backend pair.
pub struct Engine<D: ModelBackend, T: ModelBackend> {
    pub draft: PrefillCached<D>,
    pub target: PrefillCached<T>,
    families: Vec<Family>,
    overrides: HashMap<String, KmerTable>,
}

/// Per-request config normalization shared by `generate`, `generate_batch`
/// and the continuous-batching admission path: clamp max_len to the family
/// and degrade `Speculative` to single-candidate drafting.
fn normalized_cfg(cfg: &GenConfig, fam: &Family, method: Method) -> GenConfig {
    let mut cfg = cfg.clone();
    cfg.max_len = cfg.max_len.min(fam.max_len());
    if method == Method::Speculative {
        cfg.c = 1;
    }
    cfg
}

/// Adapts a worker's [`RequestSource`] to the decode layer's
/// [`AdmissionHook`]: attaches the family context and normalizes each
/// admitted config exactly like the non-continuous dispatch paths do.
struct SourceAdapter<'a> {
    source: &'a mut dyn RequestSource,
    fam: &'a Family,
    method: Method,
}

impl AdmissionHook for SourceAdapter<'_> {
    fn admit(&mut self, active: usize) -> Vec<AdmitItem> {
        self.source
            .admit(active)
            .into_iter()
            .map(|(ticket, cfg)| AdmitItem {
                ticket,
                context: self.fam.context.clone(),
                cfg: normalized_cfg(&cfg, self.fam, self.method),
            })
            .collect()
    }

    fn complete(&mut self, ticket: u64, result: Result<GenOutput>) {
        self.source.complete(ticket, result);
    }
}

impl<D: ModelBackend, T: ModelBackend> Engine<D, T> {
    pub fn new(draft: D, target: T, families: Vec<Family>) -> Engine<D, T> {
        Engine {
            draft: PrefillCached::new(draft),
            target: PrefillCached::new(target),
            families,
            overrides: HashMap::new(),
        }
    }
}

impl<D: ModelBackend, T: ModelBackend> GenEngine for Engine<D, T> {
    fn generate(&self, protein: &str, method: Method, cfg: &GenConfig) -> Result<GenOutput> {
        let fam = self.family(protein)?;
        let cfg = normalized_cfg(cfg, fam, method);
        match method {
            Method::TargetOnly => decode::target_only_generate(&self.target, &fam.context, &cfg),
            Method::DraftOnly => decode::target_only_generate(&self.draft, &fam.context, &cfg),
            Method::Speculative => {
                decode::speculative_generate(&self.draft, &self.target, None, &fam.context, &cfg)
            }
            Method::SpecMer => {
                let table = self.overrides.get(protein).unwrap_or(&fam.table);
                decode::speculative_generate(
                    &self.draft,
                    &self.target,
                    Some(table),
                    &fam.context,
                    &cfg,
                )
            }
        }
    }

    fn generate_batch(
        &self,
        protein: &str,
        method: Method,
        cfgs: &[GenConfig],
    ) -> Vec<Result<GenOutput>> {
        // only the speculative methods have a lockstep path; baselines (and
        // trivial batches) fall back to the serial loop
        if cfgs.len() <= 1 || !matches!(method, Method::Speculative | Method::SpecMer) {
            return cfgs.iter().map(|cfg| self.generate(protein, method, cfg)).collect();
        }
        let fam = match self.family(protein) {
            Ok(f) => f,
            Err(_) => {
                return cfgs
                    .iter()
                    .map(|_| Err(anyhow!("unknown protein {protein}")))
                    .collect()
            }
        };
        let table = match method {
            Method::SpecMer => Some(self.overrides.get(protein).unwrap_or(&fam.table)),
            _ => None,
        };
        // normalize per-request configs exactly like `generate` does
        let norm: Vec<GenConfig> =
            cfgs.iter().map(|cfg| normalized_cfg(cfg, fam, method)).collect();
        // group lockstep-compatible requests (equal dispatch shapes) and
        // run each group as one batched decode; order is restored at the end
        let compatible = |a: &GenConfig, b: &GenConfig| LockstepShape::of(a).admits(b);
        let mut results: Vec<Option<Result<GenOutput>>> = (0..norm.len()).map(|_| None).collect();
        let mut remaining: Vec<usize> = (0..norm.len()).collect();
        while let Some(&first) = remaining.first() {
            let group: Vec<usize> = remaining
                .iter()
                .copied()
                .filter(|&i| compatible(&norm[i], &norm[first]))
                .collect();
            remaining.retain(|i| !group.contains(i));
            let items: Vec<decode::SpecBatchItem<'_>> = group
                .iter()
                .map(|&i| decode::SpecBatchItem { context: &fam.context, cfg: &norm[i] })
                .collect();
            // per-item results: a single bad request fails alone, exactly
            // like the serial loop did
            let outs = decode::speculative_generate_batch(&self.draft, &self.target, table, &items);
            for (&i, out) in group.iter().zip(outs) {
                results[i] = Some(out);
            }
        }
        results.into_iter().map(|o| o.expect("every request answered")).collect()
    }

    fn lockstep_shape(
        &self,
        protein: &str,
        method: Method,
        cfg: &GenConfig,
    ) -> Option<LockstepShape> {
        // only the speculative methods have a lockstep decode; probe items
        // interleave extra dispatches and must take the sequential path
        if !matches!(method, Method::Speculative | Method::SpecMer) || cfg.probe_rate > 0.0 {
            return None;
        }
        let fam = self.family(protein).ok()?;
        Some(LockstepShape::of(&normalized_cfg(cfg, fam, method)))
    }

    fn generate_continuous(
        &self,
        protein: &str,
        method: Method,
        shape: &LockstepShape,
        source: &mut dyn RequestSource,
    ) {
        let fam = match self.family(protein) {
            Ok(f) => f,
            Err(e) => {
                // answer (not hang) everything the source still admits
                let msg = format!("{e:#}");
                loop {
                    let items = source.admit(0);
                    if items.is_empty() {
                        return;
                    }
                    for (ticket, _) in items {
                        source.complete(ticket, Err(anyhow!("{msg}")));
                    }
                }
            }
        };
        let table = match method {
            Method::SpecMer => Some(self.overrides.get(protein).unwrap_or(&fam.table)),
            _ => None,
        };
        let mut hook = SourceAdapter { source, fam, method };
        decode::speculative_generate_continuous(
            &self.draft,
            &self.target,
            table,
            *shape,
            &mut hook,
        );
    }

    fn score_nll(&self, tokens: &[u8]) -> Result<f64> {
        crate::eval::sequence_nll(&self.target, tokens)
    }

    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        self.target.embed(tokens)
    }

    fn families(&self) -> &[Family] {
        &self.families
    }

    fn set_table_override(&mut self, protein: &str, table: Option<KmerTable>) {
        match table {
            Some(t) => {
                self.overrides.insert(protein.to_string(), t);
            }
            None => {
                self.overrides.remove(protein);
            }
        }
    }
}

/// Build the engine described by `Config` (HLO unless `--cpu-ref`).
pub fn build_engine(cfg: &Config) -> Result<Box<dyn GenEngine>> {
    let families = load_families(&cfg.artifacts)?;
    if cfg.cpu_ref {
        let manifest = crate::params::load_manifest(&cfg.artifacts)?;
        let d = crate::params::load_model(&cfg.artifacts, &cfg.draft_model)?;
        let t = crate::params::load_model(&cfg.artifacts, &cfg.target_model)?;
        let draft = CpuModel::from_params(&d, manifest.vocab)?;
        let target = CpuModel::from_params(&t, manifest.vocab)?;
        Ok(Box::new(Engine::new(draft, target, families)))
    } else {
        let rt = Rc::new(Runtime::new(&cfg.artifacts)?);
        let draft = HloModel::load(Rc::clone(&rt), &cfg.artifacts, &cfg.draft_model)?;
        let target = HloModel::load(rt, &cfg.artifacts, &cfg.target_model)?;
        Ok(Box::new(Engine::new(draft, target, families)))
    }
}

/// Engine for benches/examples: real artifacts when present (default
/// `artifacts/` or `$SPECMER_ARTIFACTS`), otherwise the synthetic fallback
/// so every bench runs on a fresh checkout.
pub fn engine_for_bench() -> (Box<dyn GenEngine>, bool) {
    let mut cfg = Config::default();
    if let Ok(env) = std::env::var("SPECMER_ARTIFACTS") {
        cfg.artifacts = env.into();
    } else {
        // examples/benches run from the workspace root or rust/
        for cand in ["artifacts", "../artifacts"] {
            if std::path::Path::new(cand).join("manifest.json").exists() {
                cfg.artifacts = cand.into();
                break;
            }
        }
    }
    match build_engine(&cfg) {
        Ok(e) => (e, true),
        Err(e) => {
            eprintln!("[bench] no artifacts ({e}); using synthetic engine");
            (Box::new(synthetic_engine(3)), false)
        }
    }
}

/// A fully synthetic engine (no artifacts) for tests and CI smoke runs.
pub fn synthetic_engine(seed: u64) -> Engine<CpuModel, CpuModel> {
    let mut fams = Vec::new();
    for (i, (name, len, depth)) in
        [("SynA", 48usize, 40usize), ("SynB", 64, 40)].iter().enumerate()
    {
        let (_p, msa) = crate::msa::simulate::generate_family(name, *len, *depth, seed + i as u64);
        let meta = FamilyMeta {
            name: name.to_string(),
            paper_length: *len,
            length: *len,
            context: 6,
            paper_msa_depth: *depth,
            msa_depth: *depth,
            function: "synthetic".into(),
            wild_type: msa.wild_type.clone(),
        };
        fams.push(Family::from_msa(meta, msa));
    }
    let draft = CpuModel::synthetic(2, 16, 2, 96, seed ^ 1);
    let target = CpuModel::synthetic(2, 24, 2, 96, seed ^ 2);
    Engine::new(draft, target, fams)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_engine_generates_all_methods() {
        let eng = synthetic_engine(3);
        let cfg = GenConfig { max_len: 30, gamma: 5, c: 3, seed: 1, ..Default::default() };
        for method in [Method::TargetOnly, Method::Speculative, Method::SpecMer] {
            let out = eng.generate("SynA", method, &cfg).unwrap();
            assert!(out.tokens.len() > out.context_len, "{method:?}");
        }
    }

    #[test]
    fn unknown_protein_errors() {
        let eng = synthetic_engine(3);
        assert!(eng.generate("Nope", Method::SpecMer, &GenConfig::default()).is_err());
    }

    #[test]
    fn table_override_changes_selection() {
        let mut eng = synthetic_engine(5);
        let cfg = GenConfig { max_len: 40, gamma: 5, c: 5, seed: 9, ..Default::default() };
        let a = eng.generate("SynA", Method::SpecMer, &cfg).unwrap();
        // override SynA's table with SynB's (cross-protein ablation)
        let other = eng.family("SynB").unwrap().table.clone();
        eng.set_table_override("SynA", Some(other));
        let b = eng.generate("SynA", Method::SpecMer, &cfg).unwrap();
        eng.set_table_override("SynA", None);
        let c = eng.generate("SynA", Method::SpecMer, &cfg).unwrap();
        assert_eq!(a.tokens, c.tokens, "override removal restores behaviour");
        // with same seed, the only difference is candidate selection; the
        // draws are identical so outputs differ only if selection differed
        // at least once — extremely likely across a full sequence.
        let _ = b;
    }

    // batch-vs-serial engine equivalence across all methods lives in
    // tests/batch_decode_equivalence.rs (public-API integration test)

    #[test]
    fn generate_batch_unknown_protein_fails_every_request() {
        let eng = synthetic_engine(3);
        let cfgs = vec![GenConfig::default(), GenConfig::default()];
        let batch = eng.generate_batch("Nope", Method::SpecMer, &cfgs);
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.is_err()));
    }

    #[test]
    fn max_len_clamped_to_family() {
        let eng = synthetic_engine(7);
        let cfg = GenConfig { max_len: 10_000, gamma: 5, c: 1, seed: 2, ..Default::default() };
        let out = eng.generate("SynA", Method::Speculative, &cfg).unwrap();
        assert!(out.tokens.len() <= eng.family("SynA").unwrap().max_len());
    }

    #[test]
    fn score_and_embed_work() {
        let eng = synthetic_engine(11);
        let toks = eng.family("SynA").unwrap().context.clone();
        assert!(eng.score_nll(&toks).unwrap() > 0.0);
        assert_eq!(eng.embed(&toks).unwrap().len(), 24);
    }
}
