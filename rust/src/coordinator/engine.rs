//! The per-worker generation engine behind the [`SeqSpec`]-first API: a
//! request is resolved **once** — family registry lookup, k-mer table
//! `Arc` handle, config normalization — into a per-sequence scoring plan
//! ([`GenEngine::spec`] / [`FamilyRegistry::spec`]), and every decode
//! entry point (`generate`, `generate_batch`, `generate_continuous`) takes
//! specs instead of `(protein, method, cfg)` tuples. Because the table and
//! context ride on the spec, the batched paths group purely on the
//! lockstep dispatch shape: one group may mix protein families and
//! SpecMER/vanilla-speculative methods, and continuous admission splices
//! any shape-compatible request into the in-flight group.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{Config, Method};
use crate::decode::{self, AdmitItem, GenConfig, GenOutput, LockstepShape, PrefixParams};
use crate::eval::PlddtScorer;
use crate::kmer::KmerTable;
use crate::msa::{self, FamilyMeta, Msa};
use crate::runtime::prefill_cache::PrefillCached;
use crate::runtime::{
    CpuModel, HloModel, ModelBackend, PrefixStats, PrefixStore, Residency, Runtime,
};
use crate::tokenizer::{self, BOS};

use super::request::SeqSpec;

/// Per-family state: metadata, MSA-derived k-mer table, context tokens.
/// The name, table and context are shared handles so a [`SeqSpec`]
/// resolution is a few `Arc` clones, not `String`/table/token copies.
pub struct Family {
    /// Canonical identifier (mirrors `meta.name`; lookups key on this).
    pub name: Arc<str>,
    pub meta: FamilyMeta,
    pub table: Arc<KmerTable>,
    pub context: Arc<[u8]>,
    pub wt_tokens: Vec<u8>,
    pub msa: Msa,
}

impl Family {
    pub fn from_msa(meta: FamilyMeta, msa: Msa) -> Family {
        let wt_tokens = tokenizer::encode(&meta.wild_type);
        let mut context = vec![BOS];
        context.extend(&wt_tokens[..meta.context.min(wt_tokens.len())]);
        Family {
            name: Arc::from(meta.name.as_str()),
            table: Arc::new(KmerTable::build(&msa)),
            context: context.into(),
            wt_tokens,
            meta,
            msa,
        }
    }

    /// Max total token length for generation: BOS + wild-type + EOS.
    pub fn max_len(&self) -> usize {
        self.wt_tokens.len() + 2
    }

    pub fn plddt_scorer(&self) -> PlddtScorer {
        PlddtScorer::from_msa(&self.msa)
    }
}

/// The one family lookup both resolvers (router-side registry, engine-side
/// `GenEngine::family`) share — a single source of truth for name matching
/// and the unknown-protein error.
fn find_family<'a>(families: &'a [Arc<Family>], name: &str) -> Result<&'a Arc<Family>> {
    families
        .iter()
        .find(|f| &*f.name == name)
        .ok_or_else(|| anyhow!("unknown protein {name}"))
}

/// Shared family registry: the submission-side resolver for [`SeqSpec`]s.
/// Loaded once per process and handed to the router *and* the worker
/// engine factories, so families are resolved exactly once per request —
/// workers never do a name lookup again.
pub struct FamilyRegistry {
    families: Vec<Arc<Family>>,
}

impl FamilyRegistry {
    pub fn new(families: Vec<Arc<Family>>) -> FamilyRegistry {
        FamilyRegistry { families }
    }

    /// Load every family from artifacts (families.json + msa/*.a2m).
    pub fn load(artifacts: &Path) -> Result<FamilyRegistry> {
        Ok(FamilyRegistry::new(load_families(artifacts)?))
    }

    pub fn families(&self) -> &[Arc<Family>] {
        &self.families
    }

    pub fn get(&self, name: &str) -> Result<&Arc<Family>> {
        find_family(&self.families, name)
    }

    /// Resolve a request into its per-sequence scoring plan.
    pub fn spec(&self, protein: &str, method: Method, cfg: &GenConfig) -> Result<SeqSpec> {
        Ok(SeqSpec::resolve(self.get(protein)?, method, cfg, None))
    }
}

/// Load every family from artifacts (families.json + msa/*.a2m).
pub fn load_families(artifacts: &Path) -> Result<Vec<Arc<Family>>> {
    let metas = msa::load_families(&artifacts.join("families.json"))
        .map_err(|e| anyhow!("loading families.json from {}: {e:#}", artifacts.display()))?;
    metas
        .into_iter()
        .map(|meta| {
            let m = Msa::load(&artifacts.join("msa").join(format!("{}.a2m", meta.name)), &meta.name)?;
            Ok(Arc::new(Family::from_msa(meta, m)))
        })
        .collect()
}

/// Where the worker's continuous-batching dispatch pulls new requests from
/// and delivers finished ones to. The worker implements this over its
/// batcher: `admit` is called at every draft/verify round boundary and may
/// pop newly-queued shape-compatible requests — *any* protein or
/// speculative method; `complete` fires the moment any sequence finishes,
/// so clients are answered mid-flight.
pub trait RequestSource {
    /// Called at each round boundary with the number of sequences still in
    /// flight; returns `(ticket, spec)` pairs to admit into the group.
    fn admit(&mut self, active: usize) -> Vec<(u64, SeqSpec)>;
    /// Delivers one request's final result (exactly once per ticket).
    fn complete(&mut self, ticket: u64, result: Result<GenOutput>);
    /// Called at each round boundary with the resident tickets; returns the
    /// sequences to cancel mid-group (deadline enforcement, injected
    /// faults) and the error each is answered with via [`Self::complete`].
    /// Defaults to cancelling nothing.
    fn cancel(&mut self, resident: &[u64]) -> Vec<(u64, anyhow::Error)> {
        let _ = resident;
        Vec::new()
    }
}

/// How a worker turns on its resident shared-prefix KV cache
/// ([`GenEngine::enable_prefix_cache`]): a per-worker byte budget (split
/// evenly between the draft and target stores), the chunked-prefill knob,
/// and the coordinator's [`Residency`] map the *target* store publishes
/// its resident context keys into (for the router's family affinity).
pub struct PrefixCacheOpts {
    /// Total snapshot budget in bytes across both stores (0 disables).
    pub cap_bytes: usize,
    /// Max context tokens prefilled per model per round boundary for a
    /// cold admission (0 = one-shot prefill).
    pub prefill_chunk: usize,
    /// Coordinator-shared residency map; `worker` is this worker's id in it.
    pub residency: Option<Arc<Residency>>,
    pub worker: usize,
}

/// Object-safe engine interface used by the scheduler, server and benches.
/// Decode entry points take resolved [`SeqSpec`]s; `spec` (and the router's
/// registry) is where `(protein, method, cfg)` is resolved exactly once.
pub trait GenEngine {
    /// Resolve a request into its per-sequence scoring plan (family
    /// lookup, table handle, config normalization). Engines with table
    /// overrides apply them here.
    fn spec(&self, protein: &str, method: Method, cfg: &GenConfig) -> Result<SeqSpec> {
        Ok(SeqSpec::resolve(self.family(protein)?, method, cfg, None))
    }
    /// Generate one sequence from a resolved spec.
    fn generate(&self, spec: &SeqSpec) -> Result<GenOutput>;
    /// Convenience for direct drivers (examples, experiments): resolve and
    /// generate in one call.
    fn generate_for(&self, protein: &str, method: Method, cfg: &GenConfig) -> Result<GenOutput> {
        self.generate(&self.spec(protein, method, cfg)?)
    }
    /// Generate a whole batcher batch in a single call, returning
    /// per-request results in order. Specs may mix proteins and methods:
    /// the default loops [`GenEngine::generate`]; `Engine` overrides it to
    /// run lockstep-compatible specs (equal `(c, gamma)` — tables, contexts
    /// and sampling params are per-sequence) through
    /// [`decode::speculative_generate_batch`] so one decode round serves
    /// the whole group.
    fn generate_batch(&self, specs: &[SeqSpec]) -> Vec<Result<GenOutput>> {
        specs.iter().map(|spec| self.generate(spec)).collect()
    }
    /// The lockstep dispatch shape `spec` would decode under, if the
    /// engine can serve it on the continuous-batching path (None → the
    /// request must go through [`GenEngine::generate_batch`]).
    fn lockstep_shape(&self, spec: &SeqSpec) -> Option<LockstepShape> {
        let _ = spec;
        None
    }
    /// Continuous batching: run one in-flight lockstep group of shape
    /// `shape`, consulting `source` at every round boundary for newly
    /// arrived shape-compatible requests — whatever their protein or
    /// method — and completing each the moment it finishes. Returns when a
    /// boundary finds the group empty and the source has nothing to admit.
    /// The default serves requests serially (still re-polling the source
    /// between requests, and offering each ticket for cancellation before
    /// decoding it) for engines without a lockstep decode path.
    fn generate_continuous(&self, shape: &LockstepShape, source: &mut dyn RequestSource) {
        let _ = shape;
        loop {
            let items = source.admit(0);
            if items.is_empty() {
                return;
            }
            for (ticket, spec) in items {
                if let Some((_, err)) = source.cancel(&[ticket]).into_iter().next() {
                    source.complete(ticket, Err(err));
                    continue;
                }
                source.complete(ticket, self.generate(&spec));
            }
        }
    }
    /// Turn on the worker-resident shared-prefix KV cache for the
    /// continuous-batching path. Default: unsupported, silently off —
    /// engines without prefix reuse keep their exact previous behavior.
    fn enable_prefix_cache(&mut self, opts: PrefixCacheOpts) {
        let _ = opts;
    }
    /// Combined stats of this engine's prefix stores (None when the cache
    /// is off or unsupported). Feeds the `/metrics` prefix_cache_* family.
    fn prefix_stats(&self) -> Option<PrefixStats> {
        None
    }
    /// Length-normalized NLL of a token sequence under the target model.
    fn score_nll(&self, tokens: &[u8]) -> Result<f64>;
    /// Target-model embedding of a token sequence.
    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>>;
    /// Family registry.
    fn families(&self) -> &[Arc<Family>];
    fn family(&self, name: &str) -> Result<&Arc<Family>> {
        find_family(self.families(), name)
    }
    /// Override the k-mer table used for a protein (App. C ablations);
    /// applied by [`GenEngine::spec`] at resolution time.
    fn set_table_override(&mut self, protein: &str, table: Option<Arc<KmerTable>>);
}

/// Generic engine over any backend pair.
pub struct Engine<D: ModelBackend, T: ModelBackend> {
    pub draft: PrefillCached<D>,
    pub target: PrefillCached<T>,
    families: Vec<Arc<Family>>,
    overrides: HashMap<String, Arc<KmerTable>>,
    /// Prefix-store / chunked-prefill params for the continuous path
    /// (None = off). `Rc` inside: engines live on one worker thread.
    prefix: Option<PrefixParams>,
}

/// Adapts a worker's [`RequestSource`] to the decode layer's
/// [`decode::AdmissionHook`]: specs arrive fully resolved, so this is a
/// plain repack into owned [`AdmitItem`]s (context, config, table handle).
struct SourceAdapter<'a> {
    source: &'a mut dyn RequestSource,
}

impl decode::AdmissionHook for SourceAdapter<'_> {
    fn admit(&mut self, active: usize) -> Vec<AdmitItem> {
        self.source
            .admit(active)
            .into_iter()
            .map(|(ticket, spec)| AdmitItem {
                ticket,
                // the decode layer owns its copy (it becomes the output
                // token buffer's prefix); the only context copy per request
                context: spec.context.to_vec(),
                cfg: spec.cfg,
                table: spec.table,
            })
            .collect()
    }

    fn complete(&mut self, ticket: u64, result: Result<GenOutput>) {
        self.source.complete(ticket, result);
    }

    fn cancel(&mut self, resident: &[u64]) -> Vec<(u64, anyhow::Error)> {
        self.source.cancel(resident)
    }
}

impl<D: ModelBackend, T: ModelBackend> Engine<D, T> {
    pub fn new(draft: D, target: T, families: Vec<Arc<Family>>) -> Engine<D, T> {
        Engine {
            draft: PrefillCached::new(draft),
            target: PrefillCached::new(target),
            families,
            overrides: HashMap::new(),
            prefix: None,
        }
    }
}

impl<D: ModelBackend, T: ModelBackend> GenEngine for Engine<D, T> {
    fn spec(&self, protein: &str, method: Method, cfg: &GenConfig) -> Result<SeqSpec> {
        let fam = self.family(protein)?;
        Ok(SeqSpec::resolve(fam, method, cfg, self.overrides.get(protein)))
    }

    fn generate(&self, spec: &SeqSpec) -> Result<GenOutput> {
        match spec.method {
            Method::TargetOnly => {
                decode::target_only_generate(&self.target, &spec.context, &spec.cfg)
            }
            Method::DraftOnly => {
                decode::target_only_generate(&self.draft, &spec.context, &spec.cfg)
            }
            Method::Speculative | Method::SpecMer => decode::speculative_generate(
                &self.draft,
                &self.target,
                spec.table.as_deref(),
                &spec.context,
                &spec.cfg,
            ),
        }
    }

    fn generate_batch(&self, specs: &[SeqSpec]) -> Vec<Result<GenOutput>> {
        if specs.len() <= 1 {
            return specs.iter().map(|spec| self.generate(spec)).collect();
        }
        let mut results: Vec<Option<Result<GenOutput>>> = (0..specs.len()).map(|_| None).collect();
        // baselines and probe items have no lockstep decode: serial loop
        let mut remaining: Vec<usize> = Vec::new();
        for (i, spec) in specs.iter().enumerate() {
            if spec.lockstep_shape().is_none() {
                results[i] = Some(self.generate(spec));
            } else {
                remaining.push(i);
            }
        }
        // group shape-compatible specs — proteins and methods mix freely —
        // and run each group as one batched decode; order restored at the end
        while let Some(&first) = remaining.first() {
            let shape = specs[first].lockstep_shape();
            let group: Vec<usize> = remaining
                .iter()
                .copied()
                .filter(|&i| specs[i].lockstep_shape() == shape)
                .collect();
            remaining.retain(|i| !group.contains(i));
            let items: Vec<decode::SpecBatchItem<'_>> = group
                .iter()
                .map(|&i| decode::SpecBatchItem {
                    context: &specs[i].context,
                    cfg: &specs[i].cfg,
                    table: specs[i].table.clone(),
                })
                .collect();
            // per-item results: a single bad request fails alone, exactly
            // like the serial loop did
            let outs = decode::speculative_generate_batch(&self.draft, &self.target, &items);
            for (&i, out) in group.iter().zip(outs) {
                results[i] = Some(out);
            }
        }
        // a slot left unanswered is an engine bug, but on the serving path it
        // must surface as that request's error, never a worker panic
        results
            .into_iter()
            .map(|o| {
                o.unwrap_or_else(|| {
                    Err(anyhow::anyhow!(
                        "internal: batch slot left unanswered by the grouped decode"
                    ))
                })
            })
            .collect()
    }

    fn lockstep_shape(&self, spec: &SeqSpec) -> Option<LockstepShape> {
        spec.lockstep_shape()
    }

    fn generate_continuous(&self, shape: &LockstepShape, source: &mut dyn RequestSource) {
        let mut hook = SourceAdapter { source };
        let params = self.prefix.clone().unwrap_or_default();
        decode::speculative_generate_continuous_with(
            &self.draft,
            &self.target,
            *shape,
            &mut hook,
            params,
        );
    }

    fn enable_prefix_cache(&mut self, opts: PrefixCacheOpts) {
        if opts.cap_bytes == 0 {
            self.prefix = None;
            return;
        }
        // split the byte budget evenly; only the target store publishes
        // residency (one key announcement per context is enough for routing)
        let half = opts.cap_bytes / 2;
        let target_store = match opts.residency {
            Some(res) => PrefixStore::with_residency(half, res, opts.worker),
            None => PrefixStore::new(half),
        };
        self.prefix = Some(PrefixParams {
            draft_store: Some(Rc::new(RefCell::new(PrefixStore::new(opts.cap_bytes - half)))),
            target_store: Some(Rc::new(RefCell::new(target_store))),
            prefill_chunk: opts.prefill_chunk,
        });
    }

    fn prefix_stats(&self) -> Option<PrefixStats> {
        let params = self.prefix.as_ref()?;
        let mut stats = PrefixStats::default();
        if let Some(st) = &params.draft_store {
            stats = stats.merge(st.borrow().stats());
        }
        if let Some(st) = &params.target_store {
            stats = stats.merge(st.borrow().stats());
        }
        Some(stats)
    }

    fn score_nll(&self, tokens: &[u8]) -> Result<f64> {
        crate::eval::sequence_nll(&self.target, tokens)
    }

    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        self.target.embed(tokens)
    }

    fn families(&self) -> &[Arc<Family>] {
        &self.families
    }

    fn set_table_override(&mut self, protein: &str, table: Option<Arc<KmerTable>>) {
        match table {
            Some(t) => {
                self.overrides.insert(protein.to_string(), t);
            }
            None => {
                self.overrides.remove(protein);
            }
        }
    }
}

/// Build the engine described by `Config` (HLO unless `--cpu-ref`),
/// loading its own family set from artifacts.
pub fn build_engine(cfg: &Config) -> Result<Box<dyn GenEngine>> {
    build_engine_with(cfg, load_families(&cfg.artifacts)?)
}

/// Build an engine around an already-loaded (shared) family set — the
/// serving path hands every worker the same `Arc<Family>` handles the
/// router resolves specs from, so families load once per process.
pub fn build_engine_with(cfg: &Config, families: Vec<Arc<Family>>) -> Result<Box<dyn GenEngine>> {
    if cfg.cpu_ref {
        let manifest = crate::params::load_manifest(&cfg.artifacts)?;
        let d = crate::params::load_model(&cfg.artifacts, &cfg.draft_model)?;
        let t = crate::params::load_model(&cfg.artifacts, &cfg.target_model)?;
        let draft = CpuModel::from_params(&d, manifest.vocab)?;
        let target = CpuModel::from_params(&t, manifest.vocab)?;
        Ok(Box::new(Engine::new(draft, target, families)))
    } else {
        let rt = Arc::new(Runtime::new(&cfg.artifacts)?);
        let draft = HloModel::load(Arc::clone(&rt), &cfg.artifacts, &cfg.draft_model)?;
        let target = HloModel::load(rt, &cfg.artifacts, &cfg.target_model)?;
        Ok(Box::new(Engine::new(draft, target, families)))
    }
}

/// Engine for benches/examples: real artifacts when present (default
/// `artifacts/` or `$SPECMER_ARTIFACTS`), otherwise the synthetic fallback
/// so every bench runs on a fresh checkout.
pub fn engine_for_bench() -> (Box<dyn GenEngine>, bool) {
    let mut cfg = Config::default();
    if let Ok(env) = std::env::var("SPECMER_ARTIFACTS") {
        cfg.artifacts = env.into();
    } else {
        // examples/benches run from the workspace root or rust/
        for cand in ["artifacts", "../artifacts"] {
            if std::path::Path::new(cand).join("manifest.json").exists() {
                cfg.artifacts = cand.into();
                break;
            }
        }
    }
    match build_engine(&cfg) {
        Ok(e) => (e, true),
        Err(e) => {
            eprintln!("[bench] no artifacts ({e}); using synthetic engine");
            (Box::new(synthetic_engine(3)) as Box<dyn GenEngine>, false)
        }
    }
}

/// The synthetic family set backing [`synthetic_engine`] — also what test
/// stacks hand to a [`FamilyRegistry`] so the router resolves against the
/// exact same `Arc<Family>` data the workers decode with.
pub fn synthetic_families(seed: u64) -> Vec<Arc<Family>> {
    let mut fams = Vec::new();
    for (i, (name, len, depth)) in
        [("SynA", 48usize, 40usize), ("SynB", 64, 40)].iter().enumerate()
    {
        let (_p, msa) = crate::msa::simulate::generate_family(name, *len, *depth, seed + i as u64);
        let meta = FamilyMeta {
            name: name.to_string(),
            paper_length: *len,
            length: *len,
            context: 6,
            paper_msa_depth: *depth,
            msa_depth: *depth,
            function: "synthetic".into(),
            wild_type: msa.wild_type.clone(),
        };
        fams.push(Arc::new(Family::from_msa(meta, msa)));
    }
    fams
}

/// A fully synthetic engine (no artifacts) for tests and CI smoke runs.
pub fn synthetic_engine(seed: u64) -> Engine<CpuModel, CpuModel> {
    let draft = CpuModel::synthetic(2, 16, 2, 96, seed ^ 1);
    let target = CpuModel::synthetic(2, 24, 2, 96, seed ^ 2);
    Engine::new(draft, target, synthetic_families(seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_engine_generates_all_methods() {
        let eng = synthetic_engine(3);
        let cfg = GenConfig { max_len: 30, gamma: 5, c: 3, seed: 1, ..Default::default() };
        for method in [Method::TargetOnly, Method::Speculative, Method::SpecMer] {
            let out = eng.generate_for("SynA", method, &cfg).unwrap();
            assert!(out.tokens.len() > out.context_len, "{method:?}");
        }
    }

    #[test]
    fn unknown_protein_errors_at_resolution() {
        let eng = synthetic_engine(3);
        assert!(eng.spec("Nope", Method::SpecMer, &GenConfig::default()).is_err());
        assert!(eng.generate_for("Nope", Method::SpecMer, &GenConfig::default()).is_err());
    }

    #[test]
    fn spec_resolves_table_and_normalizes_once() {
        let eng = synthetic_engine(3);
        let cfg = GenConfig { max_len: 10_000, gamma: 5, c: 3, seed: 1, ..Default::default() };
        let s = eng.spec("SynA", Method::SpecMer, &cfg).unwrap();
        assert_eq!(&*s.protein, "SynA");
        assert!(s.table.is_some(), "SpecMER spec pins its family table");
        assert!(
            Arc::ptr_eq(s.table.as_ref().unwrap(), &eng.family("SynA").unwrap().table),
            "spec shares the family table handle, no copy"
        );
        assert_eq!(s.cfg.max_len, eng.family("SynA").unwrap().max_len());
        // Speculative normalizes to single-candidate drafting, no table
        let sp = eng.spec("SynA", Method::Speculative, &cfg).unwrap();
        assert_eq!(sp.cfg.c, 1);
        assert!(sp.table.is_none());
        // baselines have no lockstep shape; spec methods expose (c, gamma)
        assert!(eng.spec("SynA", Method::TargetOnly, &cfg).unwrap().lockstep_shape().is_none());
        let shape = s.lockstep_shape().unwrap();
        assert_eq!((shape.c, shape.gamma), (3, 5));
    }

    #[test]
    fn table_override_changes_selection() {
        let mut eng = synthetic_engine(5);
        let cfg = GenConfig { max_len: 40, gamma: 5, c: 5, seed: 9, ..Default::default() };
        let a = eng.generate_for("SynA", Method::SpecMer, &cfg).unwrap();
        // override SynA's table with SynB's (cross-protein ablation)
        let other = eng.family("SynB").unwrap().table.clone();
        eng.set_table_override("SynA", Some(other.clone()));
        assert!(
            Arc::ptr_eq(
                eng.spec("SynA", Method::SpecMer, &cfg).unwrap().table.as_ref().unwrap(),
                &other
            ),
            "override applied at spec resolution"
        );
        let b = eng.generate_for("SynA", Method::SpecMer, &cfg).unwrap();
        eng.set_table_override("SynA", None);
        let c = eng.generate_for("SynA", Method::SpecMer, &cfg).unwrap();
        assert_eq!(a.tokens, c.tokens, "override removal restores behaviour");
        // with same seed, the only difference is candidate selection; the
        // draws are identical so outputs differ only if selection differed
        // at least once — extremely likely across a full sequence.
        let _ = b;
    }

    // batch-vs-serial engine equivalence across all methods lives in
    // tests/batch_decode_equivalence.rs (public-API integration test)

    #[test]
    fn generate_batch_mixes_proteins_and_methods() {
        // the tentpole at the engine level: one batch, two proteins, two
        // methods, one lockstep group per (c, gamma) — bitwise equal to
        // per-request solo decodes
        let eng = synthetic_engine(3);
        let base = GenConfig { max_len: 26, gamma: 5, c: 1, seed: 0, ..Default::default() };
        let mk = |protein: &str, method: Method, seed: u64| {
            let mut c = base.clone();
            c.seed = seed;
            eng.spec(protein, method, &c).unwrap()
        };
        let specs = vec![
            mk("SynA", Method::SpecMer, 1),
            mk("SynB", Method::Speculative, 2),
            mk("SynB", Method::SpecMer, 3),
            mk("SynA", Method::Speculative, 4),
        ];
        let batch = eng.generate_batch(&specs);
        for (i, (got, spec)) in batch.iter().zip(&specs).enumerate() {
            let want = eng.generate(spec).unwrap();
            let got = got.as_ref().expect("batched request failed");
            assert_eq!(got.tokens, want.tokens, "mixed-key req {i} diverged");
        }
    }

    #[test]
    fn max_len_clamped_to_family() {
        let eng = synthetic_engine(7);
        let cfg = GenConfig { max_len: 10_000, gamma: 5, c: 1, seed: 2, ..Default::default() };
        let out = eng.generate_for("SynA", Method::Speculative, &cfg).unwrap();
        assert!(out.tokens.len() <= eng.family("SynA").unwrap().max_len());
    }

    #[test]
    fn score_and_embed_work() {
        let eng = synthetic_engine(11);
        let toks = eng.family("SynA").unwrap().context.clone();
        assert!(eng.score_nll(&toks).unwrap() > 0.0);
        assert_eq!(eng.embed(&toks).unwrap().len(), 24);
    }

    #[test]
    fn registry_resolves_same_specs_as_engine() {
        let fams = synthetic_families(3);
        let reg = FamilyRegistry::new(fams.clone());
        let eng = Engine::new(
            CpuModel::synthetic(2, 16, 2, 96, 2),
            CpuModel::synthetic(2, 24, 2, 96, 5),
            fams,
        );
        let cfg = GenConfig { max_len: 30, gamma: 5, c: 3, seed: 1, ..Default::default() };
        let a = reg.spec("SynB", Method::SpecMer, &cfg).unwrap();
        let b = eng.spec("SynB", Method::SpecMer, &cfg).unwrap();
        assert_eq!(a.context, b.context);
        assert!(Arc::ptr_eq(a.table.as_ref().unwrap(), b.table.as_ref().unwrap()));
        assert!(reg.spec("Nope", Method::SpecMer, &cfg).is_err());
    }

    #[test]
    fn batch_answers_every_slot_even_on_per_item_errors() {
        // regression: a failing request must come back as its own Err slot —
        // the serving path never panics over a batch slot (the old code
        // `expect`ed every slot answered)
        let eng = synthetic_engine(3);
        let base = GenConfig { max_len: 26, gamma: 5, c: 1, seed: 0, ..Default::default() };
        let good = eng.spec("SynA", Method::Speculative, &base).unwrap();
        let mut bad = eng.spec("SynB", Method::Speculative, &base).unwrap();
        bad.cfg.gamma = 0; // invalid: rejected per-item inside its group
        let outs = eng.generate_batch(&[good, bad]);
        assert_eq!(outs.len(), 2, "every slot answered");
        assert!(outs[0].is_ok(), "valid request unaffected");
        assert!(outs[1].is_err(), "invalid request fails alone");
    }

    #[test]
    fn prefix_cache_off_by_default_and_toggleable() {
        let mut eng = synthetic_engine(3);
        assert!(eng.prefix_stats().is_none(), "cache must be opt-in");
        eng.enable_prefix_cache(PrefixCacheOpts {
            cap_bytes: 1 << 20,
            prefill_chunk: 4,
            residency: Some(Arc::new(Residency::new())),
            worker: 2,
        });
        assert_eq!(eng.prefix_stats(), Some(PrefixStats::default()));
        eng.enable_prefix_cache(PrefixCacheOpts {
            cap_bytes: 0,
            prefill_chunk: 4,
            residency: None,
            worker: 2,
        });
        assert!(eng.prefix_stats().is_none(), "cap 0 turns the cache back off");
    }

    struct OneShotSource {
        items: Vec<(u64, SeqSpec)>,
        done: Vec<(u64, Result<GenOutput>)>,
    }

    impl RequestSource for OneShotSource {
        fn admit(&mut self, _active: usize) -> Vec<(u64, SeqSpec)> {
            std::mem::take(&mut self.items)
        }
        fn complete(&mut self, ticket: u64, result: Result<GenOutput>) {
            self.done.push((ticket, result));
        }
    }

    #[test]
    fn continuous_with_prefix_cache_matches_plain_and_publishes_residency() {
        let mut eng = synthetic_engine(3);
        let cfg = GenConfig { max_len: 30, gamma: 5, c: 3, seed: 1, ..Default::default() };
        let spec = eng.spec("SynA", Method::SpecMer, &cfg).unwrap();
        let shape = eng.lockstep_shape(&spec).unwrap();
        let want = eng.generate(&spec).unwrap();
        let res = Arc::new(Residency::new());
        eng.enable_prefix_cache(PrefixCacheOpts {
            cap_bytes: 16 << 20,
            prefill_chunk: 2,
            residency: Some(Arc::clone(&res)),
            worker: 1,
        });
        let mut src = OneShotSource { items: vec![(7, spec)], done: Vec::new() };
        eng.generate_continuous(&shape, &mut src);
        assert_eq!(src.done.len(), 1);
        let got = src.done[0].1.as_ref().unwrap();
        assert_eq!(got.tokens, want.tokens, "chunk-admitted run diverged from plain decode");
        // the target store must have published the family context's key so
        // the router can see this worker as warm for SynA
        let key = crate::runtime::context_key(&eng.family("SynA").unwrap().context);
        assert_eq!(res.holders(key), vec![1]);
        let stats = eng.prefix_stats().unwrap();
        assert!(stats.misses >= 1, "cold admission must count a miss");
        assert_eq!(stats.entries, 2, "one snapshot per store after the publish");
    }
}
