//! Typed serving errors for the hardened request path.
//!
//! Load shedding and deadline enforcement need the HTTP layer to answer
//! with *specific* status codes (429 + `Retry-After`, 504), so these
//! conditions travel as a concrete [`GenError`] inside `anyhow::Error`
//! (recovered with `downcast_ref`) rather than as message strings.

use std::fmt;

/// Why the serving stack refused or abandoned a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GenError {
    /// Admission was refused: the target worker queue is at capacity, the
    /// router's in-flight concurrency limit is reached, or the server is
    /// draining. The client should back off for `retry_after_ms`.
    Overloaded { retry_after_ms: u64 },
    /// The request's deadline passed — at submission, at batch pop, or at
    /// a lockstep round boundary mid-group.
    DeadlineExceeded,
}

impl GenError {
    /// Classify an opaque error from a [`GenResponse`](crate::coordinator::GenResponse).
    pub fn of(err: &anyhow::Error) -> Option<GenError> {
        err.downcast_ref::<GenError>().copied()
    }
}

impl fmt::Display for GenError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GenError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded: retry after {retry_after_ms}ms")
            }
            GenError::DeadlineExceeded => f.write_str("deadline exceeded"),
        }
    }
}

impl std::error::Error for GenError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_through_anyhow_with_context() {
        let e = anyhow::Error::from(GenError::Overloaded { retry_after_ms: 250 })
            .context("submitting request");
        assert_eq!(GenError::of(&e), Some(GenError::Overloaded { retry_after_ms: 250 }));
        assert_eq!(format!("{e:#}"), "submitting request: overloaded: retry after 250ms");

        let e = anyhow::Error::from(GenError::DeadlineExceeded);
        assert_eq!(GenError::of(&e), Some(GenError::DeadlineExceeded));
        assert_eq!(GenError::of(&anyhow::anyhow!("deadline exceeded")), None);
    }
}
