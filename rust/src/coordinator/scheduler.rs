//! Worker scheduler: N worker threads, each owning a [`GenEngine`]
//! (engines hold PJRT handles and are deliberately !Send — they are built
//! *inside* their worker thread from a Send factory), fed by per-worker
//! batchers behind a mutex+condvar.
//!
//! Dispatch is **continuously batched** (vLLM-style) and — since the
//! [`SeqSpec`] redesign — **shape-keyed**: a popped batch whose requests
//! have a lockstep dispatch shape runs through
//! [`GenEngine::generate_continuous`], and at *every* draft/verify round
//! boundary the worker re-polls its queue (under the existing mutex) and
//! splices newly-arrived shape-compatible requests into the in-flight
//! group — *whatever their protein or method*, since each sequence carries
//! its own k-mer table and context on its spec — while finished sequences
//! are answered the moment they complete. Admission soft-prefers the
//! group's majority protein (table/prefill locality) without starving
//! foreign proteins. Baselines and probe items batch under the `None` key
//! and go through the plain [`GenEngine::generate_batch`] dispatch.
//! Queued and in-flight work are tracked separately (the router's
//! least-loaded signal is their sum), and workers with queued but
//! not-yet-aged work sleep on the condvar until the oldest request's
//! `max_wait` deadline.
//!
//! The path is hardened for overload (docs/serving.md): worker queues are
//! **bounded** — [`Scheduler::submit_to`] sheds with a typed
//! [`GenError::Overloaded`] reply at capacity instead of enqueueing
//! without limit; request **deadlines** are enforced at batch pop and, via
//! [`RequestSource::cancel`], at every lockstep round boundary (mid-group
//! cancellation through the group's normal retirement path, so surviving
//! batchmates stay bitwise identical to their solo runs); a worker whose
//! engine factory fails marks itself dead and **requeues its queued
//! requests to surviving workers** (error-answering only when none is
//! live); [`Scheduler::begin_drain`] switches the fleet to graceful
//! shutdown — in-flight groups finish (or hit their deadlines), queued and
//! new requests are shed; and a seeded [`FaultPlan`] injects engine-build
//! failures, round errors, and round latency for deterministic chaos
//! tests.
//!
//! Each worker also owns a pair of **shared-prefix KV stores**
//! (`runtime::prefix_store`, sized by [`SchedulerOpts::prefix_cache_mb`]):
//! admission of a request whose family context was prefilled before on
//! this worker attaches the cached rows copy-on-write instead of
//! recomputing prefill, and a cold long context is prefilled in
//! [`SchedulerOpts::prefill_chunk`]-token slices across round boundaries
//! so an in-flight group is never stalled behind one full-context
//! forward. Workers publish which context keys they hold into a
//! process-wide [`Residency`] table that the router reads for soft
//! family-affinity placement, and refresh their per-worker
//! `specmer_prefix_cache_*` gauges after every dispatch.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::{Batcher, DEFAULT_QUEUE_CAPACITY};
use super::engine::{GenEngine, PrefixCacheOpts, RequestSource};
use super::error::GenError;
use super::fault::{FaultPlan, FaultState};
use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse, SeqSpec};
use crate::config::Method;
use crate::decode::GenOutput;
use crate::runtime::Residency;

/// Send-able engine constructor run inside each worker thread.
pub type EngineFactory = Arc<dyn Fn() -> Result<Box<dyn GenEngine>> + Send + Sync>;

/// `Retry-After` hint attached to shed responses.
pub const SHED_RETRY_AFTER_MS: u64 = 250;

struct WorkerShared {
    batcher: Mutex<Batcher>,
    cv: Condvar,
    stop: AtomicBool,
    queued: AtomicUsize,
    /// Requests popped from the queue but not yet answered.
    inflight: AtomicUsize,
    /// Set when the worker's engine factory failed: the worker requeues its
    /// queue to survivors, and the router stops selecting it.
    dead: AtomicBool,
    /// Graceful-shutdown mode: new and queued requests are shed, in-flight
    /// groups run to completion (or their deadlines).
    draining: AtomicBool,
}

pub struct Worker {
    shared: Arc<WorkerShared>,
    handle: Option<JoinHandle<()>>,
}

/// Construction-time knobs beyond the worker count (all defaulted).
#[derive(Clone, Copy)]
pub struct SchedulerOpts {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Per-worker queue bound: submissions past it are shed.
    pub queue_capacity: usize,
    /// Deterministic fault injection (chaos tests / `SPECMER_FAULT_*`).
    pub fault: Option<FaultPlan>,
    /// Per-worker shared-prefix KV cache budget in MiB, split between the
    /// draft and target stores (0 disables prefix reuse).
    pub prefix_cache_mb: usize,
    /// Context tokens prefilled per model per lockstep round boundary for
    /// a cold admission (0 = one-shot prefill at admission).
    pub prefill_chunk: usize,
}

impl Default for SchedulerOpts {
    fn default() -> SchedulerOpts {
        SchedulerOpts {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_capacity: DEFAULT_QUEUE_CAPACITY,
            fault: None,
            prefix_cache_mb: 32,
            prefill_chunk: 0,
        }
    }
}

pub struct Scheduler {
    workers: Vec<Worker>,
    queue_capacity: usize,
    /// Which workers hold which family-context keys warm — published by
    /// the workers' target prefix stores, read by the router's soft
    /// family-affinity placement.
    residency: Arc<Residency>,
    pub metrics: Arc<Metrics>,
}

impl Scheduler {
    pub fn start(
        n_workers: usize,
        max_batch: usize,
        max_wait: Duration,
        factory: EngineFactory,
        metrics: Arc<Metrics>,
    ) -> Scheduler {
        let opts = SchedulerOpts {
            max_batch,
            max_wait,
            fault: FaultPlan::from_env(),
            ..Default::default()
        };
        Scheduler::start_with(n_workers, opts, factory, metrics)
    }

    pub fn start_with(
        n_workers: usize,
        opts: SchedulerOpts,
        factory: EngineFactory,
        metrics: Arc<Metrics>,
    ) -> Scheduler {
        let queue_capacity = opts.queue_capacity.max(1);
        // every worker sees the whole fleet: a dying worker requeues its
        // queued requests to survivors
        let shareds: Arc<Vec<Arc<WorkerShared>>> = Arc::new(
            (0..n_workers.max(1))
                .map(|_| {
                    Arc::new(WorkerShared {
                        batcher: Mutex::new(Batcher::bounded(
                            opts.max_batch,
                            opts.max_wait,
                            queue_capacity,
                        )),
                        cv: Condvar::new(),
                        stop: AtomicBool::new(false),
                        queued: AtomicUsize::new(0),
                        inflight: AtomicUsize::new(0),
                        dead: AtomicBool::new(false),
                        draining: AtomicBool::new(false),
                    })
                })
                .collect(),
        );
        let residency = Arc::new(Residency::new());
        let workers = shareds
            .iter()
            .enumerate()
            .map(|(wid, shared)| {
                let all = Arc::clone(&shareds);
                let f = Arc::clone(&factory);
                let m = Arc::clone(&metrics);
                let fault = opts.fault.map(|p| p.state_for(wid));
                let prefix = PrefixCacheOpts {
                    cap_bytes: opts.prefix_cache_mb.saturating_mul(1 << 20),
                    prefill_chunk: opts.prefill_chunk,
                    residency: Some(Arc::clone(&residency)),
                    worker: wid,
                };
                let handle = std::thread::Builder::new()
                    .name(format!("specmer-worker-{wid}"))
                    .spawn(move || worker_loop(wid, all, f, m, fault, prefix))
                    // PANIC-OK: worker-thread spawn happens once at scheduler
                    // construction, before any request is accepted; an OS
                    // refusing to create threads is a fatal startup error.
                    .expect("spawn worker");
                Worker { shared: Arc::clone(shared), handle: Some(handle) }
            })
            .collect();
        Scheduler { workers, queue_capacity, residency, metrics }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// The fleet's prefix-residency map: which workers hold which family
    /// context keys warm. The router reads it for soft family affinity.
    pub fn residency(&self) -> &Arc<Residency> {
        &self.residency
    }

    /// Outstanding work per worker — queued *plus* in-flight, so the
    /// router's least-loaded policy sees requests for the whole time they
    /// occupy the worker, not only while they sit in its queue.
    pub fn loads(&self) -> Vec<usize> {
        self.workers
            .iter()
            .map(|w| {
                w.shared.queued.load(Ordering::Relaxed)
                    + w.shared.inflight.load(Ordering::Relaxed)
            })
            .collect()
    }

    /// Queue-only depth per worker (requests not yet popped).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.workers
            .iter()
            .map(|w| w.shared.queued.load(Ordering::Relaxed))
            .collect()
    }

    /// In-flight (popped, unanswered) requests per worker.
    pub fn inflight(&self) -> Vec<usize> {
        self.workers
            .iter()
            .map(|w| w.shared.inflight.load(Ordering::Relaxed))
            .collect()
    }

    /// Liveness per worker: false once a worker's engine factory failed
    /// (it answers every request with an error; the router skips it).
    pub fn alive(&self) -> Vec<bool> {
        self.workers
            .iter()
            .map(|w| !w.shared.dead.load(Ordering::SeqCst))
            .collect()
    }

    /// The per-worker queue bound submissions are shed past.
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    /// Submit a request to a specific worker. Bounded admission: when the
    /// worker's queue is at capacity (or the scheduler is draining) the
    /// request is **shed** — answered immediately with
    /// [`GenError::Overloaded`] — and `false` is returned.
    pub fn submit_to(&self, worker: usize, req: GenRequest) -> bool {
        let w = &self.workers[worker % self.workers.len()];
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        if w.shared.draining.load(Ordering::SeqCst) {
            self.shed(req);
            return false;
        }
        let pushed = {
            let mut b = w.shared.batcher.lock().unwrap();
            // count before the lock drops: the worker's pop-side decrement
            // can't run while we hold the batcher, so the gauge never
            // underflows
            match b.try_push(req) {
                Ok(()) => {
                    w.shared.queued.fetch_add(1, Ordering::Relaxed);
                    self.metrics.queue_depth_add(1);
                    Ok(())
                }
                Err(req) => Err(req),
            }
        };
        match pushed {
            Ok(()) => {
                w.shared.cv.notify_one();
                true
            }
            Err(req) => {
                self.shed(req);
                false
            }
        }
    }

    /// Answer `req` with a typed overload refusal (counts toward
    /// `shed_total`). Used by bounded admission here and by the router's
    /// concurrency limit.
    pub fn shed(&self, req: GenRequest) {
        self.metrics.record_shed();
        answer(req, GenError::Overloaded { retry_after_ms: SHED_RETRY_AFTER_MS }.into());
    }

    /// Switch to graceful shutdown: every worker sheds its *queued*
    /// requests (typed Overloaded replies) and refuses new ones, while
    /// in-flight groups run to completion or their deadlines. Idempotent.
    pub fn begin_drain(&self) {
        for w in &self.workers {
            w.shared.draining.store(true, Ordering::SeqCst);
            w.shared.cv.notify_all();
        }
    }

    /// Whether the scheduler is draining (graceful shutdown in progress).
    pub fn draining(&self) -> bool {
        self.workers.first().is_some_and(|w| w.shared.draining.load(Ordering::SeqCst))
    }

    /// Block until no queued or in-flight work remains, up to `timeout`.
    /// Returns whether the fleet went idle.
    pub fn await_idle(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        loop {
            if self.loads().iter().sum::<usize>() == 0 {
                return true;
            }
            if t0.elapsed() >= timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// Answer one request with an error reply (shed, deadline, dead worker).
fn answer(req: GenRequest, err: anyhow::Error) {
    let latency = req.submitted.elapsed().as_secs_f64();
    let _ = req.reply.send(GenResponse {
        id: req.id,
        protein: req.spec.protein,
        method: req.spec.method,
        result: Err(err),
        latency,
        decode_seconds: 0.0,
    });
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        for w in &self.workers {
            w.shared.stop.store(true, Ordering::SeqCst);
            w.shared.cv.notify_all();
        }
        for w in self.workers.iter_mut() {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(
    wid: usize,
    shareds: Arc<Vec<Arc<WorkerShared>>>,
    factory: EngineFactory,
    metrics: Arc<Metrics>,
    mut fault: Option<FaultState>,
    prefix: PrefixCacheOpts,
) {
    let shared = Arc::clone(&shareds[wid]);
    let injected_fail = fault.as_mut().map_or(false, |f| f.engine_build_fails());
    let built = if injected_fail { Err(anyhow!("injected engine-build fault")) } else { factory() };
    let mut engine = match built {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[specmer] worker {wid} failed to build engine: {e:#}");
            metrics.record_engine_failure();
            shared.dead.store(true, Ordering::SeqCst);
            drain_dead(wid, &shareds, &metrics, &format!("{e:#}"));
            return;
        }
    };
    // worker-resident prefix cache: enabled after the engine is built (the
    // stores live on this thread with it); no-op for engines without one
    engine.enable_prefix_cache(prefix);
    let engine = engine;
    // batcher limits are construction-time constants; read them once
    let max_batch = shared.batcher.lock().unwrap().max_batch;
    loop {
        // wait for work or shutdown
        let batch = {
            let mut b = shared.batcher.lock().unwrap();
            loop {
                if shared.draining.load(Ordering::SeqCst) && !b.is_empty() {
                    // graceful shutdown: queued (never-started) requests are
                    // shed, not decoded — only in-flight groups finish
                    while let Some(batch) = b.next_batch(Instant::now(), true) {
                        shared.queued.fetch_sub(batch.len(), Ordering::Relaxed);
                        metrics.queue_depth_add(-(batch.len() as i64));
                        for req in batch {
                            metrics.record_shed();
                            answer(
                                req,
                                GenError::Overloaded { retry_after_ms: SHED_RETRY_AFTER_MS }
                                    .into(),
                            );
                        }
                    }
                }
                if shared.stop.load(Ordering::SeqCst) && b.is_empty() {
                    return;
                }
                let flush = shared.stop.load(Ordering::SeqCst);
                if let Some(batch) = b.next_batch(Instant::now(), flush) {
                    break batch;
                }
                if b.is_empty() {
                    b = shared.cv.wait(b).unwrap();
                } else {
                    // oldest request hasn't aged out yet; sleep until its
                    // max_wait deadline (new work / shutdown still wake us)
                    let timeout = b.time_to_deadline(Instant::now());
                    let (nb, _t) = shared.cv.wait_timeout(b, timeout).unwrap();
                    b = nb;
                }
            }
        };
        shared.queued.fetch_sub(batch.len(), Ordering::Relaxed);
        metrics.queue_depth_add(-(batch.len() as i64));
        shared.inflight.fetch_add(batch.len(), Ordering::Relaxed);
        // deadline check at batch pop: a request that expired while queued
        // never reaches the engine
        let now = Instant::now();
        let (live, expired): (Vec<_>, Vec<_>) =
            batch.into_iter().partition(|r| !r.expired(now));
        for req in expired {
            metrics.record_deadline_exceeded();
            metrics.record_failure();
            answer(req, GenError::DeadlineExceeded.into());
            shared.inflight.fetch_sub(1, Ordering::Relaxed);
        }
        if live.is_empty() {
            continue;
        }
        dispatch(&shared, engine.as_ref(), &metrics, live, max_batch, &mut fault);
        // refresh this worker's prefix-cache gauges after every dispatch
        // (the stores are thread-local; metrics is the Send-side snapshot)
        if let Some(st) = engine.prefix_stats() {
            metrics.set_prefix(wid, st);
        }
    }
}

/// A worker whose engine never came up must still empty its queue: queued
/// (never-started) requests are **requeued to surviving workers** — the
/// client keeps its place in line instead of eating an error for a failure
/// that never touched its request — and error-answered only when no
/// survivor can take them. Runs until shutdown.
fn drain_dead(wid: usize, shareds: &[Arc<WorkerShared>], metrics: &Metrics, err: &str) {
    let shared = &shareds[wid];
    let mut b = shared.batcher.lock().unwrap();
    loop {
        while let Some(batch) = b.next_batch(Instant::now(), true) {
            shared.queued.fetch_sub(batch.len(), Ordering::Relaxed);
            metrics.queue_depth_add(-(batch.len() as i64));
            for req in batch {
                if req.expired(Instant::now()) {
                    metrics.record_deadline_exceeded();
                    metrics.record_failure();
                    answer(req, GenError::DeadlineExceeded.into());
                } else if shared.draining.load(Ordering::SeqCst) {
                    metrics.record_shed();
                    answer(
                        req,
                        GenError::Overloaded { retry_after_ms: SHED_RETRY_AFTER_MS }.into(),
                    );
                } else if let Err(req) = requeue(wid, shareds, metrics, req) {
                    metrics.record_failure();
                    answer(req, anyhow!("worker engine unavailable: {err}"));
                }
            }
        }
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        b = shared.cv.wait(b).unwrap();
    }
}

/// Move one queued request from dead worker `wid` to the least-loaded
/// surviving worker with queue headroom; hands it back if none exists.
fn requeue(
    wid: usize,
    shareds: &[Arc<WorkerShared>],
    metrics: &Metrics,
    req: GenRequest,
) -> Result<(), GenRequest> {
    let target = shareds
        .iter()
        .enumerate()
        .filter(|(i, s)| {
            *i != wid && !s.dead.load(Ordering::SeqCst) && !s.draining.load(Ordering::SeqCst)
        })
        .min_by_key(|(_, s)| s.queued.load(Ordering::Relaxed) + s.inflight.load(Ordering::Relaxed));
    let Some((_, target)) = target else {
        return Err(req);
    };
    let pushed = {
        let mut b = target.batcher.lock().unwrap();
        match b.try_push(req) {
            Ok(()) => {
                target.queued.fetch_add(1, Ordering::Relaxed);
                metrics.queue_depth_add(1);
                Ok(())
            }
            Err(req) => Err(req),
        }
    };
    match pushed {
        Ok(()) => {
            metrics.record_requeue();
            target.cv.notify_one();
            Ok(())
        }
        Err(req) => Err(req),
    }
}

/// Dispatch one popped batch. The batcher keys batches by lockstep shape,
/// so a popped batch is shape-homogeneous: if the engine can serve that
/// shape it runs whole on the continuous path — one in-flight group
/// admitting newly-queued shape-compatible requests (any protein, any
/// speculative method) at every round boundary; otherwise (baselines,
/// probe items, engines without a lockstep decode) it takes the plain
/// batched dispatch.
fn dispatch(
    shared: &WorkerShared,
    engine: &dyn GenEngine,
    metrics: &Metrics,
    batch: Vec<GenRequest>,
    max_batch: usize,
    fault: &mut Option<FaultState>,
) {
    let now = Instant::now();
    let queue_wait: f64 = batch
        .iter()
        .map(|r| now.saturating_duration_since(r.submitted).as_secs_f64())
        .sum();
    metrics.record_batch(batch.len(), queue_wait);

    if let Some(shape) = engine.lockstep_shape(&batch[0].spec) {
        let mut source = WorkerSource {
            shared,
            metrics,
            shape,
            max_batch,
            initial: batch,
            inflight: HashMap::new(),
            next_ticket: 0,
            last_boundary: Instant::now(),
            round_active: 0,
            anchor: None,
            distinct_proteins: Vec::new(),
            fault: fault.as_mut(),
        };
        engine.generate_continuous(&shape, &mut source);
        // defensive: an engine that abandons the group must not hang clients
        source.fail_remaining("continuous dispatch ended without answering");
        metrics.record_group_mix(source.distinct_proteins.len());
        return;
    }

    // plain batched dispatch; decode wall time is attributed evenly so
    // per-request decode_seconds still sum to the wall time
    let specs: Vec<SeqSpec> = batch.iter().map(|r| r.spec.clone()).collect();
    let t0 = Instant::now();
    let mut results = engine.generate_batch(&specs);
    // a length-mismatched result vector must never silently drop replies
    // (a client would hang forever): fail the remainder explicitly
    let got = results.len();
    if got != batch.len() {
        results.truncate(batch.len());
        while results.len() < batch.len() {
            results.push(Err(anyhow!(
                "engine answered {got} of {} batched requests",
                batch.len()
            )));
        }
    }
    let per_req_decode = t0.elapsed().as_secs_f64() / batch.len() as f64;
    for (req, result) in batch.into_iter().zip(results) {
        let latency = req.submitted.elapsed().as_secs_f64();
        match &result {
            Ok(out) => metrics.record(out, latency, per_req_decode),
            Err(_) => metrics.record_failure(),
        }
        let _ = req.reply.send(GenResponse {
            id: req.id,
            protein: req.spec.protein,
            method: req.spec.method,
            result,
            latency,
            decode_seconds: per_req_decode,
        });
        shared.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// The worker's [`RequestSource`]: feeds the continuous-batching dispatch
/// from the initial popped batch, then re-polls the batcher (under the
/// worker mutex) at every round boundary for newly-arrived shape-compatible
/// requests — preferring the group's majority protein, never starving
/// others — and answers each request the moment its sequence finishes.
/// Also does the round bookkeeping: time-weighted occupancy, cross-key
/// admission accounting, and a per-request decode-seconds share (each
/// round's wall time split evenly over the sequences that rode it).
struct WorkerSource<'a> {
    shared: &'a WorkerShared,
    metrics: &'a Metrics,
    shape: crate::decode::LockstepShape,
    max_batch: usize,
    /// Popped batch members, admitted at the first boundary.
    initial: Vec<GenRequest>,
    /// Unanswered requests by ticket, with their decode-seconds share.
    inflight: HashMap<u64, (GenRequest, f64)>,
    next_ticket: u64,
    last_boundary: Instant,
    /// Sequences that rode the round now ending (set at each admit).
    round_active: usize,
    /// `(protein, method)` of the group's first member: admissions under a
    /// different key count toward `cross_key_admitted_total`.
    anchor: Option<(Arc<str>, Method)>,
    /// Every distinct protein that rode this group (gauge numerator).
    distinct_proteins: Vec<Arc<str>>,
    /// Injected faults, consulted at round boundaries (chaos tests).
    fault: Option<&'a mut FaultState>,
}

impl WorkerSource<'_> {
    /// Attribute the wall time since the previous boundary to the
    /// sequences that were in flight for it.
    fn charge_round(&mut self) {
        let dt = self.last_boundary.elapsed().as_secs_f64();
        self.last_boundary = Instant::now();
        if dt <= 0.0 || self.round_active == 0 {
            return;
        }
        self.metrics.record_round(self.round_active, dt);
        let share = dt / self.round_active as f64;
        for slot in self.inflight.values_mut() {
            slot.1 += share;
        }
    }

    /// The group's majority protein among unanswered members — the soft
    /// admission preference (k-mer table + prefill-cache locality).
    fn majority_protein(&self) -> Option<Arc<str>> {
        let mut counts: HashMap<&str, (usize, &Arc<str>)> = HashMap::new();
        for (req, _) in self.inflight.values() {
            let e = counts.entry(&req.spec.protein).or_insert((0, &req.spec.protein));
            e.0 += 1;
        }
        counts.into_values().max_by_key(|(n, _)| *n).map(|(_, p)| Arc::clone(p))
    }

    /// Group-membership accounting for one request joining the group.
    fn note_member(&mut self, req: &GenRequest) {
        match &self.anchor {
            None => self.anchor = Some((Arc::clone(&req.spec.protein), req.spec.method)),
            Some((p, m)) => {
                if **p != *req.spec.protein || *m != req.spec.method {
                    self.metrics.record_cross_key_admission();
                }
            }
        }
        if !self.distinct_proteins.iter().any(|p| **p == *req.spec.protein) {
            // lint:allow(unbounded): bounded by the distinct proteins in one
            // lockstep group, which holds at most max_batch members
            self.distinct_proteins.push(Arc::clone(&req.spec.protein));
        }
    }

    /// Fail everything the engine never answered — admitted tickets still
    /// in flight *and* initial members it never even admitted (defensive; a
    /// correct engine admits the whole batch and completes every ticket).
    fn fail_remaining(&mut self, why: &str) {
        for req in std::mem::take(&mut self.initial) {
            self.metrics.record_failure();
            let latency = req.submitted.elapsed().as_secs_f64();
            let _ = req.reply.send(GenResponse {
                id: req.id,
                protein: req.spec.protein,
                method: req.spec.method,
                result: Err(anyhow!("{why}")),
                latency,
                decode_seconds: 0.0,
            });
            self.shared.inflight.fetch_sub(1, Ordering::Relaxed);
        }
        let tickets: Vec<u64> = self.inflight.keys().copied().collect();
        for t in tickets {
            self.complete(t, Err(anyhow!("{why}")));
        }
    }
}

impl RequestSource for WorkerSource<'_> {
    fn admit(&mut self, active: usize) -> Vec<(u64, SeqSpec)> {
        self.charge_round();
        // initial members first, then splice in whatever shape-compatible
        // work arrived while the group was decoding
        let mut reqs = std::mem::take(&mut self.initial);
        // draining: the resident group finishes, but nothing new joins it
        let free = if self.shared.draining.load(Ordering::SeqCst) {
            0
        } else {
            self.max_batch.saturating_sub(active + reqs.len())
        };
        if free > 0 {
            let prefer = self.majority_protein();
            let taken = {
                let mut b = self.shared.batcher.lock().unwrap();
                b.take_compatible(Instant::now(), self.shape, free, prefer.as_deref())
            };
            if !taken.is_empty() {
                self.shared.queued.fetch_sub(taken.len(), Ordering::Relaxed);
                self.metrics.queue_depth_add(-(taken.len() as i64));
                self.shared.inflight.fetch_add(taken.len(), Ordering::Relaxed);
                let now = Instant::now();
                for r in &taken {
                    self.metrics.record_admission(
                        now.saturating_duration_since(r.submitted).as_secs_f64(),
                    );
                }
                reqs.extend(taken);
            }
        }
        let out: Vec<(u64, SeqSpec)> = reqs
            .into_iter()
            .map(|r| {
                self.note_member(&r);
                let ticket = self.next_ticket;
                self.next_ticket += 1;
                let spec = r.spec.clone();
                self.inflight.insert(ticket, (r, 0.0));
                (ticket, spec)
            })
            .collect();
        self.round_active = self.inflight.len();
        out
    }

    fn complete(&mut self, ticket: u64, result: Result<GenOutput>) {
        self.charge_round();
        let Some((req, decode_s)) = self.inflight.remove(&ticket) else {
            return;
        };
        // retired sequences don't ride the next round: keeps the occupancy
        // gauge honest when an admission completes before any round runs
        self.round_active = self.round_active.saturating_sub(1);
        let latency = req.submitted.elapsed().as_secs_f64();
        match &result {
            Ok(out) => self.metrics.record(out, latency, decode_s),
            Err(_) => self.metrics.record_failure(),
        }
        let _ = req.reply.send(GenResponse {
            id: req.id,
            protein: req.spec.protein,
            method: req.spec.method,
            result,
            latency,
            decode_seconds: decode_s,
        });
        self.shared.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    fn cancel(&mut self, resident: &[u64]) -> Vec<(u64, anyhow::Error)> {
        // injected faults first: a round error models a failed verify
        // dispatch poisoning the whole group
        if let Some(fault) = self.fault.as_deref_mut() {
            if let Some(delay) = fault.round_delay() {
                std::thread::sleep(delay);
            }
            if fault.round_error_fires() {
                return resident
                    .iter()
                    .map(|&t| (t, anyhow!("injected fault: verify round error")))
                    .collect();
            }
        }
        // deadline enforcement at the round boundary: wall-clock policy
        // stays here in the coordinator; the lockstep driver just retires
        // the tickets we hand back (batchmates' streams are untouched)
        let now = Instant::now();
        let mut out = Vec::new();
        for &t in resident {
            if let Some((req, _)) = self.inflight.get(&t) {
                if req.expired(now) {
                    self.metrics.record_deadline_exceeded();
                    out.push((t, GenError::DeadlineExceeded.into()));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::coordinator::engine::{synthetic_engine, synthetic_families, FamilyRegistry};
    use crate::decode::GenConfig;
    use std::sync::mpsc::channel;

    fn registry() -> FamilyRegistry {
        FamilyRegistry::new(synthetic_families(3))
    }

    fn request(
        reg: &FamilyRegistry,
        id: u64,
        protein: &str,
        method: Method,
        cfg: GenConfig,
        reply: std::sync::mpsc::Sender<GenResponse>,
    ) -> GenRequest {
        GenRequest {
            id,
            spec: reg.spec(protein, method, &cfg).unwrap(),
            reply,
            submitted: Instant::now(),
            deadline: None,
        }
    }

    fn sched(workers: usize) -> Scheduler {
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        Scheduler::start(
            workers,
            4,
            Duration::from_millis(1),
            factory,
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn processes_requests_and_replies() {
        let reg = registry();
        let s = sched(1);
        let (tx, rx) = channel();
        for id in 0..4u64 {
            s.submit_to(
                0,
                request(
                    &reg,
                    id,
                    "SynA",
                    Method::SpecMer,
                    GenConfig { max_len: 20, seed: id, ..Default::default() },
                    tx.clone(),
                ),
            );
        }
        let mut got: Vec<u64> = (0..4).map(|_| rx.recv_timeout(Duration::from_secs(30)).unwrap())
            .map(|r| {
                assert!(r.result.is_ok());
                r.id
            })
            .collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(s.metrics.completed.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn multiple_workers_share_load() {
        let reg = registry();
        let s = sched(2);
        let (tx, rx) = channel();
        for id in 0..6u64 {
            s.submit_to(
                (id % 2) as usize,
                request(
                    &reg,
                    id,
                    "SynA",
                    Method::Speculative,
                    GenConfig { max_len: 16, seed: id, ..Default::default() },
                    tx.clone(),
                ),
            );
        }
        for _ in 0..6 {
            assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().result.is_ok());
        }
    }

    #[test]
    fn batch_dispatch_records_occupancy() {
        let reg = registry();
        let s = sched(1);
        let (tx, rx) = channel();
        for id in 0..4u64 {
            s.submit_to(
                0,
                request(
                    &reg,
                    id,
                    "SynA",
                    Method::SpecMer,
                    GenConfig { max_len: 20, seed: id, ..Default::default() },
                    tx.clone(),
                ),
            );
        }
        for _ in 0..4 {
            assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().result.is_ok());
        }
        // every request rode a recorded dispatch, whatever the batch split
        assert!(s.metrics.batches.load(Ordering::Relaxed) >= 1);
        assert_eq!(s.metrics.batched_requests.load(Ordering::Relaxed), 4);
        assert!(s.metrics.batch_occupancy() >= 1.0);
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let s = sched(2);
        drop(s); // must not hang
    }

    #[test]
    fn failed_engine_factory_answers_every_request() {
        // reply senders must be dropped (with an error sent) — clients used
        // to hang forever when the factory failed
        let reg = registry();
        let factory: EngineFactory = Arc::new(|| Err(anyhow!("no artifacts")));
        let metrics = Arc::new(Metrics::new());
        let s = Scheduler::start(1, 4, Duration::from_millis(1), factory, Arc::clone(&metrics));
        let (tx, rx) = channel();
        for id in 0..3u64 {
            s.submit_to(
                0,
                request(&reg, id, "SynA", Method::SpecMer, GenConfig::default(), tx.clone()),
            );
        }
        for _ in 0..3 {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(r.result.is_err(), "dead worker must answer with an error");
        }
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.engine_failures.load(Ordering::Relaxed), 1);
        assert_eq!(s.alive(), vec![false]);
    }

    #[test]
    fn short_result_vector_fails_remainder_explicitly() {
        use crate::coordinator::engine::Family;
        use crate::coordinator::request::SeqSpec;
        use crate::decode::GenOutput;
        use crate::kmer::KmerTable;

        // buggy engine: answers only the first request of any batch
        struct ShortEngine;
        impl GenEngine for ShortEngine {
            fn generate(&self, _spec: &SeqSpec) -> Result<GenOutput> {
                Ok(GenOutput { tokens: vec![1, 5, 9], context_len: 1, ..Default::default() })
            }
            fn generate_batch(&self, specs: &[SeqSpec]) -> Vec<Result<GenOutput>> {
                vec![self.generate(&specs[0])]
            }
            fn score_nll(&self, _tokens: &[u8]) -> Result<f64> {
                Ok(0.0)
            }
            fn embed(&self, _tokens: &[u8]) -> Result<Vec<f32>> {
                Ok(Vec::new())
            }
            fn families(&self) -> &[Arc<Family>] {
                &[]
            }
            fn set_table_override(&mut self, _protein: &str, _table: Option<Arc<KmerTable>>) {}
        }

        let reg = registry();
        let factory: EngineFactory = Arc::new(|| Ok(Box::new(ShortEngine) as Box<dyn GenEngine>));
        let metrics = Arc::new(Metrics::new());
        let s = Scheduler::start(1, 4, Duration::from_millis(50), factory, Arc::clone(&metrics));
        let (tx, rx) = channel();
        for id in 0..3u64 {
            s.submit_to(
                0,
                request(&reg, id, "SynA", Method::TargetOnly, GenConfig::default(), tx.clone()),
            );
        }
        let (mut ok, mut err) = (0, 0);
        for _ in 0..3 {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            match r.result {
                Ok(_) => ok += 1,
                Err(e) => {
                    err += 1;
                    assert!(format!("{e:#}").contains("answered"), "{e:#}");
                }
            }
        }
        // every request was answered: the ones the engine dropped got an
        // explicit error instead of a hung client
        assert_eq!(ok + err, 3);
        assert!(err >= 1, "short result vector must fail the remainder");
        assert_eq!(
            metrics.completed.load(Ordering::Relaxed) + metrics.failed.load(Ordering::Relaxed),
            3
        );
    }

    #[test]
    fn loads_split_queued_and_inflight() {
        let reg = registry();
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        let s = Scheduler::start(
            1,
            8,
            Duration::from_secs(3600),
            factory,
            Arc::new(Metrics::new()),
        );
        let (tx, rx) = channel();
        for id in 0..2u64 {
            s.submit_to(
                0,
                request(
                    &reg,
                    id,
                    "SynA",
                    Method::SpecMer,
                    GenConfig { max_len: 16, seed: id, ..Default::default() },
                    tx.clone(),
                ),
            );
        }
        // the batch can't fire (not full, not aged): the work must be
        // visible as queued, not in flight, and loads() as their sum
        assert_eq!(s.queue_depths(), vec![2]);
        assert_eq!(s.inflight(), vec![0]);
        assert_eq!(s.loads(), vec![2]);
        drop(tx);
        drop(s); // shutdown flush answers both
        assert_eq!(rx.iter().count(), 2);
    }

    #[test]
    fn full_queue_sheds_with_typed_overload() {
        use crate::coordinator::error::GenError;
        // a worker that can never pop (huge max_wait, tiny queue): the
        // third submission must be shed, typed, instead of growing the queue
        let reg = registry();
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        let metrics = Arc::new(Metrics::new());
        let opts = SchedulerOpts {
            max_batch: 8,
            max_wait: Duration::from_secs(3600),
            queue_capacity: 2,
            ..Default::default()
        };
        let s = Scheduler::start_with(1, opts, factory, Arc::clone(&metrics));
        let (tx, rx) = channel();
        let cfg = GenConfig { max_len: 16, ..Default::default() };
        let mut accepted = 0;
        for id in 0..3u64 {
            if s.submit_to(0, request(&reg, id, "SynA", Method::SpecMer, cfg.clone(), tx.clone()))
            {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 2);
        assert_eq!(s.queue_depths(), vec![2]);
        // the shed reply arrives immediately, while the worker still sleeps
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = r.result.unwrap_err();
        assert!(
            matches!(GenError::of(&err), Some(GenError::Overloaded { .. })),
            "expected typed Overloaded, got {err:#}"
        );
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.requests.load(Ordering::Relaxed), 3);
        drop(tx);
        drop(s);
        // the two queued requests are still answered at shutdown
        assert_eq!(rx.iter().count(), 2);
    }

    #[test]
    fn expired_deadline_is_refused_at_pop() {
        use crate::coordinator::error::GenError;
        let reg = registry();
        let s = sched(1);
        let (tx, rx) = channel();
        let mut req = request(
            &reg,
            7,
            "SynA",
            Method::SpecMer,
            GenConfig { max_len: 20, ..Default::default() },
            tx,
        );
        req.deadline = Some(Instant::now() - Duration::from_millis(1));
        assert!(s.submit_to(0, req), "an expired request still enqueues; the pop refuses it");
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let err = r.result.unwrap_err();
        assert_eq!(GenError::of(&err), Some(GenError::DeadlineExceeded), "{err:#}");
        assert_eq!(s.metrics.deadline_exceeded.load(Ordering::Relaxed), 1);
        assert_eq!(s.metrics.completed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn graceful_drain_sheds_queued_and_answers_everything() {
        use crate::coordinator::error::GenError;
        // huge max_wait: submissions stay queued until drain sheds them
        let reg = registry();
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        let opts = SchedulerOpts {
            max_batch: 8,
            max_wait: Duration::from_secs(3600),
            queue_capacity: 8,
            ..Default::default()
        };
        let s = Scheduler::start_with(1, opts, factory, Arc::new(Metrics::new()));
        let (tx, rx) = channel();
        let cfg = GenConfig { max_len: 16, ..Default::default() };
        for id in 0..3u64 {
            assert!(s.submit_to(
                0,
                request(&reg, id, "SynA", Method::SpecMer, cfg.clone(), tx.clone())
            ));
        }
        s.begin_drain();
        assert!(s.await_idle(Duration::from_secs(30)), "drain must reach idle");
        // new submissions are refused while draining
        assert!(!s.submit_to(0, request(&reg, 9, "SynA", Method::SpecMer, cfg, tx.clone())));
        drop(tx);
        let replies: Vec<GenResponse> = rx.iter().collect();
        assert_eq!(replies.len(), 4, "every request must be answered");
        for r in &replies {
            let err = r.result.as_ref().unwrap_err();
            assert!(
                matches!(GenError::of(err), Some(GenError::Overloaded { .. })),
                "drain must shed with typed Overloaded, got {err:#}"
            );
        }
        assert_eq!(s.metrics.shed.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn dead_worker_requeues_queued_requests_to_survivor() {
        use std::sync::atomic::AtomicUsize;
        // one worker's engine build fails (first factory call — thread
        // scheduling decides which worker that is), the other's succeeds:
        // requests submitted to the dead worker must be requeued and then
        // *served* by the survivor instead of error-drained
        let builds = Arc::new(AtomicUsize::new(0));
        let b2 = Arc::clone(&builds);
        let factory: EngineFactory = Arc::new(move || {
            if b2.fetch_add(1, Ordering::SeqCst) == 0 {
                Err(anyhow!("no artifacts"))
            } else {
                Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>)
            }
        });
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let s = Scheduler::start(2, 4, Duration::from_millis(1), factory, Arc::clone(&metrics));
        // wait until exactly one worker is marked dead
        let t0 = Instant::now();
        let dead = loop {
            let alive = s.alive();
            if let Some(i) = alive.iter().position(|a| !a) {
                break i;
            }
            assert!(t0.elapsed() < Duration::from_secs(30), "no worker died");
            std::thread::sleep(Duration::from_millis(1));
        };
        let (tx, rx) = channel();
        for id in 0..3u64 {
            assert!(s.submit_to(
                dead,
                request(
                    &reg,
                    id,
                    "SynA",
                    Method::SpecMer,
                    GenConfig { max_len: 16, seed: id, ..Default::default() },
                    tx.clone(),
                )
            ));
        }
        for _ in 0..3 {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(r.result.is_ok(), "requeued request must be served by the survivor");
        }
        assert_eq!(metrics.requeued.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn injected_round_faults_fail_group_then_recover() {
        // seeded chaos: every round boundary fires an injected error, so
        // lockstep requests fail with the injected message — but the worker
        // stays alive and keeps answering (no hangs, no dead worker)
        let reg = registry();
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        let metrics = Arc::new(Metrics::new());
        let opts = SchedulerOpts {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 8,
            fault: Some(FaultPlan {
                seed: 11,
                engine_build_fail: 0.0,
                round_error: 1.0,
                round_delay_ms: 0,
            }),
            ..Default::default()
        };
        let s = Scheduler::start_with(1, opts, factory, Arc::clone(&metrics));
        let (tx, rx) = channel();
        for id in 0..3u64 {
            assert!(s.submit_to(
                0,
                request(
                    &reg,
                    id,
                    "SynA",
                    Method::SpecMer,
                    GenConfig { max_len: 24, seed: id, ..Default::default() },
                    tx.clone(),
                )
            ));
        }
        for _ in 0..3 {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            let err = r.result.unwrap_err();
            assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        }
        assert_eq!(s.alive(), vec![true], "round faults must not kill the worker");
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn staggered_arrivals_bitwise_match_solo_runs() {
        // requests submitted while the worker is mid-decode get admitted
        // into the in-flight lockstep group at a round boundary; admission
        // must not perturb any request's token stream
        let reg = registry();
        let s = sched(1);
        let (tx, rx) = channel();
        let mut cfgs: HashMap<u64, GenConfig> = HashMap::new();
        for wave in 0..3u64 {
            for i in 0..2u64 {
                let id = wave * 2 + i;
                let cfg = GenConfig {
                    max_len: 36,
                    seed: id * 13 + 1,
                    c: 3,
                    gamma: 5,
                    ..Default::default()
                };
                cfgs.insert(id, cfg.clone());
                s.submit_to(0, request(&reg, id, "SynA", Method::SpecMer, cfg, tx.clone()));
            }
            std::thread::sleep(Duration::from_millis(3));
        }
        let eng = synthetic_engine(3);
        for _ in 0..6 {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            let got = r.result.expect("request failed");
            let want = eng.generate_for(&r.protein, r.method, &cfgs[&r.id]).unwrap();
            assert_eq!(got.tokens, want.tokens, "request {} diverged under admission", r.id);
        }
    }

    #[test]
    fn mixed_protein_and_method_staggered_arrivals_bitwise_match() {
        // the tentpole end-to-end: SynA and SynB requests — and mixed
        // SpecMER/vanilla methods at the same (c, gamma) — stream into one
        // worker, share in-flight lockstep groups via shape-keyed
        // admission, and every token stream still matches its solo decode
        let reg = registry();
        let s = sched(1);
        let (tx, rx) = channel();
        let mut want: HashMap<u64, (String, Method, GenConfig)> = HashMap::new();
        for wave in 0..3u64 {
            for i in 0..2u64 {
                let id = wave * 2 + i;
                let protein = if id % 2 == 0 { "SynA" } else { "SynB" };
                let method = if id % 3 == 0 { Method::Speculative } else { Method::SpecMer };
                // c = 1 everywhere so both methods normalize to one shape
                let cfg = GenConfig {
                    max_len: 36,
                    seed: id * 17 + 3,
                    c: 1,
                    gamma: 5,
                    ..Default::default()
                };
                want.insert(id, (protein.to_string(), method, cfg.clone()));
                s.submit_to(0, request(&reg, id, protein, method, cfg, tx.clone()));
            }
            std::thread::sleep(Duration::from_millis(3));
        }
        let eng = synthetic_engine(3);
        for _ in 0..6 {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            let got = r.result.expect("request failed");
            let (protein, method, cfg) = &want[&r.id];
            assert_eq!(&*r.protein, protein.as_str());
            let solo = eng.generate_for(protein, *method, cfg).unwrap();
            assert_eq!(
                got.tokens,
                solo.tokens,
                "request {} diverged under mixed-key admission",
                r.id
            );
        }
        // the whole point: requests crossed (protein, method) lines inside
        // shared groups (batch splits are timing-dependent, so >= checks)
        let cross = s.metrics.cross_key_admitted.load(Ordering::Relaxed);
        assert!(cross >= 1, "no cross-key batching happened (cross={cross})");
    }
}
