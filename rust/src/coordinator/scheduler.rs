//! Worker scheduler: N worker threads, each owning a [`GenEngine`]
//! (engines hold PJRT handles and are deliberately !Send — they are built
//! *inside* their worker thread from a Send factory), fed by per-worker
//! batchers behind a mutex+condvar.
//!
//! A worker dispatches each batcher batch *whole* through
//! [`GenEngine::generate_batch`], so compatible requests share lockstep
//! decode rounds instead of running B independent decode loops; batch
//! occupancy and queue-wait are recorded per dispatch. Workers with queued
//! but not-yet-aged work sleep on the condvar until the oldest request's
//! `max_wait` deadline instead of spinning.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::Batcher;
use super::engine::GenEngine;
use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse};

/// Send-able engine constructor run inside each worker thread.
pub type EngineFactory = Arc<dyn Fn() -> Result<Box<dyn GenEngine>> + Send + Sync>;

struct WorkerShared {
    batcher: Mutex<Batcher>,
    cv: Condvar,
    stop: AtomicBool,
    queued: AtomicUsize,
}

pub struct Worker {
    shared: Arc<WorkerShared>,
    handle: Option<JoinHandle<()>>,
}

pub struct Scheduler {
    workers: Vec<Worker>,
    pub metrics: Arc<Metrics>,
}

impl Scheduler {
    pub fn start(
        n_workers: usize,
        max_batch: usize,
        max_wait: Duration,
        factory: EngineFactory,
        metrics: Arc<Metrics>,
    ) -> Scheduler {
        let workers = (0..n_workers.max(1))
            .map(|wid| {
                let shared = Arc::new(WorkerShared {
                    batcher: Mutex::new(Batcher::new(max_batch, max_wait)),
                    cv: Condvar::new(),
                    stop: AtomicBool::new(false),
                    queued: AtomicUsize::new(0),
                });
                let s2 = Arc::clone(&shared);
                let f = Arc::clone(&factory);
                let m = Arc::clone(&metrics);
                let handle = std::thread::Builder::new()
                    .name(format!("specmer-worker-{wid}"))
                    .spawn(move || worker_loop(s2, f, m))
                    .expect("spawn worker");
                Worker { shared, handle: Some(handle) }
            })
            .collect();
        Scheduler { workers, metrics }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Queue depth of each worker (for the router's least-loaded policy).
    pub fn loads(&self) -> Vec<usize> {
        self.workers
            .iter()
            .map(|w| w.shared.queued.load(Ordering::Relaxed))
            .collect()
    }

    /// Submit a request to a specific worker.
    pub fn submit_to(&self, worker: usize, req: GenRequest) {
        let w = &self.workers[worker % self.workers.len()];
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        w.shared.queued.fetch_add(1, Ordering::Relaxed);
        w.shared.batcher.lock().unwrap().push(req);
        w.shared.cv.notify_one();
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        for w in &self.workers {
            w.shared.stop.store(true, Ordering::SeqCst);
            w.shared.cv.notify_all();
        }
        for w in self.workers.iter_mut() {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(shared: Arc<WorkerShared>, factory: EngineFactory, metrics: Arc<Metrics>) {
    let engine = match factory() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[specmer] worker failed to build engine: {e:#}");
            return;
        }
    };
    loop {
        // wait for work or shutdown
        let batch = {
            let mut b = shared.batcher.lock().unwrap();
            loop {
                if shared.stop.load(Ordering::SeqCst) && b.is_empty() {
                    return;
                }
                let flush = shared.stop.load(Ordering::SeqCst);
                if let Some(batch) = b.next_batch(Instant::now(), flush) {
                    break batch;
                }
                if b.is_empty() {
                    b = shared.cv.wait(b).unwrap();
                } else {
                    // oldest request hasn't aged out yet; sleep until its
                    // max_wait deadline (new work / shutdown still wake us)
                    let timeout = b.time_to_deadline(Instant::now());
                    let (nb, _t) = shared.cv.wait_timeout(b, timeout).unwrap();
                    b = nb;
                }
            }
        };
        shared.queued.fetch_sub(batch.len(), Ordering::Relaxed);

        // one lockstep dispatch for the whole batch (one (protein, method)
        // key by the batcher's grouping); decode wall time is attributed
        // evenly so per-request decode_seconds still sum to the wall time
        let now = Instant::now();
        let queue_wait: f64 = batch
            .iter()
            .map(|r| now.saturating_duration_since(r.submitted).as_secs_f64())
            .sum();
        metrics.record_batch(batch.len(), queue_wait);
        let cfgs: Vec<_> = batch.iter().map(|r| r.cfg.clone()).collect();
        let t0 = Instant::now();
        let results = engine.generate_batch(&batch[0].protein, batch[0].method, &cfgs);
        let per_req_decode = t0.elapsed().as_secs_f64() / batch.len() as f64;
        for (req, result) in batch.into_iter().zip(results) {
            let latency = req.submitted.elapsed().as_secs_f64();
            match &result {
                Ok(out) => metrics.record(out, latency, per_req_decode),
                Err(_) => metrics.record_failure(),
            }
            let _ = req.reply.send(GenResponse {
                id: req.id,
                protein: req.protein,
                method: req.method,
                result,
                latency,
                decode_seconds: per_req_decode,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::coordinator::engine::synthetic_engine;
    use crate::decode::GenConfig;
    use std::sync::mpsc::channel;

    fn sched(workers: usize) -> Scheduler {
        let factory: EngineFactory =
            Arc::new(|| Ok(Box::new(synthetic_engine(3)) as Box<dyn GenEngine>));
        Scheduler::start(
            workers,
            4,
            Duration::from_millis(1),
            factory,
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn processes_requests_and_replies() {
        let s = sched(1);
        let (tx, rx) = channel();
        for id in 0..4u64 {
            s.submit_to(
                0,
                GenRequest {
                    id,
                    protein: "SynA".into(),
                    method: Method::SpecMer,
                    cfg: GenConfig { max_len: 20, seed: id, ..Default::default() },
                    reply: tx.clone(),
                    submitted: Instant::now(),
                },
            );
        }
        let mut got: Vec<u64> = (0..4).map(|_| rx.recv_timeout(Duration::from_secs(30)).unwrap())
            .map(|r| {
                assert!(r.result.is_ok());
                r.id
            })
            .collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(s.metrics.completed.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn multiple_workers_share_load() {
        let s = sched(2);
        let (tx, rx) = channel();
        for id in 0..6u64 {
            s.submit_to(
                (id % 2) as usize,
                GenRequest {
                    id,
                    protein: "SynA".into(),
                    method: Method::Speculative,
                    cfg: GenConfig { max_len: 16, seed: id, ..Default::default() },
                    reply: tx.clone(),
                    submitted: Instant::now(),
                },
            );
        }
        for _ in 0..6 {
            assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().result.is_ok());
        }
    }

    #[test]
    fn batch_dispatch_records_occupancy() {
        let s = sched(1);
        let (tx, rx) = channel();
        for id in 0..4u64 {
            s.submit_to(
                0,
                GenRequest {
                    id,
                    protein: "SynA".into(),
                    method: Method::SpecMer,
                    cfg: GenConfig { max_len: 20, seed: id, ..Default::default() },
                    reply: tx.clone(),
                    submitted: Instant::now(),
                },
            );
        }
        for _ in 0..4 {
            assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().result.is_ok());
        }
        // every request rode a recorded dispatch, whatever the batch split
        assert!(s.metrics.batches.load(Ordering::Relaxed) >= 1);
        assert_eq!(s.metrics.batched_requests.load(Ordering::Relaxed), 4);
        assert!(s.metrics.batch_occupancy() >= 1.0);
    }

    #[test]
    fn unknown_protein_reports_error() {
        let s = sched(1);
        let (tx, rx) = channel();
        s.submit_to(
            0,
            GenRequest {
                id: 1,
                protein: "Nope".into(),
                method: Method::SpecMer,
                cfg: GenConfig::default(),
                reply: tx,
                submitted: Instant::now(),
            },
        );
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.result.is_err());
        assert_eq!(s.metrics.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let s = sched(2);
        drop(s); // must not hang
    }
}
