//! Deterministic fault injection for chaos-testing the serving stack.
//!
//! A seeded [`FaultPlan`] describes *which* faults to inject; each worker
//! derives a [`FaultState`] (plan + per-worker Pcg64 stream) so a given
//! `(seed, worker)` pair always fails at the same points. Faults are
//! injected at three places in the worker loop:
//!
//!   * **engine build** — the worker's engine factory is failed before it
//!     runs, exercising the dead-worker requeue path;
//!   * **round error** — at a lockstep round boundary every resident
//!     sequence is failed, modelling a verify-dispatch error poisoning
//!     the group;
//!   * **round delay** — extra latency added at each round boundary so
//!     deadline enforcement can be driven without slow models.
//!
//! Plans come from the environment (`SPECMER_FAULT_*`) for CLI chaos runs,
//! or are passed explicitly through `SchedulerOpts` in tests.

use crate::util::rng::Pcg64;
use std::time::Duration;

/// Seeded description of the faults to inject. All-zero = no faults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Base seed; each worker draws from `Pcg64::new(seed ^ worker_id)`.
    pub seed: u64,
    /// Probability that a worker's engine build is failed outright.
    pub engine_build_fail: f64,
    /// Per-round-boundary probability of failing the resident group.
    pub round_error: f64,
    /// Extra latency injected at every round boundary.
    pub round_delay_ms: u64,
}

impl FaultPlan {
    /// Read a plan from `SPECMER_FAULT_SEED`, `SPECMER_FAULT_ENGINE_FAIL`,
    /// `SPECMER_FAULT_ROUND_ERROR`, `SPECMER_FAULT_ROUND_DELAY_MS`.
    /// Returns `None` when no fault knob is set (the production default).
    pub fn from_env() -> Option<FaultPlan> {
        fn f64_env(key: &str) -> f64 {
            std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(0.0)
        }
        fn u64_env(key: &str) -> u64 {
            std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(0)
        }
        let plan = FaultPlan {
            seed: u64_env("SPECMER_FAULT_SEED"),
            engine_build_fail: f64_env("SPECMER_FAULT_ENGINE_FAIL"),
            round_error: f64_env("SPECMER_FAULT_ROUND_ERROR"),
            round_delay_ms: u64_env("SPECMER_FAULT_ROUND_DELAY_MS"),
        };
        let armed =
            plan.engine_build_fail > 0.0 || plan.round_error > 0.0 || plan.round_delay_ms > 0;
        armed.then_some(plan)
    }

    /// The deterministic per-worker fault stream.
    pub fn state_for(&self, worker: usize) -> FaultState {
        FaultState { plan: *self, rng: Pcg64::new(self.seed ^ (worker as u64).wrapping_add(1)) }
    }
}

/// A worker's live fault stream: consults the plan with seeded draws.
pub struct FaultState {
    plan: FaultPlan,
    rng: Pcg64,
}

impl FaultState {
    /// Consulted once, before the engine factory runs.
    pub fn engine_build_fails(&mut self) -> bool {
        self.plan.engine_build_fail > 0.0 && self.rng.next_f64() < self.plan.engine_build_fail
    }

    /// Consulted at each lockstep round boundary with resident sequences.
    pub fn round_error_fires(&mut self) -> bool {
        self.plan.round_error > 0.0 && self.rng.next_f64() < self.plan.round_error
    }

    /// Extra latency to sleep at each round boundary, if any.
    pub fn round_delay(&self) -> Option<Duration> {
        (self.plan.round_delay_ms > 0).then(|| Duration::from_millis(self.plan.round_delay_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_are_deterministic_per_worker() {
        let plan =
            FaultPlan { seed: 7, engine_build_fail: 0.5, round_error: 0.5, round_delay_ms: 0 };
        let a: Vec<bool> = {
            let mut s = plan.state_for(0);
            (0..16).map(|_| s.round_error_fires()).collect()
        };
        let b: Vec<bool> = {
            let mut s = plan.state_for(0);
            (0..16).map(|_| s.round_error_fires()).collect()
        };
        assert_eq!(a, b);
        // different workers see different streams
        let c: Vec<bool> = {
            let mut s = plan.state_for(1);
            (0..16).map(|_| s.round_error_fires()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn certain_faults_always_fire_and_zero_never_does() {
        let hot =
            FaultPlan { seed: 1, engine_build_fail: 1.0, round_error: 1.0, round_delay_ms: 3 };
        let mut s = hot.state_for(0);
        assert!(s.engine_build_fails());
        assert!(s.round_error_fires());
        assert_eq!(s.round_delay(), Some(Duration::from_millis(3)));

        let cold =
            FaultPlan { seed: 1, engine_build_fail: 0.0, round_error: 0.0, round_delay_ms: 0 };
        let mut s = cold.state_for(0);
        assert!(!s.engine_build_fails());
        assert!(!s.round_error_fires());
        assert_eq!(s.round_delay(), None);
    }
}
