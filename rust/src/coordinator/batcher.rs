//! Dynamic batcher: groups compatible queued requests so a worker can
//! amortize per-protein state (k-mer table locality, prefill-cache hits).
//!
//! Policy (vLLM-router style): requests are keyed by (protein, method);
//! a batch closes when it reaches `max_batch` or the oldest member has
//! waited `max_wait`. The queue preserves arrival order across keys so no
//! key starves.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::config::Method;
use crate::coordinator::request::GenRequest;

pub struct Batcher {
    queue: VecDeque<GenRequest>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch: max_batch.max(1), max_wait }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Key under which requests may share a batch.
    fn key(r: &GenRequest) -> (String, Method) {
        (r.protein.clone(), r.method)
    }

    /// Pop the next batch if one is ready (full, or oldest has waited long
    /// enough, or `flush` forces). Returns None when nothing should run yet.
    pub fn next_batch(&mut self, now: Instant, flush: bool) -> Option<Vec<GenRequest>> {
        let oldest = self.queue.front()?;
        let waited = now.duration_since(oldest.submitted);
        let key = Self::key(oldest);
        let matching = self
            .queue
            .iter()
            .filter(|r| Self::key(r) == key)
            .take(self.max_batch)
            .count();
        if !(flush || waited >= self.max_wait || matching >= self.max_batch) {
            return None;
        }
        // extract up to max_batch requests with the head's key, preserving order
        let mut batch = Vec::with_capacity(matching);
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(r) = self.queue.pop_front() {
            if batch.len() < self.max_batch && Self::key(&r) == key {
                batch.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.queue = rest;
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::GenConfig;
    use std::sync::mpsc::channel;

    fn req(id: u64, protein: &str, method: Method, age_ms: u64) -> GenRequest {
        let (tx, _rx) = channel();
        // keep receiver alive by leaking; tests only inspect grouping
        std::mem::forget(_rx);
        GenRequest {
            id,
            protein: protein.into(),
            method,
            cfg: GenConfig::default(),
            reply: tx,
            submitted: Instant::now() - Duration::from_millis(age_ms),
        }
    }

    #[test]
    fn groups_by_protein_and_method() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(1, "GFP", Method::SpecMer, 10));
        b.push(req(2, "GB1", Method::SpecMer, 10));
        b.push(req(3, "GFP", Method::SpecMer, 10));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 1);
        let batch2 = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch2[0].id, 2);
    }

    #[test]
    fn waits_for_max_wait() {
        let mut b = Batcher::new(8, Duration::from_millis(50));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        assert!(b.next_batch(Instant::now(), false).is_none(), "too fresh");
        b.push(req(2, "GFP", Method::SpecMer, 100));
        // oldest (id=1) is still fresh, but batch isn't full: next_batch
        // keys off the *front* request's age
        let got = b.next_batch(Instant::now() + Duration::from_millis(60), false);
        assert!(got.is_some());
    }

    #[test]
    fn full_batch_fires_immediately() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        b.push(req(2, "GFP", Method::SpecMer, 0));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn flush_forces_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        assert!(b.next_batch(Instant::now(), true).is_some());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2, Duration::from_millis(0));
        for i in 0..5 {
            b.push(req(i, "GFP", Method::SpecMer, 10));
        }
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn different_methods_do_not_mix() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(1, "GFP", Method::Speculative, 10));
        b.push(req(2, "GFP", Method::SpecMer, 10));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
    }
}
