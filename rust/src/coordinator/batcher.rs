//! Dynamic batcher: groups compatible queued requests so a worker can
//! amortize per-protein state (k-mer table locality, prefill-cache hits).
//!
//! Policy (vLLM-router style): requests are keyed by (protein, method);
//! a batch closes when it reaches `max_batch` or the oldest member has
//! waited `max_wait`. The queue preserves arrival order across keys so no
//! key starves.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::config::Method;
use crate::coordinator::request::GenRequest;

pub struct Batcher {
    queue: VecDeque<GenRequest>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch: max_batch.max(1), max_wait }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Key under which requests may share a batch. By-reference so the
    /// per-element comparisons `next_batch` runs on every poll don't
    /// allocate a `String` clone each.
    fn key(r: &GenRequest) -> (&str, Method) {
        (r.protein.as_str(), r.method)
    }

    /// Time until the oldest queued request reaches `max_wait` (zero if it
    /// already has; `max_wait` when the queue is empty). Workers sleep on
    /// this instead of polling.
    pub fn time_to_deadline(&self, now: Instant) -> Duration {
        match self.queue.front() {
            Some(r) => self.max_wait.saturating_sub(now.saturating_duration_since(r.submitted)),
            None => self.max_wait,
        }
    }

    /// Pop the next batch if one is ready (full, or oldest has waited long
    /// enough, or `flush` forces). Returns None when nothing should run yet.
    pub fn next_batch(&mut self, now: Instant, flush: bool) -> Option<Vec<GenRequest>> {
        let oldest = self.queue.front()?;
        let waited = now.saturating_duration_since(oldest.submitted);
        let matching = {
            let key = Self::key(oldest);
            self.queue
                .iter()
                .filter(|r| Self::key(r) == key)
                .take(self.max_batch)
                .count()
        };
        if !(flush || waited >= self.max_wait || matching >= self.max_batch) {
            return None;
        }
        // extract up to max_batch requests with the head's key, preserving
        // order; the popped head carries the key for the remaining compares
        let mut batch = Vec::with_capacity(matching);
        let mut rest = VecDeque::with_capacity(self.queue.len());
        batch.push(self.queue.pop_front()?);
        while let Some(r) = self.queue.pop_front() {
            if batch.len() < self.max_batch && Self::key(&r) == Self::key(&batch[0]) {
                batch.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.queue = rest;
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::GenConfig;
    use std::sync::mpsc::channel;

    fn req(id: u64, protein: &str, method: Method, age_ms: u64) -> GenRequest {
        let (tx, _rx) = channel();
        // keep receiver alive by leaking; tests only inspect grouping
        std::mem::forget(_rx);
        GenRequest {
            id,
            protein: protein.into(),
            method,
            cfg: GenConfig::default(),
            reply: tx,
            submitted: Instant::now() - Duration::from_millis(age_ms),
        }
    }

    #[test]
    fn groups_by_protein_and_method() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(1, "GFP", Method::SpecMer, 10));
        b.push(req(2, "GB1", Method::SpecMer, 10));
        b.push(req(3, "GFP", Method::SpecMer, 10));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 1);
        let batch2 = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch2[0].id, 2);
    }

    #[test]
    fn waits_for_max_wait() {
        let mut b = Batcher::new(8, Duration::from_millis(50));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        assert!(b.next_batch(Instant::now(), false).is_none(), "too fresh");
        b.push(req(2, "GFP", Method::SpecMer, 100));
        // oldest (id=1) is still fresh, but batch isn't full: next_batch
        // keys off the *front* request's age
        let got = b.next_batch(Instant::now() + Duration::from_millis(60), false);
        assert!(got.is_some());
    }

    #[test]
    fn full_batch_fires_immediately() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        b.push(req(2, "GFP", Method::SpecMer, 0));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn flush_forces_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        assert!(b.next_batch(Instant::now(), true).is_some());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2, Duration::from_millis(0));
        for i in 0..5 {
            b.push(req(i, "GFP", Method::SpecMer, 10));
        }
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn cross_key_batches_pop_in_arrival_order() {
        // interleaved keys: batches must come out headed by the oldest
        // remaining request, never reordered across keys
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(1, "GFP", Method::SpecMer, 40));
        b.push(req(2, "GB1", Method::SpecMer, 30));
        b.push(req(3, "GFP", Method::SpecMer, 20));
        b.push(req(4, "TEM1", Method::SpecMer, 10));
        b.push(req(5, "GB1", Method::SpecMer, 5));
        let heads: Vec<u64> = std::iter::from_fn(|| {
            b.next_batch(Instant::now(), false).map(|batch| batch[0].id)
        })
        .collect();
        assert_eq!(heads, vec![1, 2, 4], "head order must follow arrival order");
        assert!(b.is_empty());
    }

    #[test]
    fn minority_key_is_not_starved_by_a_flood() {
        // 10 GFP requests around a single GB1: GB1 must be served as soon
        // as it reaches the front, within a bounded number of polls
        let mut b = Batcher::new(4, Duration::from_millis(0));
        for i in 0..5 {
            b.push(req(i, "GFP", Method::SpecMer, 100));
        }
        b.push(req(99, "GB1", Method::SpecMer, 60));
        for i in 5..10 {
            b.push(req(i, "GFP", Method::SpecMer, 50));
        }
        let mut polls = 0;
        let mut minority_seen = 0;
        while !b.is_empty() {
            polls += 1;
            assert!(polls <= 4, "minority key starved: {polls} polls and counting");
            let batch = b.next_batch(Instant::now(), false).unwrap();
            minority_seen += batch.iter().filter(|r| r.protein == "GB1").count();
        }
        assert_eq!(minority_seen, 1, "minority request delivered exactly once");
    }

    #[test]
    fn flush_drains_every_request_exactly_once() {
        let mut b = Batcher::new(3, Duration::from_secs(3600));
        let mut want: Vec<u64> = Vec::new();
        for i in 0..10u64 {
            let protein = ["GFP", "GB1", "TEM1"][(i % 3) as usize];
            b.push(req(i, protein, Method::SpecMer, 0));
            want.push(i);
        }
        let mut got: Vec<u64> = Vec::new();
        while let Some(batch) = b.next_batch(Instant::now(), true) {
            assert!(batch.len() <= 3, "flush must still respect max_batch");
            got.extend(batch.iter().map(|r| r.id));
        }
        assert!(b.is_empty(), "flush leaves nothing behind");
        got.sort_unstable();
        assert_eq!(got, want, "every queued request drained exactly once");
    }

    #[test]
    fn deadline_tracks_oldest_request() {
        let mut b = Batcher::new(8, Duration::from_millis(100));
        assert_eq!(
            b.time_to_deadline(Instant::now()),
            Duration::from_millis(100),
            "empty queue falls back to max_wait"
        );
        b.push(req(1, "GFP", Method::SpecMer, 40));
        b.push(req(2, "GFP", Method::SpecMer, 10)); // younger, not the head
        let ttd = b.time_to_deadline(Instant::now());
        assert!(ttd <= Duration::from_millis(60), "keyed off the oldest: {ttd:?}");
        // an aged-out head saturates to zero rather than panicking
        let mut b2 = Batcher::new(8, Duration::from_millis(100));
        b2.push(req(3, "GB1", Method::SpecMer, 500));
        assert_eq!(b2.time_to_deadline(Instant::now()), Duration::ZERO);
    }

    #[test]
    fn different_methods_do_not_mix() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(1, "GFP", Method::Speculative, 10));
        b.push(req(2, "GFP", Method::SpecMer, 10));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
    }
}
