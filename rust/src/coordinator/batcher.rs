//! Dynamic batcher: groups compatible queued requests so a worker can
//! share lockstep decode rounds across them.
//!
//! Policy: requests are keyed by their **lockstep dispatch shape** alone
//! (`SeqSpec::lockstep_shape()` — `Some((c, gamma))` for the speculative
//! methods, `None` for baselines and probe items), *not* by
//! `(protein, method)`: per-sequence k-mer tables and contexts ride on the
//! `SeqSpec`, so requests for different protein families and mixed
//! SpecMER/vanilla-speculative methods share one batch and one in-flight
//! lockstep group. A batch closes when it reaches `max_batch` or the
//! oldest member has waited `max_wait`. The queue preserves arrival order
//! across keys so no shape starves, and round-boundary admission
//! ([`Batcher::take_compatible`]) adds a **soft protein affinity**: when
//! more shape-compatible requests are poppable than fit, the in-flight
//! group's majority protein is preferred (k-mer table + prefill-cache
//! locality) — but aged-out requests of any protein keep arrival-order
//! priority, and an aged-out incompatible queue head blocks admission
//! entirely, so foreign proteins are never starved.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::request::GenRequest;
use crate::decode::LockstepShape;

/// Default per-worker queue capacity when the caller doesn't pick one.
pub const DEFAULT_QUEUE_CAPACITY: usize = 256;

pub struct Batcher {
    // lint:allow(unbounded): growth is bounded by `capacity`, enforced in try_push
    queue: VecDeque<GenRequest>,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Admission bound: [`Self::try_push`] refuses beyond this depth.
    capacity: usize,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher::bounded(max_batch, max_wait, DEFAULT_QUEUE_CAPACITY)
    }

    pub fn bounded(max_batch: usize, max_wait: Duration, capacity: usize) -> Batcher {
        Batcher {
            // lint:allow(unbounded): growth is bounded by `capacity`, enforced in try_push
            queue: VecDeque::new(),
            max_batch: max_batch.max(1),
            max_wait,
            capacity: capacity.max(1),
        }
    }

    /// Bounded enqueue: hands the request back when the queue is at
    /// capacity so the caller can shed it (answer `GenError::Overloaded`)
    /// instead of growing memory without limit.
    pub fn try_push(&mut self, req: GenRequest) -> Result<(), GenRequest> {
        if self.queue.len() >= self.capacity {
            return Err(req);
        }
        // lint:allow(unbounded): capacity checked in the line above
        self.queue.push_back(req);
        Ok(())
    }

    /// Test convenience: bounded push that panics past capacity (production
    /// callers shed through [`Self::try_push`]).
    #[cfg(test)]
    fn push(&mut self, req: GenRequest) {
        if self.try_push(req).is_err() {
            panic!("test enqueue past capacity");
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Key under which requests may share a batch: the lockstep dispatch
    /// shape only. `None` (baselines, probe items) is its own key — those
    /// requests decode serially inside their batch anyway.
    fn key(r: &GenRequest) -> Option<LockstepShape> {
        r.spec.lockstep_shape()
    }

    /// Time until the oldest queued request reaches `max_wait` (zero if it
    /// already has; `max_wait` when the queue is empty). Workers sleep on
    /// this instead of polling.
    pub fn time_to_deadline(&self, now: Instant) -> Duration {
        match self.queue.front() {
            Some(r) => self.max_wait.saturating_sub(now.saturating_duration_since(r.submitted)),
            None => self.max_wait,
        }
    }

    /// Count queued requests that could join an in-flight lockstep group
    /// of `shape` — the admission preview [`Self::take_compatible`] uses to
    /// skip queue rebuilds on boundaries with nothing to admit.
    pub fn peek_compatible(&self, shape: LockstepShape) -> usize {
        self.queue.iter().filter(|r| Self::key(r) == Some(shape)).count()
    }

    /// Remove and return up to `max` queued requests whose dispatch shape
    /// matches `shape` — the round-boundary admission pop for continuous
    /// batching. Any protein and any speculative method qualifies.
    ///
    /// Fairness guard: when the queue head is *incompatible* and has
    /// already waited `max_wait`, nothing is admitted — an in-flight group
    /// must not keep jumping an aged-out request whose own dispatch is
    /// blocked behind it.
    ///
    /// Soft protein affinity: when more compatible requests are queued
    /// than `max`, requests for `prefer` (the group's majority protein)
    /// are taken first — except that compatible requests which have
    /// *already aged out* keep arrival-order priority over everything, so
    /// a minority protein is never starved by a same-shape flood. Taken
    /// requests are returned in arrival order.
    pub fn take_compatible(
        &mut self,
        now: Instant,
        shape: LockstepShape,
        max: usize,
        prefer: Option<&str>,
    ) -> Vec<GenRequest> {
        if max == 0 || self.queue.is_empty() {
            return Vec::new();
        }
        if let Some(front) = self.queue.front() {
            let front_compatible = Self::key(front) == Some(shape);
            if !front_compatible
                && now.saturating_duration_since(front.submitted) >= self.max_wait
            {
                return Vec::new();
            }
        }
        // boundaries with nothing to admit are the common case under mixed
        // traffic: don't rebuild the queue unless something matches
        let n_compat = self.peek_compatible(shape);
        if n_compat == 0 {
            return Vec::new();
        }
        let chosen: Vec<usize> = if n_compat <= max {
            // everything compatible fits: plain arrival order
            self.queue
                .iter()
                .enumerate()
                .filter(|(_, r)| Self::key(r) == Some(shape))
                .map(|(i, _)| i)
                .collect()
        } else {
            // oversubscribed: aged-out first (arrival order — the
            // no-starvation clause), then the preferred protein, then the
            // rest; re-sorted to arrival order after the cut
            let mut aged = Vec::new();
            let mut pref = Vec::new();
            let mut rest = Vec::new();
            for (i, r) in self.queue.iter().enumerate() {
                if Self::key(r) != Some(shape) {
                    continue;
                }
                if now.saturating_duration_since(r.submitted) >= self.max_wait {
                    aged.push(i);
                } else if prefer.is_some_and(|p| &*r.spec.protein == p) {
                    pref.push(i);
                } else {
                    rest.push(i);
                }
            }
            let mut chosen: Vec<usize> =
                aged.into_iter().chain(pref).chain(rest).take(max).collect();
            chosen.sort_unstable();
            chosen
        };
        let mut taken = Vec::with_capacity(chosen.len());
        let mut rest = VecDeque::with_capacity(self.queue.len());
        for (i, r) in self.queue.drain(..).enumerate() {
            if chosen.binary_search(&i).is_ok() {
                taken.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.queue = rest;
        taken
    }

    /// Pop the next batch if one is ready (full, or oldest has waited long
    /// enough, or `flush` forces). Returns None when nothing should run yet.
    /// A popped batch is shape-homogeneous: either one lockstep group's
    /// worth of compatible requests or a run of non-lockstep requests.
    pub fn next_batch(&mut self, now: Instant, flush: bool) -> Option<Vec<GenRequest>> {
        let oldest = self.queue.front()?;
        let waited = now.saturating_duration_since(oldest.submitted);
        let matching = {
            let key = Self::key(oldest);
            self.queue
                .iter()
                .filter(|r| Self::key(r) == key)
                .take(self.max_batch)
                .count()
        };
        if !(flush || waited >= self.max_wait || matching >= self.max_batch) {
            return None;
        }
        // extract up to max_batch requests with the head's key, preserving
        // order; the popped head carries the key for the remaining compares
        let mut batch = Vec::with_capacity(matching);
        let mut rest = VecDeque::with_capacity(self.queue.len());
        batch.push(self.queue.pop_front()?);
        while let Some(r) = self.queue.pop_front() {
            if batch.len() < self.max_batch && Self::key(&r) == Self::key(&batch[0]) {
                batch.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.queue = rest;
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::coordinator::request::SeqSpec;
    use crate::decode::GenConfig;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn spec(protein: &str, method: Method, c: usize, gamma: usize) -> SeqSpec {
        // hand-built spec (tests bypass the registry); configs are given
        // pre-normalized, like SeqSpec::resolve would produce
        SeqSpec {
            protein: Arc::from(protein),
            method,
            context: vec![1, 5, 9].into(),
            table: None,
            cfg: GenConfig { c, gamma, ..Default::default() },
        }
    }

    fn req_shaped(
        id: u64,
        protein: &str,
        method: Method,
        c: usize,
        gamma: usize,
        age_ms: u64,
    ) -> GenRequest {
        let (tx, _rx) = channel();
        // keep receiver alive by leaking; tests only inspect grouping
        std::mem::forget(_rx);
        GenRequest {
            id,
            spec: spec(protein, method, c, gamma),
            reply: tx,
            submitted: Instant::now() - Duration::from_millis(age_ms),
            deadline: None,
        }
    }

    fn req(id: u64, protein: &str, method: Method, age_ms: u64) -> GenRequest {
        req_shaped(id, protein, method, 3, 5, age_ms)
    }

    fn shape(c: usize, gamma: usize) -> LockstepShape {
        LockstepShape { c, gamma, tree: Default::default() }
    }

    #[test]
    fn try_push_sheds_past_capacity() {
        let mut b = Batcher::bounded(8, Duration::from_millis(0), 2);
        assert!(b.try_push(req(1, "GFP", Method::SpecMer, 0)).is_ok());
        assert!(b.try_push(req(2, "GFP", Method::SpecMer, 0)).is_ok());
        assert!(b.is_full());
        // the refused request comes back intact for the caller to answer
        let back = b.try_push(req(3, "GFP", Method::SpecMer, 0)).unwrap_err();
        assert_eq!(back.id, 3);
        assert_eq!(b.len(), 2);
        // popping frees capacity again
        b.next_batch(Instant::now(), true).unwrap();
        assert!(b.try_push(req(4, "GFP", Method::SpecMer, 0)).is_ok());
    }

    #[test]
    fn groups_by_shape_across_proteins_and_methods() {
        // the tentpole: different proteins — and mixed SpecMER/vanilla
        // methods — with the same (c, gamma) share one batch
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req_shaped(1, "GFP", Method::SpecMer, 3, 5, 10));
        b.push(req_shaped(2, "GB1", Method::SpecMer, 3, 5, 10));
        b.push(req_shaped(3, "TEM1", Method::Speculative, 3, 5, 10));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn different_shapes_do_not_mix() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req_shaped(1, "GFP", Method::SpecMer, 3, 5, 10));
        b.push(req_shaped(2, "GFP", Method::SpecMer, 3, 8, 10)); // gamma differs
        b.push(req_shaped(3, "GFP", Method::SpecMer, 3, 5, 10));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 1);
        let batch2 = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch2[0].id, 2);
    }

    #[test]
    fn non_lockstep_requests_share_the_none_key() {
        // baselines have no dispatch shape; they batch together (the
        // engine loops them serially) but never with lockstep requests
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req_shaped(1, "GFP", Method::TargetOnly, 1, 5, 10));
        b.push(req_shaped(2, "GB1", Method::DraftOnly, 1, 5, 10));
        b.push(req_shaped(3, "GFP", Method::SpecMer, 3, 5, 10));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        let batch2 = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch2[0].id, 3);
    }

    #[test]
    fn waits_for_max_wait() {
        let mut b = Batcher::new(8, Duration::from_millis(50));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        assert!(b.next_batch(Instant::now(), false).is_none(), "too fresh");
        b.push(req(2, "GFP", Method::SpecMer, 100));
        // oldest (id=1) is still fresh, but batch isn't full: next_batch
        // keys off the *front* request's age
        let got = b.next_batch(Instant::now() + Duration::from_millis(60), false);
        assert!(got.is_some());
    }

    #[test]
    fn full_batch_fires_immediately() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        b.push(req(2, "GB1", Method::SpecMer, 0));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 2, "cross-protein requests fill the batch");
    }

    #[test]
    fn flush_forces_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        assert!(b.next_batch(Instant::now(), true).is_some());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2, Duration::from_millis(0));
        for i in 0..5 {
            b.push(req(i, "GFP", Method::SpecMer, 10));
        }
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn cross_key_batches_pop_in_arrival_order() {
        // interleaved shapes: batches must come out headed by the oldest
        // remaining request, never reordered across keys
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req_shaped(1, "GFP", Method::SpecMer, 3, 5, 40));
        b.push(req_shaped(2, "GB1", Method::SpecMer, 3, 8, 30));
        b.push(req_shaped(3, "GFP", Method::SpecMer, 3, 5, 20));
        b.push(req_shaped(4, "TEM1", Method::SpecMer, 5, 5, 10));
        b.push(req_shaped(5, "GB1", Method::SpecMer, 3, 8, 5));
        let heads: Vec<u64> = std::iter::from_fn(|| {
            b.next_batch(Instant::now(), false).map(|batch| batch[0].id)
        })
        .collect();
        assert_eq!(heads, vec![1, 2, 4], "head order must follow arrival order");
        assert!(b.is_empty());
    }

    #[test]
    fn minority_shape_is_not_starved_by_a_flood() {
        // 10 (3,5) requests around a single (3,8): the minority shape must
        // be served as soon as it reaches the front, within bounded polls
        let mut b = Batcher::new(4, Duration::from_millis(0));
        for i in 0..5 {
            b.push(req_shaped(i, "GFP", Method::SpecMer, 3, 5, 100));
        }
        b.push(req_shaped(99, "GB1", Method::SpecMer, 3, 8, 60));
        for i in 5..10 {
            b.push(req_shaped(i, "GFP", Method::SpecMer, 3, 5, 50));
        }
        let mut polls = 0;
        let mut minority_seen = 0;
        while !b.is_empty() {
            polls += 1;
            assert!(polls <= 4, "minority shape starved: {polls} polls and counting");
            let batch = b.next_batch(Instant::now(), false).unwrap();
            minority_seen += batch.iter().filter(|r| &*r.spec.protein == "GB1").count();
        }
        assert_eq!(minority_seen, 1, "minority request delivered exactly once");
    }

    #[test]
    fn flush_drains_every_request_exactly_once() {
        let mut b = Batcher::new(3, Duration::from_secs(3600));
        let mut want: Vec<u64> = Vec::new();
        for i in 0..10u64 {
            let protein = ["GFP", "GB1", "TEM1"][(i % 3) as usize];
            let gamma = [5usize, 8, 10][(i % 3) as usize];
            b.push(req_shaped(i, protein, Method::SpecMer, 3, gamma, 0));
            want.push(i);
        }
        let mut got: Vec<u64> = Vec::new();
        while let Some(batch) = b.next_batch(Instant::now(), true) {
            assert!(batch.len() <= 3, "flush must still respect max_batch");
            got.extend(batch.iter().map(|r| r.id));
        }
        assert!(b.is_empty(), "flush leaves nothing behind");
        got.sort_unstable();
        assert_eq!(got, want, "every queued request drained exactly once");
    }

    #[test]
    fn deadline_tracks_oldest_request() {
        let mut b = Batcher::new(8, Duration::from_millis(100));
        assert_eq!(
            b.time_to_deadline(Instant::now()),
            Duration::from_millis(100),
            "empty queue falls back to max_wait"
        );
        b.push(req(1, "GFP", Method::SpecMer, 40));
        b.push(req(2, "GFP", Method::SpecMer, 10)); // younger, not the head
        let ttd = b.time_to_deadline(Instant::now());
        assert!(ttd <= Duration::from_millis(60), "keyed off the oldest: {ttd:?}");
        // an aged-out head saturates to zero rather than panicking
        let mut b2 = Batcher::new(8, Duration::from_millis(100));
        b2.push(req(3, "GB1", Method::SpecMer, 500));
        assert_eq!(b2.time_to_deadline(Instant::now()), Duration::ZERO);
    }

    #[test]
    fn take_compatible_pops_matching_shapes_across_proteins() {
        let mut b = Batcher::new(8, Duration::from_secs(3600));
        b.push(req_shaped(1, "GFP", Method::SpecMer, 3, 5, 10));
        b.push(req_shaped(2, "GB1", Method::SpecMer, 3, 8, 9)); // wrong shape
        b.push(req_shaped(3, "GB1", Method::SpecMer, 3, 5, 8)); // other protein, fits
        b.push(req_shaped(4, "GFP", Method::Speculative, 1, 5, 7)); // wrong shape (c=1)
        b.push(req_shaped(5, "GFP", Method::SpecMer, 3, 5, 6));
        assert_eq!(b.peek_compatible(shape(3, 5)), 3);
        let got = b.take_compatible(Instant::now(), shape(3, 5), 2, None);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 3, "non-matching and over-max requests stay queued");
        // the leftovers keep their arrival order
        let mut rest = Vec::new();
        while let Some(batch) = b.next_batch(Instant::now(), true) {
            rest.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(rest, vec![2, 4, 5]);
    }

    #[test]
    fn take_compatible_prefers_majority_protein_when_oversubscribed() {
        let mut b = Batcher::new(8, Duration::from_secs(3600));
        b.push(req(1, "GB1", Method::SpecMer, 10));
        b.push(req(2, "GFP", Method::SpecMer, 9));
        b.push(req(3, "GB1", Method::SpecMer, 8));
        b.push(req(4, "GFP", Method::SpecMer, 7));
        // room for 2 of 4: the in-flight group's majority protein (GFP)
        // wins the contested slots, arrival order preserved among taken
        let got = b.take_compatible(Instant::now(), shape(3, 5), 2, Some("GFP"));
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 4]);
        assert_eq!(b.len(), 2, "foreign-protein requests stay queued, not dropped");
        // with room for everything, affinity must not reorder or filter
        let got2 = b.take_compatible(Instant::now(), shape(3, 5), 8, Some("GFP"));
        assert_eq!(got2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn affinity_never_starves_aged_foreign_proteins() {
        let mut b = Batcher::new(8, Duration::from_millis(50));
        b.push(req(1, "GB1", Method::SpecMer, 100)); // aged out, foreign
        b.push(req(2, "GFP", Method::SpecMer, 10));
        b.push(req(3, "GFP", Method::SpecMer, 9));
        // one slot, preference GFP — but the aged-out GB1 request keeps
        // arrival-order priority over the preferred protein
        let got = b.take_compatible(Instant::now(), shape(3, 5), 1, Some("GFP"));
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn take_compatible_yields_to_aged_out_incompatible_head() {
        // an aged-out head of a *different* shape blocks admission (the
        // in-flight group must not starve it further)...
        let mut b = Batcher::new(8, Duration::from_millis(50));
        b.push(req_shaped(1, "GB1", Method::SpecMer, 3, 8, 100));
        b.push(req_shaped(2, "GFP", Method::SpecMer, 3, 5, 100));
        assert!(b.take_compatible(Instant::now(), shape(3, 5), 8, None).is_empty());
        // ...but a still-fresh incompatible head does not
        let mut b2 = Batcher::new(8, Duration::from_millis(50));
        b2.push(req_shaped(3, "GB1", Method::SpecMer, 3, 8, 0));
        b2.push(req_shaped(4, "GFP", Method::SpecMer, 3, 5, 0));
        let got = b2.take_compatible(Instant::now(), shape(3, 5), 8, None);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
        assert_eq!(b2.len(), 1);
        // an aged-out *compatible* head never blocks — whatever its protein
        let mut b3 = Batcher::new(8, Duration::from_millis(50));
        b3.push(req_shaped(5, "GB1", Method::SpecMer, 3, 5, 100));
        let got = b3.take_compatible(Instant::now(), shape(3, 5), 8, Some("GFP"));
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![5]);
    }

    #[test]
    fn probe_items_never_join_lockstep_admission() {
        let mut b = Batcher::new(8, Duration::from_secs(3600));
        let mut r = req(1, "GFP", Method::SpecMer, 10);
        r.spec.cfg.probe_rate = 1.0; // sequential-path only
        b.push(r);
        b.push(req(2, "GFP", Method::SpecMer, 9));
        let got = b.take_compatible(Instant::now(), shape(3, 5), 8, None);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert_eq!(b.len(), 1, "probe item stays queued for the serial path");
    }
}
