//! Dynamic batcher: groups compatible queued requests so a worker can
//! amortize per-protein state (k-mer table locality, prefill-cache hits).
//!
//! Policy (vLLM-router style): requests are keyed by (protein, method);
//! a batch closes when it reaches `max_batch` or the oldest member has
//! waited `max_wait`. The queue preserves arrival order across keys so no
//! key starves.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::config::Method;
use crate::coordinator::request::GenRequest;

pub struct Batcher {
    queue: VecDeque<GenRequest>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch: max_batch.max(1), max_wait }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Key under which requests may share a batch. By-reference so the
    /// per-element comparisons `next_batch` runs on every poll don't
    /// allocate a `String` clone each.
    fn key(r: &GenRequest) -> (&str, Method) {
        (r.protein.as_str(), r.method)
    }

    /// Time until the oldest queued request reaches `max_wait` (zero if it
    /// already has; `max_wait` when the queue is empty). Workers sleep on
    /// this instead of polling.
    pub fn time_to_deadline(&self, now: Instant) -> Duration {
        match self.queue.front() {
            Some(r) => self.max_wait.saturating_sub(now.saturating_duration_since(r.submitted)),
            None => self.max_wait,
        }
    }

    /// Count queued requests that could join an in-flight lockstep group
    /// for `(protein, method)` under `pred` — the admission preview
    /// [`Self::take_compatible`] uses to skip queue rebuilds on boundaries
    /// with nothing to admit.
    pub fn peek_compatible(
        &self,
        protein: &str,
        method: Method,
        pred: &dyn Fn(&GenRequest) -> bool,
    ) -> usize {
        self.queue
            .iter()
            .filter(|r| Self::key(r) == (protein, method) && pred(r))
            .count()
    }

    /// Remove and return up to `max` queued requests for `(protein, method)`
    /// that satisfy `pred`, preserving arrival order — the round-boundary
    /// admission pop for continuous batching.
    ///
    /// Fairness guard: when the queue head belongs to a *different* group
    /// and has already waited `max_wait`, nothing is admitted — an
    /// in-flight group must not keep jumping an aged-out request whose own
    /// dispatch is blocked behind it.
    pub fn take_compatible(
        &mut self,
        now: Instant,
        protein: &str,
        method: Method,
        max: usize,
        pred: &dyn Fn(&GenRequest) -> bool,
    ) -> Vec<GenRequest> {
        if max == 0 || self.queue.is_empty() {
            return Vec::new();
        }
        if let Some(front) = self.queue.front() {
            let front_admissible = Self::key(front) == (protein, method) && pred(front);
            if !front_admissible
                && now.saturating_duration_since(front.submitted) >= self.max_wait
            {
                return Vec::new();
            }
        }
        // boundaries with nothing to admit are the common case under mixed
        // traffic: don't rebuild the queue unless something matches
        if self.peek_compatible(protein, method, pred) == 0 {
            return Vec::new();
        }
        let mut taken = Vec::new();
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(r) = self.queue.pop_front() {
            if Self::key(&r) == (protein, method) && pred(&r) {
                taken.push(r);
                if taken.len() == max {
                    break;
                }
            } else {
                rest.push_back(r);
            }
        }
        // once full, everything left keeps its order behind the leftovers
        rest.extend(self.queue.drain(..));
        self.queue = rest;
        taken
    }

    /// Pop the next batch if one is ready (full, or oldest has waited long
    /// enough, or `flush` forces). Returns None when nothing should run yet.
    pub fn next_batch(&mut self, now: Instant, flush: bool) -> Option<Vec<GenRequest>> {
        let oldest = self.queue.front()?;
        let waited = now.saturating_duration_since(oldest.submitted);
        let matching = {
            let key = Self::key(oldest);
            self.queue
                .iter()
                .filter(|r| Self::key(r) == key)
                .take(self.max_batch)
                .count()
        };
        if !(flush || waited >= self.max_wait || matching >= self.max_batch) {
            return None;
        }
        // extract up to max_batch requests with the head's key, preserving
        // order; the popped head carries the key for the remaining compares
        let mut batch = Vec::with_capacity(matching);
        let mut rest = VecDeque::with_capacity(self.queue.len());
        batch.push(self.queue.pop_front()?);
        while let Some(r) = self.queue.pop_front() {
            if batch.len() < self.max_batch && Self::key(&r) == Self::key(&batch[0]) {
                batch.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.queue = rest;
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::GenConfig;
    use std::sync::mpsc::channel;

    fn req(id: u64, protein: &str, method: Method, age_ms: u64) -> GenRequest {
        let (tx, _rx) = channel();
        // keep receiver alive by leaking; tests only inspect grouping
        std::mem::forget(_rx);
        GenRequest {
            id,
            protein: protein.into(),
            method,
            cfg: GenConfig::default(),
            reply: tx,
            submitted: Instant::now() - Duration::from_millis(age_ms),
        }
    }

    #[test]
    fn groups_by_protein_and_method() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(1, "GFP", Method::SpecMer, 10));
        b.push(req(2, "GB1", Method::SpecMer, 10));
        b.push(req(3, "GFP", Method::SpecMer, 10));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 1);
        let batch2 = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch2[0].id, 2);
    }

    #[test]
    fn waits_for_max_wait() {
        let mut b = Batcher::new(8, Duration::from_millis(50));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        assert!(b.next_batch(Instant::now(), false).is_none(), "too fresh");
        b.push(req(2, "GFP", Method::SpecMer, 100));
        // oldest (id=1) is still fresh, but batch isn't full: next_batch
        // keys off the *front* request's age
        let got = b.next_batch(Instant::now() + Duration::from_millis(60), false);
        assert!(got.is_some());
    }

    #[test]
    fn full_batch_fires_immediately() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        b.push(req(2, "GFP", Method::SpecMer, 0));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn flush_forces_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 0));
        assert!(b.next_batch(Instant::now(), true).is_some());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2, Duration::from_millis(0));
        for i in 0..5 {
            b.push(req(i, "GFP", Method::SpecMer, 10));
        }
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn cross_key_batches_pop_in_arrival_order() {
        // interleaved keys: batches must come out headed by the oldest
        // remaining request, never reordered across keys
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(1, "GFP", Method::SpecMer, 40));
        b.push(req(2, "GB1", Method::SpecMer, 30));
        b.push(req(3, "GFP", Method::SpecMer, 20));
        b.push(req(4, "TEM1", Method::SpecMer, 10));
        b.push(req(5, "GB1", Method::SpecMer, 5));
        let heads: Vec<u64> = std::iter::from_fn(|| {
            b.next_batch(Instant::now(), false).map(|batch| batch[0].id)
        })
        .collect();
        assert_eq!(heads, vec![1, 2, 4], "head order must follow arrival order");
        assert!(b.is_empty());
    }

    #[test]
    fn minority_key_is_not_starved_by_a_flood() {
        // 10 GFP requests around a single GB1: GB1 must be served as soon
        // as it reaches the front, within a bounded number of polls
        let mut b = Batcher::new(4, Duration::from_millis(0));
        for i in 0..5 {
            b.push(req(i, "GFP", Method::SpecMer, 100));
        }
        b.push(req(99, "GB1", Method::SpecMer, 60));
        for i in 5..10 {
            b.push(req(i, "GFP", Method::SpecMer, 50));
        }
        let mut polls = 0;
        let mut minority_seen = 0;
        while !b.is_empty() {
            polls += 1;
            assert!(polls <= 4, "minority key starved: {polls} polls and counting");
            let batch = b.next_batch(Instant::now(), false).unwrap();
            minority_seen += batch.iter().filter(|r| r.protein == "GB1").count();
        }
        assert_eq!(minority_seen, 1, "minority request delivered exactly once");
    }

    #[test]
    fn flush_drains_every_request_exactly_once() {
        let mut b = Batcher::new(3, Duration::from_secs(3600));
        let mut want: Vec<u64> = Vec::new();
        for i in 0..10u64 {
            let protein = ["GFP", "GB1", "TEM1"][(i % 3) as usize];
            b.push(req(i, protein, Method::SpecMer, 0));
            want.push(i);
        }
        let mut got: Vec<u64> = Vec::new();
        while let Some(batch) = b.next_batch(Instant::now(), true) {
            assert!(batch.len() <= 3, "flush must still respect max_batch");
            got.extend(batch.iter().map(|r| r.id));
        }
        assert!(b.is_empty(), "flush leaves nothing behind");
        got.sort_unstable();
        assert_eq!(got, want, "every queued request drained exactly once");
    }

    #[test]
    fn deadline_tracks_oldest_request() {
        let mut b = Batcher::new(8, Duration::from_millis(100));
        assert_eq!(
            b.time_to_deadline(Instant::now()),
            Duration::from_millis(100),
            "empty queue falls back to max_wait"
        );
        b.push(req(1, "GFP", Method::SpecMer, 40));
        b.push(req(2, "GFP", Method::SpecMer, 10)); // younger, not the head
        let ttd = b.time_to_deadline(Instant::now());
        assert!(ttd <= Duration::from_millis(60), "keyed off the oldest: {ttd:?}");
        // an aged-out head saturates to zero rather than panicking
        let mut b2 = Batcher::new(8, Duration::from_millis(100));
        b2.push(req(3, "GB1", Method::SpecMer, 500));
        assert_eq!(b2.time_to_deadline(Instant::now()), Duration::ZERO);
    }

    #[test]
    fn take_compatible_pops_matching_in_arrival_order() {
        let mut b = Batcher::new(8, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 10));
        b.push(req(2, "GB1", Method::SpecMer, 9));
        b.push(req(3, "GFP", Method::SpecMer, 8));
        b.push(req(4, "GFP", Method::Speculative, 7));
        b.push(req(5, "GFP", Method::SpecMer, 6));
        let all = |_: &GenRequest| true;
        assert_eq!(b.peek_compatible("GFP", Method::SpecMer, &all), 3);
        let got = b.take_compatible(Instant::now(), "GFP", Method::SpecMer, 2, &all);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 3, "non-matching and over-max requests stay queued");
        // the leftovers keep their arrival order
        let mut rest = Vec::new();
        while let Some(batch) = b.next_batch(Instant::now(), true) {
            rest.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(rest, vec![2, 4, 5]);
    }

    #[test]
    fn take_compatible_respects_pred() {
        let mut b = Batcher::new(8, Duration::from_secs(3600));
        b.push(req(1, "GFP", Method::SpecMer, 10));
        b.push(req(2, "GFP", Method::SpecMer, 9));
        let odd_only = |r: &GenRequest| r.id % 2 == 1;
        let got = b.take_compatible(Instant::now(), "GFP", Method::SpecMer, 8, &odd_only);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 1);
        assert_eq!(b.len(), 1, "pred-rejected request stays queued");
    }

    #[test]
    fn take_compatible_yields_to_aged_out_foreign_head() {
        // an aged-out head of a *different* group blocks admission (the
        // in-flight group must not starve it further)...
        let mut b = Batcher::new(8, Duration::from_millis(50));
        b.push(req(1, "GB1", Method::SpecMer, 100));
        b.push(req(2, "GFP", Method::SpecMer, 100));
        let all = |_: &GenRequest| true;
        assert!(b.take_compatible(Instant::now(), "GFP", Method::SpecMer, 8, &all).is_empty());
        // ...but a still-fresh foreign head does not
        let mut b2 = Batcher::new(8, Duration::from_millis(50));
        b2.push(req(3, "GB1", Method::SpecMer, 0));
        b2.push(req(4, "GFP", Method::SpecMer, 0));
        let got = b2.take_compatible(Instant::now(), "GFP", Method::SpecMer, 8, &all);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
        assert_eq!(b2.len(), 1);
    }

    #[test]
    fn different_methods_do_not_mix() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(1, "GFP", Method::Speculative, 10));
        b.push(req(2, "GFP", Method::SpecMer, 10));
        let batch = b.next_batch(Instant::now(), false).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
    }
}
