//! Prefill memoization adapter.
//!
//! Protein-screening workloads issue many requests with the *same* context
//! (Table 1: one fixed wild-type prefix per protein), and prefill is a
//! full-maxlen forward — by far the most expensive single dispatch of a
//! request. This adapter wraps any [`ModelBackend`] and memoizes prefill
//! results by context, restoring snapshots via the cache host round-trip.
//! Everything else delegates.
//!
//! The memo is **bounded** (default [`DEFAULT_MEMO_CAP`] contexts) with
//! deterministic insertion-order (FIFO) eviction — the spirit of lint rule
//! 6: a long-lived process serving unbounded distinct contexts must not
//! grow without limit. The worker-resident `runtime::prefix_store` is the
//! byte-budgeted, LRU, residency-publishing sibling on the admission path;
//! this adapter stays the simple per-backend memo underneath it.

use std::cell::RefCell;
use std::collections::BTreeMap;

use anyhow::Result;

use super::backend::{
    DraftBlock, DraftSeq, DraftTreeBlock, ModelBackend, TokenTree, VerifyBlock, VerifySeq,
    VerifyTreeBlock,
};

/// Default memo capacity in distinct contexts. Serving workloads see a
/// handful of family contexts per worker; 32 covers them with room while
/// bounding a pathological stream of distinct contexts.
pub const DEFAULT_MEMO_CAP: usize = 32;

pub struct PrefillCached<B: ModelBackend> {
    inner: B,
    memo: RefCell<BTreeMap<Vec<u8>, Vec<f32>>>,
    /// Insertion order of live memo keys (oldest first) — FIFO eviction.
    order: RefCell<Vec<Vec<u8>>>,
    cap: usize,
    pub hits: RefCell<u64>,
    pub misses: RefCell<u64>,
    pub evictions: RefCell<u64>,
}

impl<B: ModelBackend> PrefillCached<B> {
    pub fn new(inner: B) -> Self {
        PrefillCached::with_capacity(inner, DEFAULT_MEMO_CAP)
    }

    /// A memo bounded to `cap` distinct contexts (0 disables memoization).
    pub fn with_capacity(inner: B, cap: usize) -> Self {
        PrefillCached {
            inner,
            memo: RefCell::new(BTreeMap::new()),
            order: RefCell::new(Vec::new()),
            cap,
            hits: RefCell::new(0),
            misses: RefCell::new(0),
            evictions: RefCell::new(0),
        }
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }
}

impl<B: ModelBackend> ModelBackend for PrefillCached<B> {
    type Cache = B::Cache;

    fn maxlen(&self) -> usize {
        self.inner.maxlen()
    }
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }
    fn supported_c(&self) -> &[usize] {
        self.inner.supported_c()
    }
    fn supported_gamma(&self) -> &[usize] {
        self.inner.supported_gamma()
    }

    fn prefill(&self, tokens: &[u8]) -> Result<Self::Cache> {
        if let Some(host) = self.memo.borrow().get(tokens) {
            *self.hits.borrow_mut() += 1;
            return self.inner.cache_from_host(host);
        }
        *self.misses.borrow_mut() += 1;
        let cache = self.inner.prefill(tokens)?;
        if self.cap == 0 {
            return Ok(cache);
        }
        let host = self.inner.cache_to_host(&cache)?;
        let mut memo = self.memo.borrow_mut();
        let mut order = self.order.borrow_mut();
        while memo.len() >= self.cap {
            // deterministic FIFO: the oldest-inserted context goes first
            let oldest = order.remove(0);
            memo.remove(&oldest);
            *self.evictions.borrow_mut() += 1;
        }
        memo.insert(tokens.to_vec(), host);
        order.push(tokens.to_vec());
        Ok(cache)
    }

    fn generate(
        &self,
        cache: &mut Self::Cache,
        feed: &[u8],
        pos: usize,
        c: usize,
        gamma: usize,
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> Result<DraftBlock> {
        self.inner.generate(cache, feed, pos, c, gamma, u, temp, top_p)
    }

    fn verify(
        &self,
        cache: &mut Self::Cache,
        toks: &[u8],
        pos: usize,
        temp: f32,
        top_p: f32,
    ) -> Result<VerifyBlock> {
        self.inner.verify(cache, toks, pos, temp, top_p)
    }

    // forward the lockstep entry points so the inner backend's batched
    // dispatches are used (the trait defaults would loop solo calls)
    fn generate_batch(
        &self,
        seqs: &mut [DraftSeq<'_, Self::Cache>],
        c: usize,
        gamma: usize,
    ) -> Result<Vec<DraftBlock>> {
        self.inner.generate_batch(seqs, c, gamma)
    }

    fn verify_batch(&self, seqs: &mut [VerifySeq<'_, Self::Cache>]) -> Result<Vec<VerifyBlock>> {
        self.inner.verify_batch(seqs)
    }

    // forward the tree entry points so the inner backend's tree-shaped
    // dispatches are used (the trait defaults would linearize to chains)
    fn draft_tree(
        &self,
        cache: &mut Self::Cache,
        feed: &[u8],
        pos: usize,
        parents: &[Option<usize>],
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> Result<DraftTreeBlock> {
        self.inner.draft_tree(cache, feed, pos, parents, u, temp, top_p)
    }

    fn verify_tree(
        &self,
        cache: &mut Self::Cache,
        trunk: &[u8],
        pos: usize,
        tree: &TokenTree,
        temp: f32,
        top_p: f32,
    ) -> Result<VerifyTreeBlock> {
        self.inner.verify_tree(cache, trunk, pos, tree, temp, top_p)
    }

    fn score(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        self.inner.score(tokens)
    }

    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        self.inner.embed(tokens)
    }

    fn cache_to_host(&self, cache: &Self::Cache) -> Result<Vec<f32>> {
        self.inner.cache_to_host(cache)
    }

    fn cache_from_host(&self, data: &[f32]) -> Result<Self::Cache> {
        self.inner.cache_from_host(data)
    }

    // forward the prefix-store admission hooks so chunked prefill and
    // copy-on-write snapshot attach reach the inner backend (the trait
    // defaults would report "unsupported" / materialize a copy)
    fn prefill_begin(&self) -> Option<Self::Cache> {
        self.inner.prefill_begin()
    }

    fn prefill_chunked(&self, cache: &mut Self::Cache, toks: &[u8], pos: usize) -> Result<()> {
        self.inner.prefill_chunked(cache, toks, pos)
    }

    fn prefill_into(&self, host: &std::sync::Arc<Vec<f32>>) -> Result<Self::Cache> {
        self.inner.prefill_into(host)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::cpu_ref::CpuModel;

    #[test]
    fn prefill_memoized_and_exact() {
        let m = PrefillCached::new(CpuModel::synthetic(2, 16, 2, 32, 3));
        let ctx = vec![1u8, 5, 9, 13];
        let a = m.prefill(&ctx).unwrap();
        let b = m.prefill(&ctx).unwrap();
        assert_eq!(*m.hits.borrow(), 1);
        assert_eq!(*m.misses.borrow(), 1);
        assert_eq!(a.data, b.data, "memoized prefill must be bit-identical");
        // different context misses
        let _ = m.prefill(&[1u8, 5]).unwrap();
        assert_eq!(*m.misses.borrow(), 2);
    }

    #[test]
    fn memo_is_bounded_with_fifo_eviction() {
        let m = PrefillCached::with_capacity(CpuModel::synthetic(2, 16, 2, 32, 3), 2);
        let a = vec![1u8, 5];
        let b = vec![1u8, 9];
        let c = vec![1u8, 13];
        m.prefill(&a).unwrap();
        m.prefill(&b).unwrap();
        // re-prefill `a` — a hit, but FIFO order is insertion, not use
        m.prefill(&a).unwrap();
        assert_eq!(*m.evictions.borrow(), 0);
        // third distinct context evicts the oldest-inserted (`a`)
        m.prefill(&c).unwrap();
        assert_eq!(*m.evictions.borrow(), 1);
        assert_eq!(*m.hits.borrow(), 1);
        m.prefill(&a).unwrap(); // miss again: was evicted
        assert_eq!(*m.misses.borrow(), 4);
        m.prefill(&c).unwrap(); // still resident
        assert_eq!(*m.hits.borrow(), 2);
    }

    #[test]
    fn zero_capacity_disables_memoization() {
        let m = PrefillCached::with_capacity(CpuModel::synthetic(2, 16, 2, 32, 3), 0);
        let ctx = vec![1u8, 5, 9];
        let a = m.prefill(&ctx).unwrap();
        let b = m.prefill(&ctx).unwrap();
        assert_eq!(*m.hits.borrow(), 0);
        assert_eq!(*m.misses.borrow(), 2);
        assert_eq!(a.data, b.data, "uncached prefills still agree bitwise");
    }

    #[test]
    fn decode_through_adapter_matches_plain() {
        use crate::decode::{speculative_generate, GenConfig};
        let d_plain = CpuModel::synthetic(2, 16, 2, 48, 7);
        let t_plain = CpuModel::synthetic(2, 16, 2, 48, 8);
        let d_cached = PrefillCached::new(CpuModel::synthetic(2, 16, 2, 48, 7));
        let t_cached = PrefillCached::new(CpuModel::synthetic(2, 16, 2, 48, 8));
        let cfg = GenConfig { max_len: 40, seed: 5, c: 2, ..Default::default() };
        let a = speculative_generate(&d_plain, &t_plain, None, &[1, 5, 9], &cfg).unwrap();
        let b = speculative_generate(&d_cached, &t_cached, None, &[1, 5, 9], &cfg).unwrap();
        let c = speculative_generate(&d_cached, &t_cached, None, &[1, 5, 9], &cfg).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(b.tokens, c.tokens, "second run hits the memo and must agree");
    }
}
