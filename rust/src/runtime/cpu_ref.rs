//! Pure-Rust reference implementation of the exported transformer.
//!
//! Same architecture, same parameters (read from params_<m>.bin via the
//! manifest tensor directory), same position/caching convention as the HLO
//! programs — integration tests assert the two backends agree to float
//! tolerance, which validates the whole AOT path end to end. Also usable
//! as a fallback engine (`--cpu-ref`) when artifacts exist but PJRT is
//! unavailable, and by unit tests that need a backend without artifacts
//! (see `CpuModel::synthetic`).

use anyhow::Result;

use super::backend::{DraftBlock, ModelBackend, VerifyBlock};
use crate::params::{ModelDims, ModelParams};
use crate::sampling;
use crate::util::rng::Pcg64;

/// One transformer block's weights.
struct Layer {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

pub struct CpuModel {
    pub name: String,
    pub dims: ModelDims,
    vocab: usize,
    tok_emb: Vec<f32>, // [V, D]
    pos_emb: Vec<f32>, // [S, D]
    layers: Vec<Layer>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
}

/// KV cache: flat [L, 2, H, S, Dh], identical layout to the HLO programs.
pub struct CpuCache {
    pub data: Vec<f32>,
}

fn ln(x: &mut [f32], g: &[f32], b: &[f32]) {
    let d = x.len();
    let mu: f32 = x.iter().sum::<f32>() / d as f32;
    let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..d {
        x[i] = (x[i] - mu) * inv * g[i] + b[i];
    }
}

/// tanh-approximated GELU (matches jax.nn.gelu's default approximate=True).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

/// y[j] += Σ_i x[i] * w[i*cols + j]  (row-major [rows, cols])
fn matvec_acc(x: &[f32], w: &[f32], y: &mut [f32]) {
    let cols = y.len();
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * cols..(i + 1) * cols];
        for j in 0..cols {
            y[j] += xi * row[j];
        }
    }
}

fn matvec(x: &[f32], w: &[f32], cols: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; cols];
    matvec_acc(x, w, &mut y);
    y
}

impl CpuModel {
    pub fn from_params(mp: &ModelParams, vocab: usize) -> Result<CpuModel> {
        let t = |name: &str| -> Result<Vec<f32>> { Ok(mp.tensor(name)?.0.to_vec()) };
        let mut layers = Vec::new();
        for l in 0..mp.dims.n_layer {
            let p = |s: &str| format!("l{l}.{s}");
            layers.push(Layer {
                ln1_g: t(&p("ln1_g"))?,
                ln1_b: t(&p("ln1_b"))?,
                wq: t(&p("wq"))?,
                wk: t(&p("wk"))?,
                wv: t(&p("wv"))?,
                wo: t(&p("wo"))?,
                ln2_g: t(&p("ln2_g"))?,
                ln2_b: t(&p("ln2_b"))?,
                w1: t(&p("w1"))?,
                b1: t(&p("b1"))?,
                w2: t(&p("w2"))?,
                b2: t(&p("b2"))?,
            });
        }
        Ok(CpuModel {
            name: mp.name.clone(),
            dims: mp.dims.clone(),
            vocab,
            tok_emb: t("tok_emb")?,
            pos_emb: t("pos_emb")?,
            layers,
            lnf_g: t("lnf_g")?,
            lnf_b: t("lnf_b")?,
        })
    }

    /// Randomly-initialized model for tests that need a backend without
    /// artifacts (deterministic in `seed`).
    pub fn synthetic(n_layer: usize, d_model: usize, n_head: usize, maxlen: usize, seed: u64) -> CpuModel {
        let vocab = crate::tokenizer::VOCAB;
        let d_ff = d_model * 4;
        let mut rng = Pcg64::new(seed);
        let mut w = |n: usize, scale: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.gaussian() * scale) as f32).collect()
        };
        let layers = (0..n_layer)
            .map(|_| Layer {
                ln1_g: vec![1.0; d_model],
                ln1_b: vec![0.0; d_model],
                wq: w(d_model * d_model, 0.05),
                wk: w(d_model * d_model, 0.05),
                wv: w(d_model * d_model, 0.05),
                wo: w(d_model * d_model, 0.05),
                ln2_g: vec![1.0; d_model],
                ln2_b: vec![0.0; d_model],
                w1: w(d_model * d_ff, 0.05),
                b1: vec![0.0; d_ff],
                w2: w(d_ff * d_model, 0.05),
                b2: vec![0.0; d_model],
            })
            .collect();
        CpuModel {
            name: "synthetic".into(),
            dims: ModelDims {
                n_layer,
                d_model,
                n_head,
                d_ff,
                n_params: 0,
                cache_shape: [n_layer, 2, n_head, maxlen, d_model / n_head],
            },
            vocab,
            tok_emb: w(vocab * d_model, 0.3),
            pos_emb: w(maxlen * d_model, 0.05),
            layers,
            lnf_g: vec![1.0; d_model],
            lnf_b: vec![0.0; d_model],
        }
    }

    pub fn empty_cache(&self) -> CpuCache {
        CpuCache { data: vec![0.0; self.dims.cache_len()] }
    }

    #[inline]
    fn cache_idx(&self, l: usize, kv: usize, h: usize, s: usize) -> usize {
        let [_, _, nh, sm, dh] = self.dims.cache_shape;
        (((l * 2 + kv) * nh + h) * sm + s) * dh
    }

    /// Teacher-forced forward of `toks` at absolute positions
    /// `pos..pos+toks.len()`, reading/writing the KV cache. Returns the
    /// final hidden state per input position [G][D].
    fn cached_forward(&self, cache: &mut CpuCache, toks: &[u8], pos: usize) -> Vec<Vec<f32>> {
        assert!(
            pos + toks.len() <= self.dims.maxlen(),
            "cached_forward past maxlen: pos {pos} + {} > {} (engines must \
             leave a full block of slack — see decode::spec)",
            toks.len(),
            self.dims.maxlen()
        );
        let d = self.dims.d_model;
        let nh = self.dims.n_head;
        let dh = self.dims.d_head();
        let g = toks.len();
        let scale = 1.0 / (dh as f32).sqrt();

        // embed
        let mut xs: Vec<Vec<f32>> = toks
            .iter()
            .enumerate()
            .map(|(i, &t)| {
                let te = &self.tok_emb[t as usize * d..(t as usize + 1) * d];
                let pe = &self.pos_emb[(pos + i) * d..(pos + i + 1) * d];
                te.iter().zip(pe).map(|(a, b)| a + b).collect()
            })
            .collect();

        for (l, lay) in self.layers.iter().enumerate() {
            // pre-LN + qkv for all G positions, write K/V into the cache
            let mut qs: Vec<Vec<f32>> = Vec::with_capacity(g);
            for (i, x) in xs.iter().enumerate() {
                let mut h = x.clone();
                ln(&mut h, &lay.ln1_g, &lay.ln1_b);
                let q = matvec(&h, &lay.wq, d);
                let k = matvec(&h, &lay.wk, d);
                let v = matvec(&h, &lay.wv, d);
                for hh in 0..nh {
                    let kslot = self.cache_idx(l, 0, hh, pos + i);
                    let vslot = self.cache_idx(l, 1, hh, pos + i);
                    cache.data[kslot..kslot + dh].copy_from_slice(&k[hh * dh..(hh + 1) * dh]);
                    cache.data[vslot..vslot + dh].copy_from_slice(&v[hh * dh..(hh + 1) * dh]);
                }
                qs.push(q);
            }
            // attention per position over cache slots <= qpos
            for (i, x) in xs.iter_mut().enumerate() {
                let qpos = pos + i;
                let mut att_out = vec![0.0f32; d];
                for hh in 0..nh {
                    let qh = &qs[i][hh * dh..(hh + 1) * dh];
                    // scores over 0..=qpos
                    let mut scores = Vec::with_capacity(qpos + 1);
                    let mut max = f32::NEG_INFINITY;
                    for s in 0..=qpos {
                        let kslot = self.cache_idx(l, 0, hh, s);
                        let kv = &cache.data[kslot..kslot + dh];
                        let dot: f32 = qh.iter().zip(kv).map(|(a, b)| a * b).sum();
                        let sc = dot * scale;
                        max = max.max(sc);
                        scores.push(sc);
                    }
                    let mut z = 0.0f32;
                    for sc in scores.iter_mut() {
                        *sc = (*sc - max).exp();
                        z += *sc;
                    }
                    let out = &mut att_out[hh * dh..(hh + 1) * dh];
                    for (s, &w) in scores.iter().enumerate() {
                        let vslot = self.cache_idx(l, 1, hh, s);
                        let vv = &cache.data[vslot..vslot + dh];
                        let wz = w / z;
                        for j in 0..dh {
                            out[j] += wz * vv[j];
                        }
                    }
                }
                // out projection + residual
                let proj = matvec(&att_out, &lay.wo, d);
                for j in 0..d {
                    x[j] += proj[j];
                }
                // MLP
                let mut h = x.clone();
                ln(&mut h, &lay.ln2_g, &lay.ln2_b);
                let mut ff = matvec(&h, &lay.w1, self.dims.d_ff);
                for (j, f) in ff.iter_mut().enumerate() {
                    *f = gelu(*f + lay.b1[j]);
                }
                let mut out2 = matvec(&ff, &lay.w2, d);
                for j in 0..d {
                    out2[j] += lay.b2[j];
                    x[j] += out2[j];
                }
            }
        }
        // final LN
        for x in xs.iter_mut() {
            ln(x, &self.lnf_g, &self.lnf_b);
        }
        xs
    }

    /// Logits from a final hidden state (weight-tied head).
    fn logits(&self, h: &[f32]) -> Vec<f32> {
        let d = self.dims.d_model;
        (0..self.vocab)
            .map(|t| {
                let te = &self.tok_emb[t * d..(t + 1) * d];
                h.iter().zip(te).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    /// Full-sequence forward from scratch: per-position logits.
    pub fn forward_logits(&self, tokens: &[u8]) -> Vec<Vec<f32>> {
        let mut cache = self.empty_cache();
        let hidden = self.cached_forward(&mut cache, tokens, 0);
        hidden.iter().map(|h| self.logits(h)).collect()
    }
}

impl ModelBackend for CpuModel {
    type Cache = CpuCache;

    fn maxlen(&self) -> usize {
        self.dims.maxlen()
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn supported_c(&self) -> Vec<usize> {
        (1..=8).collect()
    }
    fn supported_gamma(&self) -> Vec<usize> {
        (1..=16).collect()
    }

    fn prefill(&self, tokens: &[u8]) -> Result<CpuCache> {
        let mut cache = self.empty_cache();
        if tokens.len() > 1 {
            self.cached_forward(&mut cache, &tokens[..tokens.len() - 1], 0);
        }
        Ok(cache)
    }

    fn generate(
        &self,
        cache: &mut CpuCache,
        feed: &[u8],
        pos: usize,
        c: usize,
        gamma: usize,
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> Result<DraftBlock> {
        let hidden = self.cached_forward(cache, feed, pos);
        let last_logits = self.logits(hidden.last().unwrap());
        let start = pos + feed.len();

        let mut tokens = vec![vec![0u8; gamma]; c];
        let mut dists = vec![Vec::with_capacity(gamma); c];
        for ci in 0..c {
            // each candidate branches from the committed cache
            let mut cc = CpuCache { data: cache.data.clone() };
            let mut logits = last_logits.clone();
            for gi in 0..gamma {
                let dist = sampling::adjust_dist(&logits, temp, top_p);
                let tok = sampling::sample(&dist, u[ci * gamma + gi]) as u8;
                tokens[ci][gi] = tok;
                dists[ci].push(dist);
                let h = self.cached_forward(&mut cc, &[tok], start + gi);
                logits = self.logits(&h[0]);
            }
        }
        Ok(DraftBlock { tokens, dists })
    }

    fn verify(
        &self,
        cache: &mut CpuCache,
        toks: &[u8],
        pos: usize,
        temp: f32,
        top_p: f32,
    ) -> Result<VerifyBlock> {
        let hidden = self.cached_forward(cache, toks, pos);
        let dists = hidden
            .iter()
            .map(|h| sampling::adjust_dist(&self.logits(h), temp, top_p))
            .collect();
        Ok(VerifyBlock { dists })
    }

    fn score(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        let logits = self.forward_logits(tokens);
        let mut nll = vec![0.0f32; tokens.len()];
        for i in 1..tokens.len() {
            let p = sampling::softmax(&logits[i - 1], 1.0);
            nll[i] = -(p[tokens[i] as usize].max(1e-12)).ln();
        }
        Ok(nll)
    }

    fn cache_to_host(&self, cache: &CpuCache) -> Result<Vec<f32>> {
        Ok(cache.data.clone())
    }

    fn cache_from_host(&self, data: &[f32]) -> Result<CpuCache> {
        Ok(CpuCache { data: data.to_vec() })
    }

    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        let mut cache = self.empty_cache();
        let hidden = self.cached_forward(&mut cache, tokens, 0);
        let d = self.dims.d_model;
        let mut out = vec![0.0f32; d];
        for h in &hidden {
            for j in 0..d {
                out[j] += h[j];
            }
        }
        let n = hidden.len().max(1) as f32;
        out.iter_mut().for_each(|x| *x /= n);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CpuModel {
        CpuModel::synthetic(2, 16, 2, 32, 42)
    }

    #[test]
    fn cached_equals_fresh_forward() {
        let m = tiny();
        let seq: Vec<u8> = vec![1, 5, 9, 13, 7, 4, 20];
        let full = m.forward_logits(&seq);
        // incremental: prefill 4 (feeds 3), then feed the rest one by one
        let mut cache = m.prefill(&seq[..4]).unwrap();
        let mut got = Vec::new();
        for i in 3..seq.len() {
            let h = m.cached_forward(&mut cache, &seq[i..i + 1], i);
            got.push(m.logits(&h[0]));
        }
        for (i, g) in got.iter().enumerate() {
            let f = &full[3 + i];
            for (a, b) in g.iter().zip(f) {
                assert!((a - b).abs() < 1e-4, "pos {} mismatch {a} vs {b}", 3 + i);
            }
        }
    }

    #[test]
    fn verify_dists_are_normalized() {
        let m = tiny();
        let mut cache = m.prefill(&[1, 5, 9]).unwrap();
        let vb = m.verify(&mut cache, &[9, 4, 6, 8], 2, 1.0, 0.95).unwrap();
        assert_eq!(vb.dists.len(), 4);
        for d in &vb.dists {
            let s: f32 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn generate_respects_c_and_gamma() {
        let m = tiny();
        let mut cache = m.prefill(&[1, 5, 9]).unwrap();
        let u: Vec<f32> = (0..3 * 4).map(|i| (i as f32 * 0.37) % 1.0).collect();
        let db = m.generate(&mut cache, &[9], 2, 3, 4, &u, 1.0, 0.95).unwrap();
        assert_eq!(db.tokens.len(), 3);
        assert_eq!(db.tokens[0].len(), 4);
        assert_eq!(db.dists[0].len(), 4);
        // sampled token must have nonzero prob in its dist
        for ci in 0..3 {
            for gi in 0..4 {
                assert!(db.dists[ci][gi][db.tokens[ci][gi] as usize] > 0.0);
            }
        }
    }

    #[test]
    fn same_uniforms_same_candidates() {
        let m = tiny();
        let mut c1 = m.prefill(&[1, 5, 9]).unwrap();
        let mut c2 = m.prefill(&[1, 5, 9]).unwrap();
        let u: Vec<f32> = (0..10).map(|i| (i as f32 * 0.13) % 1.0).collect();
        let a = m.generate(&mut c1, &[9], 2, 2, 5, &u, 0.8, 0.9).unwrap();
        let b = m.generate(&mut c2, &[9], 2, 2, 5, &u, 0.8, 0.9).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn score_zero_at_origin_positive_after() {
        let m = tiny();
        let nll = m.score(&[1, 5, 9, 13]).unwrap();
        assert_eq!(nll[0], 0.0);
        assert!(nll[1..].iter().all(|&x| x > 0.0));
    }

    #[test]
    fn embed_shape() {
        let m = tiny();
        let e = m.embed(&[1, 5, 9]).unwrap();
        assert_eq!(e.len(), 16);
    }

    #[test]
    fn verify_then_reverify_overlapping_positions() {
        // stale-slot rewrite: verify 5 tokens, then re-verify from an
        // earlier position; dists must match a fresh forward.
        let m = tiny();
        let seq: Vec<u8> = vec![1, 5, 9, 13, 7, 4, 20, 11, 2, 6];
        let mut cache = m.prefill(&seq[..4]).unwrap();
        let _ = m.verify(&mut cache, &seq[3..9], 3, 1.0, 1.0).unwrap();
        // pretend only 2 of those were accepted: re-verify from pos 5
        let vb = m.verify(&mut cache, &seq[5..10], 5, 1.0, 1.0).unwrap();
        let full = m.forward_logits(&seq);
        for (i, d) in vb.dists.iter().enumerate() {
            let expect = sampling::adjust_dist(&full[5 + i], 1.0, 1.0);
            for (a, b) in d.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-4, "pos {} {a} vs {b}", 5 + i);
            }
        }
    }
}
