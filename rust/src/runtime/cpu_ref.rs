//! Pure-Rust reference implementation of the exported transformer.
//!
//! Same architecture, same parameters (read from params_<m>.bin via the
//! manifest tensor directory), same position/caching convention as the HLO
//! programs — integration tests assert the two backends agree to float
//! tolerance, which validates the whole AOT path end to end. Also usable
//! as a fallback engine (`--cpu-ref`) when artifacts exist but PJRT is
//! unavailable, and by unit tests that need a backend without artifacts
//! (see `CpuModel::synthetic`).
//!
//! # Batched hot path
//!
//! The forward is batched two ways (see `runtime` module docs for the full
//! conventions):
//!
//!   * **Teacher-forced blocks** (`prefill`/`verify`/feed phase of
//!     `generate`): all `G` positions go through each projection and the
//!     logits head as one `[G,D]×[D,N]` call into [`super::gemm`].
//!   * **Candidate drafting** (`generate`): a [`BranchedCache`] shares the
//!     committed prefix read-only across the `c` candidates and gives each
//!     one a γ-slot scratch tail, so a draft round performs γ−1 batched
//!     `[c,D]` steps — no full KV-cache clone, no per-step heap churn.
//!   * **Cross-sequence lockstep** (`generate_batch`/`verify_batch`): B
//!     sequences with ragged committed prefixes run one decode round
//!     together — a ragged `[ΣG_b, D]` feed, γ−1 arena steps of `[B·c, D]`
//!     rows (a `BranchedArena`: per-sequence cache slots + per-candidate
//!     tails), and a `[Σ(γ+1), D]` verify — with per-row results bitwise
//!     equal to B solo dispatches, so lockstep serving is lossless.
//!   * **Candidate trees** (`draft_tree`/`verify_tree`): a [`TreeTails`]
//!     arena stores one KV row per *node* of a shared-prefix candidate
//!     forest (parent-pointer table, DFS path order). Drafting feeds one
//!     `[frontier, D]` step per depth level; verification teacher-forces
//!     every node in a single `[N, D]` dispatch where each row's attention
//!     gathers exactly its root-to-self ancestor rows next to the committed
//!     prefix — the ancestor-visible tree mask, realized as a K/V gather
//!     instead of a dense mask. Chain-shaped forests (`branch == 1`) walk
//!     the same node ids as flat candidate blocks (`ci·γ + gi`) and are
//!     bitwise-identical to `generate`/`verify`, which the unit tests pin.
//!
//! The GEMM kernels (runtime-dispatched SIMD, see the `runtime` and
//! [`super::simd`] module docs) accumulate bitwise-identically to the
//! scalar mat-vec path, so the batched forward is *exactly* equal to the
//! seed per-position implementation, which is preserved under [`reference`]
//! as the equivalence oracle and bench baseline. The weight-tied logits
//! head runs against a [`PackedWeights`] panel — the tied embedding
//! transposed once at model load — so it shares the column-vectorized
//! kernels instead of doing per-vocab-entry transposed dot products.
//!
//! All round-lifetime workspaces (the arena/branch tails and the
//! teacher-forced forward buffers) are drawn from a per-model [`BufPool`]
//! rather than allocated per round: each worker owns its engine, so the
//! pool is effectively per-worker, and continuous-batching decode rounds
//! recycle one another's buffers. Pooled buffers are re-zeroed on handout,
//! keeping every round bitwise-identical to a fresh-allocation run.

use std::sync::Mutex;

use anyhow::Result;

use super::backend::{
    DraftBlock, DraftSeq, DraftTreeBlock, ModelBackend, TokenTree, VerifyBlock, VerifySeq,
    VerifyTreeBlock,
};
use super::{gemm, simd};
use crate::params::{ModelDims, ModelParams, PackedWeights, Panel, WeightDtype};
use crate::sampling;
use crate::util::rng::Pcg64;

/// Reusable workspace set for one forward / draft round. The hot-path entry
/// points draw one of these from the owning model's [`BufPool`] instead of
/// allocating: under continuous batching a worker issues one arena plus one
/// ragged teacher-forced workspace per decode round, and at high request
/// rates those per-round allocations dominated allocator traffic.
#[derive(Default)]
struct RoundBufs {
    tail: Vec<f32>,
    xs: Vec<f32>,
    hbuf: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    proj: Vec<f32>,
    ff: Vec<f32>,
    scores: Vec<f32>,
}

/// Per-model buffer pool. Engines are built inside their worker thread, so
/// this doubles as the ROADMAP's per-*worker* arena pool: buffers grown for
/// one round are handed to the next round instead of going back to the
/// allocator. The mutex is uncontended on the serving path (one worker
/// thread drives a model); it only exists to keep `CpuModel: Sync`.
#[derive(Default)]
struct BufPool {
    bufs: Mutex<Vec<RoundBufs>>,
}

impl BufPool {
    fn take(&self) -> RoundBufs {
        self.bufs.lock().unwrap().pop().unwrap_or_default()
    }

    fn put(&self, bufs: RoundBufs) {
        let mut pool = self.bufs.lock().unwrap();
        // a forward holds at most a few sets at once; keep the pool bounded
        if pool.len() < 8 {
            pool.push(bufs);
        }
    }
}

/// Size a pooled buffer: zeroed `len` floats reusing capacity. `clear` +
/// `resize` zero-fills everything, so a pooled round is bitwise identical
/// to one running on fresh `vec![0.0; len]` allocations.
fn grab(v: &mut Vec<f32>, len: usize) {
    v.clear();
    v.resize(len, 0.0);
}

/// One transformer block's weights. The projection/MLP matrices are stored
/// as dtype-tagged [`Panel`]s (quantized once at load when a narrow
/// [`WeightDtype`] is selected); layernorm params and biases stay f32 —
/// they are O(D) per layer and contribute nothing to weight traffic.
struct Layer {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: Panel,
    wk: Panel,
    wv: Panel,
    wo: Panel,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: Panel,
    b1: Vec<f32>,
    w2: Panel,
    b2: Vec<f32>,
}

pub struct CpuModel {
    pub name: String,
    pub dims: ModelDims,
    vocab: usize,
    tok_emb: Vec<f32>, // [V, D]
    pos_emb: Vec<f32>, // [S, D]
    layers: Vec<Layer>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    /// Tied embedding transposed once at load into a `[D, V]` panel so the
    /// logits head runs on the column-vectorized GEMM kernel instead of
    /// per-vocab-entry transposed dot products (see [`PackedWeights`]).
    packed: PackedWeights,
    /// Weight storage dtype shared by the layer panels and the logits head
    /// (resolved once at construction; see [`simd::weight_dtype`]).
    dtype: WeightDtype,
    /// Opt-in fast dispatch tier: FMA micro-kernels plus polynomial
    /// exp/tanh in softmax/GELU. Off the bitwise contract (see
    /// [`simd::fast_tier`]); the [`reference`] oracle never uses it.
    fast: bool,
    /// Round-workspace pool (see [`BufPool`]).
    pool: BufPool,
}

/// Copy-on-write float buffer backing [`CpuCache`].
///
/// A cache attached from a `runtime::prefix_store` snapshot *shares* the
/// snapshot (`Arc`) until the first mutable access; reads go through
/// `Deref` with zero copies, and the first `DerefMut` detaches by cloning
/// the shared floats into owned storage. Owned buffers (the cold-path
/// default) pay only an `Option` check. Deliberately **not** `Clone`:
/// `buf.clone()` method-resolves through `Deref` to `Vec<f32>::clone`, so
/// existing `cache.data.clone()` call sites keep yielding host floats.
pub struct CowBuf {
    shared: Option<std::sync::Arc<Vec<f32>>>,
    owned: Vec<f32>,
}

impl CowBuf {
    fn owned(v: Vec<f32>) -> CowBuf {
        CowBuf { shared: None, owned: v }
    }

    fn attached(a: std::sync::Arc<Vec<f32>>) -> CowBuf {
        CowBuf { shared: Some(a), owned: Vec::new() }
    }

    /// Still sharing the attached snapshot (no write has detached it)?
    pub fn is_shared(&self) -> bool {
        self.shared.is_some()
    }
}

impl std::ops::Deref for CowBuf {
    type Target = Vec<f32>;
    fn deref(&self) -> &Vec<f32> {
        match &self.shared {
            Some(a) => a,
            None => &self.owned,
        }
    }
}

impl std::ops::DerefMut for CowBuf {
    fn deref_mut(&mut self) -> &mut Vec<f32> {
        if let Some(a) = self.shared.take() {
            // detach: first write after attach copies the snapshot
            self.owned = a.as_ref().clone();
        }
        &mut self.owned
    }
}

impl PartialEq for CowBuf {
    fn eq(&self, o: &CowBuf) -> bool {
        **self == **o
    }
}

impl std::fmt::Debug for CowBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CowBuf")
            .field("shared", &self.shared.is_some())
            .field("len", &self.len())
            .finish()
    }
}

impl<'a> IntoIterator for &'a CowBuf {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        (**self).iter()
    }
}

/// KV cache: flat [L, 2, H, S, Dh], identical layout to the HLO programs.
/// The buffer is copy-on-write so a prefix-store hit can attach a shared
/// committed prefix without copying it (see [`CowBuf`]).
pub struct CpuCache {
    pub data: CowBuf,
}

impl CpuCache {
    pub fn owned(data: Vec<f32>) -> CpuCache {
        CpuCache { data: CowBuf::owned(data) }
    }

    pub fn attached(snapshot: std::sync::Arc<Vec<f32>>) -> CpuCache {
        CpuCache { data: CowBuf::attached(snapshot) }
    }
}

/// Branched KV state for one batched draft round: every candidate reads the
/// committed prefix from `base` (shared, never copied) and owns a γ-slot
/// scratch tail per layer/head. Tail layout: flat [L, 2, C, H, γ, Dh], so a
/// candidate's per-head slot run is contiguous exactly like the base cache.
/// Also carries the round's forward workspaces so the per-step loop does no
/// heap allocation.
pub struct BranchedCache<'a> {
    base: &'a CpuCache,
    /// Committed positions `0..base_len` are visible to every candidate;
    /// tail slot `s` holds the KV of absolute position `base_len + s`.
    base_len: usize,
    c: usize,
    gamma: usize,
    tail: Vec<f32>,
    // round-lifetime workspaces, all [c, d_model] except `ff` ([c, d_ff])
    xs: Vec<f32>,
    hbuf: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    proj: Vec<f32>,
    ff: Vec<f32>,
    scores: Vec<f32>,
}

/// Sequence-slot arena for one *lockstep* draft round over B sequences:
/// the multi-sequence generalization of [`BranchedCache`]. Every sequence
/// keeps its committed prefix in its own (read-only) cache slot — prefixes
/// may have different lengths — and each of its `c` candidates owns a
/// γ-slot scratch tail. Tails are flat `[B, L, 2, C, H, γ, Dh]` (a
/// sequence's sub-block uses the exact [`BranchedCache`] layout), and the
/// round workspaces span the union of candidate rows `[B·c, D]`, so one
/// arena step runs every projection/MLP/logits GEMM over all sequences at
/// once while attention stays per-row against the owning sequence's cache.
struct BranchedArena<'a> {
    /// Per-sequence (committed cache, committed length). Tail slot `s` of
    /// sequence `b` holds the KV of absolute position `bases[b].1 + s`.
    bases: Vec<(&'a CpuCache, usize)>,
    c: usize,
    gamma: usize,
    /// Tail floats per sequence ( = L * 2 * c * H * γ * Dh).
    seq_stride: usize,
    tail: Vec<f32>,
    // round-lifetime workspaces, all [B·c, d_model] except `ff` ([B·c, d_ff])
    xs: Vec<f32>,
    hbuf: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    proj: Vec<f32>,
    ff: Vec<f32>,
    scores: Vec<f32>,
}

impl<'a> BranchedArena<'a> {
    /// Build the round arena on pooled buffers (`bufs` is resized and
    /// zeroed to this round's B·c rows, reusing capacity left by earlier
    /// rounds — sequences admitted mid-flight land in whatever slot space
    /// retired sequences freed).
    fn new(
        m: &CpuModel,
        bases: Vec<(&'a CpuCache, usize)>,
        c: usize,
        gamma: usize,
        mut bufs: RoundBufs,
    ) -> Self {
        let d = m.dims.d_model;
        let d_ff = m.dims.d_ff;
        let nh = m.dims.n_head;
        let dh = m.dims.d_head();
        let b = bases.len();
        let rows = b * c;
        let seq_stride = m.dims.n_layer * 2 * c * nh * gamma * dh;
        grab(&mut bufs.tail, b * seq_stride);
        grab(&mut bufs.xs, rows * d);
        grab(&mut bufs.hbuf, rows * d);
        grab(&mut bufs.q, rows * d);
        grab(&mut bufs.k, rows * d);
        grab(&mut bufs.v, rows * d);
        grab(&mut bufs.att, rows * d);
        grab(&mut bufs.proj, rows * d);
        grab(&mut bufs.ff, rows * d_ff);
        bufs.scores.clear();
        BranchedArena {
            bases,
            c,
            gamma,
            seq_stride,
            tail: bufs.tail,
            xs: bufs.xs,
            hbuf: bufs.hbuf,
            q: bufs.q,
            k: bufs.k,
            v: bufs.v,
            att: bufs.att,
            proj: bufs.proj,
            ff: bufs.ff,
            scores: bufs.scores,
        }
    }

    /// Release the arena, returning its buffers for pooling.
    fn into_bufs(self) -> RoundBufs {
        RoundBufs {
            tail: self.tail,
            xs: self.xs,
            hbuf: self.hbuf,
            q: self.q,
            k: self.k,
            v: self.v,
            att: self.att,
            proj: self.proj,
            ff: self.ff,
            scores: self.scores,
        }
    }

    /// Start offset of the contiguous slot run for
    /// (sequence, layer, k/v, cand, head).
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn tail_base(
        &self,
        nh: usize,
        dh: usize,
        b: usize,
        l: usize,
        kv: usize,
        ci: usize,
        hh: usize,
    ) -> usize {
        b * self.seq_stride + ((((l * 2 + kv) * self.c + ci) * nh + hh) * self.gamma) * dh
    }

    /// Check the arena's sizing and KV-row-accounting invariants against the
    /// owning model's dimensions. Always compiled — the seeded-corruption
    /// tests call it directly — while the hot-path call site is
    /// `cfg!(debug_assertions)` + `SPECMER_VALIDATE`-gated (see
    /// [`validate_on`]). The error message names the broken invariant.
    fn debug_validate(&self, dims: &ModelDims) -> Result<(), String> {
        let nh = dims.n_head;
        let dh = dims.d_head();
        let want = dims.n_layer * 2 * self.c * nh * self.gamma * dh;
        if self.seq_stride != want {
            return Err(format!(
                "BranchedArena seq_stride invariant broken: stride {} != L*2*c*H*gamma*Dh = {want}",
                self.seq_stride
            ));
        }
        if self.tail.len() != self.bases.len() * self.seq_stride {
            return Err(format!(
                "BranchedArena tail sizing invariant broken: {} floats for {} sequences x \
                 stride {}",
                self.tail.len(),
                self.bases.len(),
                self.seq_stride
            ));
        }
        for (b, &(cache, base_len)) in self.bases.iter().enumerate() {
            if cache.data.len() != dims.cache_len() {
                return Err(format!(
                    "BranchedArena KV row accounting invariant broken: seq {b} cache holds {} \
                     floats, dims say {}",
                    cache.data.len(),
                    dims.cache_len()
                ));
            }
            if base_len + self.gamma > dims.maxlen() {
                return Err(format!(
                    "BranchedArena KV row accounting invariant broken: seq {b} committed length \
                     {base_len} + gamma {} overruns maxlen {}",
                    self.gamma,
                    dims.maxlen()
                ));
            }
        }
        let rows = self.bases.len() * self.c;
        if self.xs.len() != rows * dims.d_model || self.ff.len() != rows * dims.d_ff {
            return Err(format!(
                "BranchedArena workspace sizing invariant broken: xs {} / ff {} for {rows} rows",
                self.xs.len(),
                self.ff.len()
            ));
        }
        Ok(())
    }
}

/// Whether the opt-in runtime validators should run at this call site:
/// compiled away in release builds, and gated on `SPECMER_VALIDATE=1` in
/// debug builds (see [`simd::validate_enabled`]).
#[inline]
fn validate_on() -> bool {
    cfg!(debug_assertions) && simd::validate_enabled()
}

impl<'a> BranchedCache<'a> {
    fn new(
        m: &CpuModel,
        base: &'a CpuCache,
        base_len: usize,
        c: usize,
        gamma: usize,
        mut bufs: RoundBufs,
    ) -> Self {
        let d = m.dims.d_model;
        let d_ff = m.dims.d_ff;
        let nh = m.dims.n_head;
        let dh = m.dims.d_head();
        grab(&mut bufs.tail, m.dims.n_layer * 2 * c * nh * gamma * dh);
        grab(&mut bufs.xs, c * d);
        grab(&mut bufs.hbuf, c * d);
        grab(&mut bufs.q, c * d);
        grab(&mut bufs.k, c * d);
        grab(&mut bufs.v, c * d);
        grab(&mut bufs.att, c * d);
        grab(&mut bufs.proj, c * d);
        grab(&mut bufs.ff, c * d_ff);
        bufs.scores.clear();
        BranchedCache {
            base,
            base_len,
            c,
            gamma,
            tail: bufs.tail,
            xs: bufs.xs,
            hbuf: bufs.hbuf,
            q: bufs.q,
            k: bufs.k,
            v: bufs.v,
            att: bufs.att,
            proj: bufs.proj,
            ff: bufs.ff,
            scores: bufs.scores,
        }
    }

    /// Release the branch state, returning its buffers for pooling.
    fn into_bufs(self) -> RoundBufs {
        RoundBufs {
            tail: self.tail,
            xs: self.xs,
            hbuf: self.hbuf,
            q: self.q,
            k: self.k,
            v: self.v,
            att: self.att,
            proj: self.proj,
            ff: self.ff,
            scores: self.scores,
        }
    }

    /// Start offset of the contiguous slot run for (layer, k/v, cand, head).
    #[inline]
    fn tail_base(&self, nh: usize, dh: usize, l: usize, kv: usize, ci: usize, hh: usize) -> usize {
        ((((l * 2 + kv) * self.c + ci) * nh + hh) * self.gamma) * dh
    }
}

/// Parent-pointer node table for one candidate-*tree* round: the tree
/// generalization of [`BranchedCache`]'s per-candidate tails. Every tree
/// node owns exactly one scratch KV row (tail layout flat `[L, 2, N, H, Dh]`,
/// slot = node id), so a prefix shared by several root-to-leaf candidate
/// blocks is computed and cached exactly once instead of once per chain.
/// Node `q` sits at absolute position `base_len + depth[q]`, and its
/// attention row sees the committed prefix (read-only from `base`) plus its
/// root-to-self ancestor rows — the tree's ancestor-visibility mask,
/// realized by gathering the (non-contiguous) ancestor K/V rows per head
/// into a contiguous scratch run feeding the same two-segment
/// [`attend_one`] the chain tails use. The gather only *copies* rows, so
/// score and accumulation order match a chain tail position-for-position —
/// which is what keeps degenerate (chain-shaped) trees bitwise-equal to
/// [`BranchedCache`] drafting.
pub struct TreeTails<'a> {
    base: &'a CpuCache,
    /// Committed positions `0..base_len` are visible to every node.
    base_len: usize,
    n: usize,
    /// Topologically-ordered parent table (kept for [`Self::debug_validate`]).
    parents: Vec<Option<usize>>,
    depths: Vec<usize>,
    /// Root-to-self node ids per node (the per-row gather list).
    anc: Vec<Vec<usize>>,
    tail: Vec<f32>,
    // round-lifetime workspaces sized to the widest dispatch ([N, D] rows)
    xs: Vec<f32>,
    hbuf: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    proj: Vec<f32>,
    ff: Vec<f32>,
    scores: Vec<f32>,
    // per-head ancestor K/V gather runs, [max_depth+1, Dh]
    gk: Vec<f32>,
    gv: Vec<f32>,
}

impl<'a> TreeTails<'a> {
    fn new(
        m: &CpuModel,
        base: &'a CpuCache,
        base_len: usize,
        parents: &[Option<usize>],
        mut bufs: RoundBufs,
    ) -> Self {
        let d = m.dims.d_model;
        let d_ff = m.dims.d_ff;
        let nh = m.dims.n_head;
        let dh = m.dims.d_head();
        let n = parents.len();
        let mut depths = vec![0usize; n];
        let mut anc: Vec<Vec<usize>> = Vec::with_capacity(n);
        for (i, p) in parents.iter().enumerate() {
            match *p {
                Some(p) => {
                    debug_assert!(p < i, "parents must precede children");
                    depths[i] = depths[p] + 1;
                    let mut chain = anc[p].clone();
                    chain.push(i);
                    anc.push(chain);
                }
                None => anc.push(vec![i]),
            }
        }
        let gamma = depths.iter().max().map_or(0, |&m| m + 1);
        grab(&mut bufs.tail, m.dims.n_layer * 2 * n * nh * dh);
        grab(&mut bufs.xs, n * d);
        grab(&mut bufs.hbuf, n * d);
        grab(&mut bufs.q, n * d);
        grab(&mut bufs.k, n * d);
        grab(&mut bufs.v, n * d);
        grab(&mut bufs.att, n * d);
        grab(&mut bufs.proj, n * d);
        grab(&mut bufs.ff, n * d_ff);
        bufs.scores.clear();
        TreeTails {
            base,
            base_len,
            n,
            parents: parents.to_vec(),
            depths,
            anc,
            tail: bufs.tail,
            xs: bufs.xs,
            hbuf: bufs.hbuf,
            q: bufs.q,
            k: bufs.k,
            v: bufs.v,
            att: bufs.att,
            proj: bufs.proj,
            ff: bufs.ff,
            scores: bufs.scores,
            gk: vec![0.0; gamma * dh],
            gv: vec![0.0; gamma * dh],
        }
    }

    /// Deepest level + 1 (the draft length the tree realizes).
    fn gamma(&self) -> usize {
        self.depths.iter().max().map_or(0, |&m| m + 1)
    }

    /// Release the node table, returning its pooled buffers.
    fn into_bufs(self) -> RoundBufs {
        RoundBufs {
            tail: self.tail,
            xs: self.xs,
            hbuf: self.hbuf,
            q: self.q,
            k: self.k,
            v: self.v,
            att: self.att,
            proj: self.proj,
            ff: self.ff,
            scores: self.scores,
        }
    }

    /// Start offset of node `node`'s KV row for (layer, k/v, head).
    #[inline]
    fn tail_base(&self, nh: usize, dh: usize, l: usize, kv: usize, node: usize, hh: usize) -> usize {
        (((l * 2 + kv) * self.n + node) * nh + hh) * dh
    }

    /// Check the node table's structural invariants — parent-pointer order
    /// (acyclicity), depth/ancestor-chain consistency, tail sizing, and KV
    /// row accounting against the base cache. Always compiled — the
    /// seeded-corruption tests call it directly — while hot-path call sites
    /// are gated behind [`validate_on`]. The error names the invariant.
    fn debug_validate(&self, dims: &ModelDims) -> Result<(), String> {
        let n = self.n;
        if self.parents.len() != n || self.depths.len() != n || self.anc.len() != n {
            return Err(format!(
                "TreeTails table sizing invariant broken: n {n} vs parents {} / depths {} / \
                 anc {}",
                self.parents.len(),
                self.depths.len(),
                self.anc.len()
            ));
        }
        for i in 0..self.n {
            match self.parents[i] {
                Some(p) => {
                    if p >= i {
                        return Err(format!(
                            "TreeTails parent-pointer order invariant broken (cycle risk): \
                             node {i} lists parent {p}, but parents must precede children"
                        ));
                    }
                    if self.depths[i] != self.depths[p] + 1 {
                        return Err(format!(
                            "TreeTails depth accounting invariant broken: node {i} at depth {} \
                             under parent {p} at depth {}",
                            self.depths[i], self.depths[p]
                        ));
                    }
                    let plen = self.anc[p].len();
                    if self.anc[i].len() != plen + 1
                        || self.anc[i][..plen] != self.anc[p][..]
                        || self.anc[i][plen] != i
                    {
                        return Err(format!(
                            "TreeTails ancestor-chain (DFS path order) invariant broken: \
                             node {i} chain {:?} does not extend parent {p} chain {:?}",
                            self.anc[i], self.anc[p]
                        ));
                    }
                }
                None => {
                    if self.depths[i] != 0 || self.anc[i] != [i] {
                        return Err(format!(
                            "TreeTails ancestor-chain (DFS path order) invariant broken: \
                             root node {i} has depth {} and chain {:?}",
                            self.depths[i], self.anc[i]
                        ));
                    }
                }
            }
        }
        let nh = dims.n_head;
        let dh = dims.d_head();
        if self.tail.len() != dims.n_layer * 2 * self.n * nh * dh {
            return Err(format!(
                "TreeTails tail sizing invariant broken: {} floats for {} nodes (want \
                 L*2*N*H*Dh = {})",
                self.tail.len(),
                self.n,
                dims.n_layer * 2 * self.n * nh * dh
            ));
        }
        if self.base.data.len() != dims.cache_len() {
            return Err(format!(
                "TreeTails KV row accounting invariant broken: base cache holds {} floats, \
                 dims say {}",
                self.base.data.len(),
                dims.cache_len()
            ));
        }
        if self.base_len + self.gamma() > dims.maxlen() {
            return Err(format!(
                "TreeTails KV row accounting invariant broken: committed length {} + tree \
                 depth {} overruns maxlen {}",
                self.base_len,
                self.gamma(),
                dims.maxlen()
            ));
        }
        Ok(())
    }
}

/// LayerNorm. The mean/variance reductions keep one serial accumulator in
/// index order (vector lanes would reassociate the sums and change bits);
/// the elementwise application runs on the SIMD lane helper.
fn ln(x: &mut [f32], g: &[f32], b: &[f32]) {
    let d = x.len();
    let mu: f32 = x.iter().sum::<f32>() / d as f32;
    let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
    let inv = 1.0 / (var + 1e-5).sqrt();
    simd::ln_apply(x, g, b, mu, inv);
}

/// tanh-approximated GELU (matches jax.nn.gelu's default approximate=True).
/// The exact arm is bitwise-identical to the seed implementation: same
/// expression, same operation order, libm `tanh`. The fast arm swaps in
/// [`simd::tanh_fast`] and is only reachable under `SPECMER_FAST=1`.
#[inline]
fn gelu_with(x: f32, fast: bool) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    let t = C * (x + 0.044_715 * x * x * x);
    let th = if fast { simd::tanh_fast(t) } else { t.tanh() };
    0.5 * x * (1.0 + th)
}

/// Exact-tier GELU, used by the [`reference`] oracle.
#[inline]
fn gelu(x: f32) -> f32 {
    gelu_with(x, false)
}

/// One query head's attention over two contiguous KV segments (committed
/// prefix + optional branch tail), accumulated into `out` (pre-zeroed).
/// Score order, running max, and the weighted-V accumulation all match the
/// scalar reference path operation-for-operation. The QK dots and the
/// softmax normalizer are single-accumulator reductions (and `exp` is a
/// libm call), so they stay scalar in index order; the weighted-V inner
/// loop has independent output slots per `dh` lane and rides
/// [`simd::axpy`]. With `fast` set the softmax exponentials run on the
/// polynomial [`simd::exp_fast`] instead of libm `exp` (accuracy-bounded,
/// not bitwise — see the fast-tier notes in the `runtime` module docs).
#[allow(clippy::too_many_arguments)]
fn attend_one(
    qh: &[f32],
    scale: f32,
    dh: usize,
    k1: &[f32],
    v1: &[f32],
    n1: usize,
    k2: &[f32],
    v2: &[f32],
    n2: usize,
    out: &mut [f32],
    scores: &mut Vec<f32>,
    fast: bool,
) {
    scores.clear();
    let mut max = f32::NEG_INFINITY;
    for s in 0..n1 {
        let kv = &k1[s * dh..(s + 1) * dh];
        let dot: f32 = qh.iter().zip(kv).map(|(a, b)| a * b).sum();
        let sc = dot * scale;
        max = max.max(sc);
        scores.push(sc);
    }
    for s in 0..n2 {
        let kv = &k2[s * dh..(s + 1) * dh];
        let dot: f32 = qh.iter().zip(kv).map(|(a, b)| a * b).sum();
        let sc = dot * scale;
        max = max.max(sc);
        scores.push(sc);
    }
    let mut z = 0.0f32;
    for sc in scores.iter_mut() {
        *sc = if fast { simd::exp_fast(*sc - max) } else { (*sc - max).exp() };
        z += *sc;
    }
    for (s, &w) in scores.iter().take(n1).enumerate() {
        let vv = &v1[s * dh..(s + 1) * dh];
        simd::axpy(w / z, vv, out);
    }
    for (s, &w) in scores[n1..].iter().enumerate() {
        let vv = &v2[s * dh..(s + 1) * dh];
        simd::axpy(w / z, vv, out);
    }
}

impl CpuModel {
    /// Load from exported params using the process-wide dispatch config
    /// (`SPECMER_WEIGHT_DTYPE` / `SPECMER_FAST`, resolved once per process).
    pub fn from_params(mp: &ModelParams, vocab: usize) -> Result<CpuModel> {
        Self::from_params_with(mp, vocab, simd::weight_dtype(), simd::fast_tier())
    }

    /// Load from exported params with an explicit weight dtype and fast-tier
    /// flag. Weights are quantized once here; the hot paths never widen them
    /// back to an f32 buffer (dequant happens in-register inside the GEMM
    /// kernels).
    pub fn from_params_with(
        mp: &ModelParams,
        vocab: usize,
        dtype: WeightDtype,
        fast: bool,
    ) -> Result<CpuModel> {
        let t = |name: &str| -> Result<Vec<f32>> { Ok(mp.tensor(name)?.0.to_vec()) };
        let d = mp.dims.d_model;
        let d_ff = mp.dims.d_ff;
        let q = |w: &[f32], k: usize, n: usize| Panel::quantize(w, k, n, dtype);
        let mut layers = Vec::new();
        for l in 0..mp.dims.n_layer {
            let p = |s: &str| format!("l{l}.{s}");
            layers.push(Layer {
                ln1_g: t(&p("ln1_g"))?,
                ln1_b: t(&p("ln1_b"))?,
                wq: q(&t(&p("wq"))?, d, d),
                wk: q(&t(&p("wk"))?, d, d),
                wv: q(&t(&p("wv"))?, d, d),
                wo: q(&t(&p("wo"))?, d, d),
                ln2_g: t(&p("ln2_g"))?,
                ln2_b: t(&p("ln2_b"))?,
                w1: q(&t(&p("w1"))?, d, d_ff),
                b1: t(&p("b1"))?,
                w2: q(&t(&p("w2"))?, d_ff, d),
                b2: t(&p("b2"))?,
            });
        }
        let tok_emb = t("tok_emb")?;
        // exact-width [D, V] panel: the column-vectorized kernels handle a
        // non-lane-multiple trailing tile themselves, so padding here would
        // only buy wasted multiply-adds against zero columns plus a per-call
        // truncation copy in `logits_rows`
        let packed = PackedWeights::pack_dtype(&tok_emb[..vocab * d], vocab, d, 1, dtype);
        Ok(CpuModel {
            name: mp.name.clone(),
            dims: mp.dims.clone(),
            vocab,
            tok_emb,
            pos_emb: t("pos_emb")?,
            layers,
            lnf_g: t("lnf_g")?,
            lnf_b: t("lnf_b")?,
            packed,
            dtype,
            fast,
            pool: BufPool::default(),
        })
    }

    /// Randomly-initialized model for tests that need a backend without
    /// artifacts (deterministic in `seed`). Uses the process-wide dispatch
    /// config like [`CpuModel::from_params`].
    pub fn synthetic(n_layer: usize, d_model: usize, n_head: usize, maxlen: usize, seed: u64) -> CpuModel {
        Self::synthetic_with(
            n_layer,
            d_model,
            n_head,
            maxlen,
            seed,
            simd::weight_dtype(),
            simd::fast_tier(),
        )
    }

    /// [`CpuModel::synthetic`] with an explicit weight dtype and fast-tier
    /// flag, so accuracy-bounded tests can build exact/fast model pairs in
    /// one process regardless of the environment.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic_with(
        n_layer: usize,
        d_model: usize,
        n_head: usize,
        maxlen: usize,
        seed: u64,
        dtype: WeightDtype,
        fast: bool,
    ) -> CpuModel {
        let vocab = crate::tokenizer::VOCAB;
        let d_ff = d_model * 4;
        let mut rng = Pcg64::new(seed);
        let mut w = |n: usize, scale: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.gaussian() * scale) as f32).collect()
        };
        let layers = (0..n_layer)
            .map(|_| Layer {
                ln1_g: vec![1.0; d_model],
                ln1_b: vec![0.0; d_model],
                wq: Panel::quantize(&w(d_model * d_model, 0.05), d_model, d_model, dtype),
                wk: Panel::quantize(&w(d_model * d_model, 0.05), d_model, d_model, dtype),
                wv: Panel::quantize(&w(d_model * d_model, 0.05), d_model, d_model, dtype),
                wo: Panel::quantize(&w(d_model * d_model, 0.05), d_model, d_model, dtype),
                ln2_g: vec![1.0; d_model],
                ln2_b: vec![0.0; d_model],
                w1: Panel::quantize(&w(d_model * d_ff, 0.05), d_model, d_ff, dtype),
                b1: vec![0.0; d_ff],
                w2: Panel::quantize(&w(d_ff * d_model, 0.05), d_ff, d_model, dtype),
                b2: vec![0.0; d_model],
            })
            .collect();
        let tok_emb = w(vocab * d_model, 0.3);
        let packed = PackedWeights::pack_dtype(&tok_emb, vocab, d_model, 1, dtype);
        CpuModel {
            name: "synthetic".into(),
            dims: ModelDims {
                n_layer,
                d_model,
                n_head,
                d_ff,
                n_params: 0,
                cache_shape: [n_layer, 2, n_head, maxlen, d_model / n_head],
            },
            vocab,
            tok_emb,
            pos_emb: w(maxlen * d_model, 0.05),
            layers,
            lnf_g: vec![1.0; d_model],
            lnf_b: vec![0.0; d_model],
            packed,
            dtype,
            fast,
            pool: BufPool::default(),
        }
    }

    /// Weight storage dtype the model was built with.
    pub fn weight_dtype(&self) -> WeightDtype {
        self.dtype
    }

    /// Whether the accuracy-bounded fast tier is active for this model.
    pub fn fast_tier(&self) -> bool {
        self.fast
    }

    /// Bytes of weight-matrix storage read per full decode forward: the
    /// per-layer projection/MLP panels plus the logits head panel. Biases
    /// and layernorm params are excluded (O(D) per layer, noise next to the
    /// O(D²) matrices). Used by `bench_micro` to derive bytes/token and
    /// effective bandwidth per dtype.
    pub fn weight_bytes(&self) -> usize {
        let per_layer: usize = self
            .layers
            .iter()
            .map(|l| {
                l.wq.weight_bytes()
                    + l.wk.weight_bytes()
                    + l.wv.weight_bytes()
                    + l.wo.weight_bytes()
                    + l.w1.weight_bytes()
                    + l.w2.weight_bytes()
            })
            .sum();
        per_layer + self.packed.weight_bytes()
    }

    pub fn empty_cache(&self) -> CpuCache {
        CpuCache::owned(vec![0.0; self.dims.cache_len()])
    }

    #[inline]
    fn cache_idx(&self, l: usize, kv: usize, h: usize, s: usize) -> usize {
        let [_, _, nh, sm, dh] = self.dims.cache_shape;
        (((l * 2 + kv) * nh + h) * sm + s) * dh
    }

    /// Teacher-forced forward of `toks` at absolute positions
    /// `pos..pos+toks.len()`, reading/writing the KV cache. All G positions
    /// are batched through each projection and the MLP as one GEMM. Returns
    /// the final hidden states as one flat [G, D] buffer.
    fn cached_forward(&self, cache: &mut CpuCache, toks: &[u8], pos: usize) -> Vec<f32> {
        assert!(
            pos + toks.len() <= self.dims.maxlen(),
            "cached_forward past maxlen: pos {pos} + {} > {} (engines must \
             leave a full block of slack — see decode::spec)",
            toks.len(),
            self.dims.maxlen()
        );
        let d = self.dims.d_model;
        let d_ff = self.dims.d_ff;
        let nh = self.dims.n_head;
        let dh = self.dims.d_head();
        let g = toks.len();
        let scale = 1.0 / (dh as f32).sqrt();

        // embed
        let mut xs = vec![0.0f32; g * d];
        for (i, &t) in toks.iter().enumerate() {
            let te = &self.tok_emb[t as usize * d..(t as usize + 1) * d];
            let pe = &self.pos_emb[(pos + i) * d..(pos + i + 1) * d];
            let row = &mut xs[i * d..(i + 1) * d];
            for j in 0..d {
                row[j] = te[j] + pe[j];
            }
        }

        // pooled workspaces (xs is the return value and stays owned)
        let mut bufs = self.pool.take();
        let mut hbuf = std::mem::take(&mut bufs.hbuf);
        let mut q = std::mem::take(&mut bufs.q);
        let mut kbuf = std::mem::take(&mut bufs.k);
        let mut vbuf = std::mem::take(&mut bufs.v);
        let mut att = std::mem::take(&mut bufs.att);
        let mut proj = std::mem::take(&mut bufs.proj);
        let mut ff = std::mem::take(&mut bufs.ff);
        let mut scores = std::mem::take(&mut bufs.scores);
        grab(&mut hbuf, g * d);
        grab(&mut q, g * d);
        grab(&mut kbuf, g * d);
        grab(&mut vbuf, g * d);
        grab(&mut att, g * d);
        grab(&mut proj, g * d);
        grab(&mut ff, g * d_ff);
        scores.clear();

        for (l, lay) in self.layers.iter().enumerate() {
            // pre-LN + batched QKV for all G positions, K/V into the cache
            hbuf.copy_from_slice(&xs);
            for i in 0..g {
                ln(&mut hbuf[i * d..(i + 1) * d], &lay.ln1_g, &lay.ln1_b);
            }
            gemm::matmul_panel(&hbuf, lay.wq.view(), g, d, d, &mut q, true, self.fast);
            gemm::matmul_panel(&hbuf, lay.wk.view(), g, d, d, &mut kbuf, true, self.fast);
            gemm::matmul_panel(&hbuf, lay.wv.view(), g, d, d, &mut vbuf, true, self.fast);
            for i in 0..g {
                for hh in 0..nh {
                    let kslot = self.cache_idx(l, 0, hh, pos + i);
                    let vslot = self.cache_idx(l, 1, hh, pos + i);
                    cache.data[kslot..kslot + dh]
                        .copy_from_slice(&kbuf[i * d + hh * dh..i * d + (hh + 1) * dh]);
                    cache.data[vslot..vslot + dh]
                        .copy_from_slice(&vbuf[i * d + hh * dh..i * d + (hh + 1) * dh]);
                }
            }
            // attention per position over cache slots <= qpos (all K/V for
            // this block were just written, so rows are independent)
            att.fill(0.0);
            for i in 0..g {
                let qpos = pos + i;
                for hh in 0..nh {
                    let qh = &q[i * d + hh * dh..i * d + (hh + 1) * dh];
                    let kbase = self.cache_idx(l, 0, hh, 0);
                    let vbase = self.cache_idx(l, 1, hh, 0);
                    let n1 = qpos + 1;
                    attend_one(
                        qh,
                        scale,
                        dh,
                        &cache.data[kbase..kbase + n1 * dh],
                        &cache.data[vbase..vbase + n1 * dh],
                        n1,
                        &[],
                        &[],
                        0,
                        &mut att[i * d + hh * dh..i * d + (hh + 1) * dh],
                        &mut scores,
                        self.fast,
                    );
                }
            }
            // out projection + residual (batched)
            gemm::matmul_panel(&att, lay.wo.view(), g, d, d, &mut proj, true, self.fast);
            simd::add_assign(&mut xs, &proj);
            // MLP (batched)
            hbuf.copy_from_slice(&xs);
            for i in 0..g {
                ln(&mut hbuf[i * d..(i + 1) * d], &lay.ln2_g, &lay.ln2_b);
            }
            gemm::matmul_panel(&hbuf, lay.w1.view(), g, d, d_ff, &mut ff, true, self.fast);
            for i in 0..g {
                let row = &mut ff[i * d_ff..(i + 1) * d_ff];
                for (j, f) in row.iter_mut().enumerate() {
                    *f = gelu_with(*f + lay.b1[j], self.fast);
                }
            }
            gemm::matmul_panel(&ff, lay.w2.view(), g, d_ff, d, &mut proj, true, self.fast);
            for i in 0..g {
                let xrow = &mut xs[i * d..(i + 1) * d];
                let prow = &proj[i * d..(i + 1) * d];
                simd::add2_assign(xrow, prow, &lay.b2);
            }
        }
        // final LN
        for i in 0..g {
            ln(&mut xs[i * d..(i + 1) * d], &self.lnf_g, &self.lnf_b);
        }
        bufs.hbuf = hbuf;
        bufs.q = q;
        bufs.k = kbuf;
        bufs.v = vbuf;
        bufs.att = att;
        bufs.proj = proj;
        bufs.ff = ff;
        bufs.scores = scores;
        self.pool.put(bufs);
        xs
    }

    /// One batched draft step: forward the `c` candidates' current tokens at
    /// absolute position `qpos`, writing K/V into tail slot `slot` and
    /// attending over the shared committed prefix plus each candidate's own
    /// tail slots `0..=slot`. Returns the next-token logits, flat [c, V].
    fn branched_step(&self, br: &mut BranchedCache, toks: &[u8], qpos: usize, slot: usize) -> Vec<f32> {
        let d = self.dims.d_model;
        let d_ff = self.dims.d_ff;
        let nh = self.dims.n_head;
        let dh = self.dims.d_head();
        let b = toks.len();
        debug_assert_eq!(b, br.c);
        debug_assert!(slot < br.gamma);
        debug_assert!(qpos < self.dims.maxlen());
        let scale = 1.0 / (dh as f32).sqrt();

        // embed: every candidate's token sits at the same absolute position
        let pe = &self.pos_emb[qpos * d..(qpos + 1) * d];
        for (ci, &t) in toks.iter().enumerate() {
            let te = &self.tok_emb[t as usize * d..(t as usize + 1) * d];
            let row = &mut br.xs[ci * d..(ci + 1) * d];
            for j in 0..d {
                row[j] = te[j] + pe[j];
            }
        }

        for (l, lay) in self.layers.iter().enumerate() {
            br.hbuf.copy_from_slice(&br.xs);
            for ci in 0..b {
                ln(&mut br.hbuf[ci * d..(ci + 1) * d], &lay.ln1_g, &lay.ln1_b);
            }
            gemm::matmul_panel(&br.hbuf, lay.wq.view(), b, d, d, &mut br.q, true, self.fast);
            gemm::matmul_panel(&br.hbuf, lay.wk.view(), b, d, d, &mut br.k, true, self.fast);
            gemm::matmul_panel(&br.hbuf, lay.wv.view(), b, d, d, &mut br.v, true, self.fast);
            // write K/V into each candidate's private tail slot
            for ci in 0..b {
                for hh in 0..nh {
                    let kb = br.tail_base(nh, dh, l, 0, ci, hh) + slot * dh;
                    let vb = br.tail_base(nh, dh, l, 1, ci, hh) + slot * dh;
                    br.tail[kb..kb + dh]
                        .copy_from_slice(&br.k[ci * d + hh * dh..ci * d + (hh + 1) * dh]);
                    br.tail[vb..vb + dh]
                        .copy_from_slice(&br.v[ci * d + hh * dh..ci * d + (hh + 1) * dh]);
                }
            }
            // attention: shared committed prefix + own tail slots 0..=slot
            br.att.fill(0.0);
            for ci in 0..b {
                for hh in 0..nh {
                    let qh = &br.q[ci * d + hh * dh..ci * d + (hh + 1) * dh];
                    let kbase = self.cache_idx(l, 0, hh, 0);
                    let vbase = self.cache_idx(l, 1, hh, 0);
                    let kt = br.tail_base(nh, dh, l, 0, ci, hh);
                    let vt = br.tail_base(nh, dh, l, 1, ci, hh);
                    attend_one(
                        qh,
                        scale,
                        dh,
                        &br.base.data[kbase..kbase + br.base_len * dh],
                        &br.base.data[vbase..vbase + br.base_len * dh],
                        br.base_len,
                        &br.tail[kt..kt + (slot + 1) * dh],
                        &br.tail[vt..vt + (slot + 1) * dh],
                        slot + 1,
                        &mut br.att[ci * d + hh * dh..ci * d + (hh + 1) * dh],
                        &mut br.scores,
                        self.fast,
                    );
                }
            }
            gemm::matmul_panel(&br.att, lay.wo.view(), b, d, d, &mut br.proj, true, self.fast);
            simd::add_assign(&mut br.xs, &br.proj);
            br.hbuf.copy_from_slice(&br.xs);
            for ci in 0..b {
                ln(&mut br.hbuf[ci * d..(ci + 1) * d], &lay.ln2_g, &lay.ln2_b);
            }
            gemm::matmul_panel(&br.hbuf, lay.w1.view(), b, d, d_ff, &mut br.ff, true, self.fast);
            for ci in 0..b {
                let row = &mut br.ff[ci * d_ff..(ci + 1) * d_ff];
                for (j, f) in row.iter_mut().enumerate() {
                    *f = gelu_with(*f + lay.b1[j], self.fast);
                }
            }
            gemm::matmul_panel(&br.ff, lay.w2.view(), b, d_ff, d, &mut br.proj, true, self.fast);
            for ci in 0..b {
                let xrow = &mut br.xs[ci * d..(ci + 1) * d];
                let prow = &br.proj[ci * d..(ci + 1) * d];
                simd::add2_assign(xrow, prow, &lay.b2);
            }
        }
        br.hbuf.copy_from_slice(&br.xs);
        for ci in 0..b {
            ln(&mut br.hbuf[ci * d..(ci + 1) * d], &self.lnf_g, &self.lnf_b);
        }
        self.logits_rows(&br.hbuf, b)
    }

    /// Forward a set of tree-node rows through all layers: `rows[i]` is a
    /// node id with token `toks[i]`, embedded at absolute position
    /// `base_len + depth[node]`; K/V land in the node's [`TreeTails`] slot
    /// and each row attends the shared committed prefix plus its gathered
    /// root-to-self ancestor rows (the tree-structured attention mask).
    /// Two call shapes share this code: drafting feeds one *level* per call
    /// (γ−1 `[F_d, D]` dispatches, ancestors persisted by earlier levels),
    /// verification feeds *every* node in one `[N, D]` tree-masked ragged
    /// dispatch (all K/V of a layer are written before any row attends, as
    /// in [`Self::cached_forward`], so ancestor visibility is satisfied
    /// within the single call). Returns next-token logits, flat
    /// [rows.len(), V].
    fn tree_step(&self, tt: &mut TreeTails, rows: &[usize], toks: &[u8]) -> Vec<f32> {
        let d = self.dims.d_model;
        let d_ff = self.dims.d_ff;
        let nh = self.dims.n_head;
        let dh = self.dims.d_head();
        let f = rows.len();
        debug_assert_eq!(f, toks.len());
        let scale = 1.0 / (dh as f32).sqrt();

        // embed: a node's token sits at the frontier + its depth
        for (i, (&node, &t)) in rows.iter().zip(toks).enumerate() {
            let qpos = tt.base_len + tt.depths[node];
            assert!(
                qpos < self.dims.maxlen(),
                "tree node past maxlen: pos {qpos} >= {} (engines must leave \
                 a full block of slack — see decode::spec)",
                self.dims.maxlen()
            );
            let te = &self.tok_emb[t as usize * d..(t as usize + 1) * d];
            let pe = &self.pos_emb[qpos * d..(qpos + 1) * d];
            let row = &mut tt.xs[i * d..(i + 1) * d];
            for j in 0..d {
                row[j] = te[j] + pe[j];
            }
        }

        for (l, lay) in self.layers.iter().enumerate() {
            tt.hbuf[..f * d].copy_from_slice(&tt.xs[..f * d]);
            for i in 0..f {
                ln(&mut tt.hbuf[i * d..(i + 1) * d], &lay.ln1_g, &lay.ln1_b);
            }
            gemm::matmul_panel(
                &tt.hbuf[..f * d],
                lay.wq.view(),
                f,
                d,
                d,
                &mut tt.q[..f * d],
                true,
                self.fast,
            );
            gemm::matmul_panel(
                &tt.hbuf[..f * d],
                lay.wk.view(),
                f,
                d,
                d,
                &mut tt.k[..f * d],
                true,
                self.fast,
            );
            gemm::matmul_panel(
                &tt.hbuf[..f * d],
                lay.wv.view(),
                f,
                d,
                d,
                &mut tt.v[..f * d],
                true,
                self.fast,
            );
            // write K/V into each node's own tail row
            for (i, &node) in rows.iter().enumerate() {
                for hh in 0..nh {
                    let kb = tt.tail_base(nh, dh, l, 0, node, hh);
                    let vb = tt.tail_base(nh, dh, l, 1, node, hh);
                    let src = i * d + hh * dh;
                    tt.tail[kb..kb + dh].copy_from_slice(&tt.k[src..src + dh]);
                    tt.tail[vb..vb + dh].copy_from_slice(&tt.v[src..src + dh]);
                }
            }
            // attention: committed prefix + gathered root-to-self ancestors
            tt.att.fill(0.0);
            for (i, &node) in rows.iter().enumerate() {
                let na = tt.anc[node].len();
                for hh in 0..nh {
                    // gather the ancestor K/V rows (root..=self, depth order)
                    // into contiguous runs; pure copies, so the two-segment
                    // attend below accumulates exactly like a chain tail
                    for (j, &aq) in tt.anc[node].iter().enumerate() {
                        let kb = tt.tail_base(nh, dh, l, 0, aq, hh);
                        let vb = tt.tail_base(nh, dh, l, 1, aq, hh);
                        tt.gk[j * dh..(j + 1) * dh].copy_from_slice(&tt.tail[kb..kb + dh]);
                        tt.gv[j * dh..(j + 1) * dh].copy_from_slice(&tt.tail[vb..vb + dh]);
                    }
                    let qh = &tt.q[i * d + hh * dh..i * d + (hh + 1) * dh];
                    let kbase = self.cache_idx(l, 0, hh, 0);
                    let vbase = self.cache_idx(l, 1, hh, 0);
                    attend_one(
                        qh,
                        scale,
                        dh,
                        &tt.base.data[kbase..kbase + tt.base_len * dh],
                        &tt.base.data[vbase..vbase + tt.base_len * dh],
                        tt.base_len,
                        &tt.gk[..na * dh],
                        &tt.gv[..na * dh],
                        na,
                        &mut tt.att[i * d + hh * dh..i * d + (hh + 1) * dh],
                        &mut tt.scores,
                        self.fast,
                    );
                }
            }
            gemm::matmul_panel(
                &tt.att[..f * d],
                lay.wo.view(),
                f,
                d,
                d,
                &mut tt.proj[..f * d],
                true,
                self.fast,
            );
            simd::add_assign(&mut tt.xs[..f * d], &tt.proj[..f * d]);
            tt.hbuf[..f * d].copy_from_slice(&tt.xs[..f * d]);
            for i in 0..f {
                ln(&mut tt.hbuf[i * d..(i + 1) * d], &lay.ln2_g, &lay.ln2_b);
            }
            gemm::matmul_panel(
                &tt.hbuf[..f * d],
                lay.w1.view(),
                f,
                d,
                d_ff,
                &mut tt.ff[..f * d_ff],
                true,
                self.fast,
            );
            for i in 0..f {
                let row = &mut tt.ff[i * d_ff..(i + 1) * d_ff];
                for (j, x) in row.iter_mut().enumerate() {
                    *x = gelu_with(*x + lay.b1[j], self.fast);
                }
            }
            gemm::matmul_panel(
                &tt.ff[..f * d_ff],
                lay.w2.view(),
                f,
                d_ff,
                d,
                &mut tt.proj[..f * d],
                true,
                self.fast,
            );
            for i in 0..f {
                let xrow = &mut tt.xs[i * d..(i + 1) * d];
                let prow = &tt.proj[i * d..(i + 1) * d];
                simd::add2_assign(xrow, prow, &lay.b2);
            }
        }
        tt.hbuf[..f * d].copy_from_slice(&tt.xs[..f * d]);
        for i in 0..f {
            ln(&mut tt.hbuf[i * d..(i + 1) * d], &self.lnf_g, &self.lnf_b);
        }
        self.logits_rows(&tt.hbuf[..f * d], f)
    }

    /// Ragged teacher-forced forward over B sequences: item `b` feeds
    /// `items[b].1` at absolute positions starting from `items[b].2`,
    /// reading/writing its *own* cache (`items[b].0`). The union of all
    /// rows (R = Σ_b G_b) goes through each projection, the MLP and the
    /// final LN as one `[R, D]` GEMM; K/V writes and attention reads stay
    /// per-sequence. Per-row arithmetic is identical to [`Self::cached_forward`]
    /// on that sequence alone (the GEMM kernels accumulate row-
    /// independently), so the result is bitwise-equal to B separate
    /// dispatches. Returns the final hidden states as one flat [R, D]
    /// buffer, rows in item order.
    fn forward_ragged(&self, items: &mut [(&mut CpuCache, &[u8], usize)]) -> Vec<f32> {
        let d = self.dims.d_model;
        let d_ff = self.dims.d_ff;
        let nh = self.dims.n_head;
        let dh = self.dims.d_head();
        let scale = 1.0 / (dh as f32).sqrt();

        // row layout: item b's rows start at row_off[b]
        let mut row_off = Vec::with_capacity(items.len());
        let mut rt = 0usize;
        for it in items.iter() {
            assert!(
                it.2 + it.1.len() <= self.dims.maxlen(),
                "ragged forward past maxlen: pos {} + {} > {} (engines must \
                 leave a full block of slack — see decode::spec)",
                it.2,
                it.1.len(),
                self.dims.maxlen()
            );
            row_off.push(rt);
            rt += it.1.len();
        }

        // embed
        let mut xs = vec![0.0f32; rt * d];
        for (b, it) in items.iter().enumerate() {
            let (toks, pos) = (it.1, it.2);
            for (i, &t) in toks.iter().enumerate() {
                let te = &self.tok_emb[t as usize * d..(t as usize + 1) * d];
                let pe = &self.pos_emb[(pos + i) * d..(pos + i + 1) * d];
                let row = &mut xs[(row_off[b] + i) * d..(row_off[b] + i + 1) * d];
                for j in 0..d {
                    row[j] = te[j] + pe[j];
                }
            }
        }

        // pooled workspaces (xs is the return value and stays owned)
        let mut bufs = self.pool.take();
        let mut hbuf = std::mem::take(&mut bufs.hbuf);
        let mut q = std::mem::take(&mut bufs.q);
        let mut kbuf = std::mem::take(&mut bufs.k);
        let mut vbuf = std::mem::take(&mut bufs.v);
        let mut att = std::mem::take(&mut bufs.att);
        let mut proj = std::mem::take(&mut bufs.proj);
        let mut ff = std::mem::take(&mut bufs.ff);
        let mut scores = std::mem::take(&mut bufs.scores);
        grab(&mut hbuf, rt * d);
        grab(&mut q, rt * d);
        grab(&mut kbuf, rt * d);
        grab(&mut vbuf, rt * d);
        grab(&mut att, rt * d);
        grab(&mut proj, rt * d);
        grab(&mut ff, rt * d_ff);
        scores.clear();

        for (l, lay) in self.layers.iter().enumerate() {
            // pre-LN + batched QKV for the union of rows
            hbuf.copy_from_slice(&xs);
            for i in 0..rt {
                ln(&mut hbuf[i * d..(i + 1) * d], &lay.ln1_g, &lay.ln1_b);
            }
            gemm::matmul_panel(&hbuf, lay.wq.view(), rt, d, d, &mut q, true, self.fast);
            gemm::matmul_panel(&hbuf, lay.wk.view(), rt, d, d, &mut kbuf, true, self.fast);
            gemm::matmul_panel(&hbuf, lay.wv.view(), rt, d, d, &mut vbuf, true, self.fast);
            // K/V into each sequence's own cache at its own positions
            for (b, it) in items.iter_mut().enumerate() {
                let (toks, pos) = (it.1, it.2);
                let cache = &mut *it.0;
                for i in 0..toks.len() {
                    let row = row_off[b] + i;
                    for hh in 0..nh {
                        let kslot = self.cache_idx(l, 0, hh, pos + i);
                        let vslot = self.cache_idx(l, 1, hh, pos + i);
                        cache.data[kslot..kslot + dh]
                            .copy_from_slice(&kbuf[row * d + hh * dh..row * d + (hh + 1) * dh]);
                        cache.data[vslot..vslot + dh]
                            .copy_from_slice(&vbuf[row * d + hh * dh..row * d + (hh + 1) * dh]);
                    }
                }
            }
            // attention per row over the owning sequence's cache
            att.fill(0.0);
            for (b, it) in items.iter().enumerate() {
                let (toks, pos) = (it.1, it.2);
                let cache = &*it.0;
                for i in 0..toks.len() {
                    let qpos = pos + i;
                    let row = row_off[b] + i;
                    for hh in 0..nh {
                        let qh = &q[row * d + hh * dh..row * d + (hh + 1) * dh];
                        let kbase = self.cache_idx(l, 0, hh, 0);
                        let vbase = self.cache_idx(l, 1, hh, 0);
                        let n1 = qpos + 1;
                        attend_one(
                            qh,
                            scale,
                            dh,
                            &cache.data[kbase..kbase + n1 * dh],
                            &cache.data[vbase..vbase + n1 * dh],
                            n1,
                            &[],
                            &[],
                            0,
                            &mut att[row * d + hh * dh..row * d + (hh + 1) * dh],
                            &mut scores,
                            self.fast,
                        );
                    }
                }
            }
            // out projection + residual (batched over the union of rows)
            gemm::matmul_panel(&att, lay.wo.view(), rt, d, d, &mut proj, true, self.fast);
            simd::add_assign(&mut xs, &proj);
            // MLP (batched)
            hbuf.copy_from_slice(&xs);
            for i in 0..rt {
                ln(&mut hbuf[i * d..(i + 1) * d], &lay.ln2_g, &lay.ln2_b);
            }
            gemm::matmul_panel(&hbuf, lay.w1.view(), rt, d, d_ff, &mut ff, true, self.fast);
            for i in 0..rt {
                let row = &mut ff[i * d_ff..(i + 1) * d_ff];
                for (j, f) in row.iter_mut().enumerate() {
                    *f = gelu_with(*f + lay.b1[j], self.fast);
                }
            }
            gemm::matmul_panel(&ff, lay.w2.view(), rt, d_ff, d, &mut proj, true, self.fast);
            for i in 0..rt {
                let xrow = &mut xs[i * d..(i + 1) * d];
                let prow = &proj[i * d..(i + 1) * d];
                simd::add2_assign(xrow, prow, &lay.b2);
            }
        }
        // final LN
        for i in 0..rt {
            ln(&mut xs[i * d..(i + 1) * d], &self.lnf_g, &self.lnf_b);
        }
        bufs.hbuf = hbuf;
        bufs.q = q;
        bufs.k = kbuf;
        bufs.v = vbuf;
        bufs.att = att;
        bufs.proj = proj;
        bufs.ff = ff;
        bufs.scores = scores;
        self.pool.put(bufs);
        xs
    }

    /// One lockstep draft step over the arena: forward every (sequence,
    /// candidate) row's current token — `cur` is flat `[B·c]` — writing K/V
    /// into tail slot `slot` and attending over each sequence's committed
    /// prefix plus the candidate's own tail slots `0..=slot`. A sequence's
    /// query position is `bases[b].1 + slot` (prefixes are ragged). Returns
    /// the next-token logits, flat [B·c, V].
    fn arena_step(&self, ar: &mut BranchedArena, cur: &[u8], slot: usize) -> Vec<f32> {
        let d = self.dims.d_model;
        let d_ff = self.dims.d_ff;
        let nh = self.dims.n_head;
        let dh = self.dims.d_head();
        let bn = ar.bases.len();
        let c = ar.c;
        let rows = bn * c;
        debug_assert_eq!(cur.len(), rows);
        debug_assert!(slot < ar.gamma);
        let scale = 1.0 / (dh as f32).sqrt();

        // embed: a row's token sits at its sequence's frontier + slot
        for b in 0..bn {
            let qpos = ar.bases[b].1 + slot;
            debug_assert!(qpos < self.dims.maxlen());
            let pe = &self.pos_emb[qpos * d..(qpos + 1) * d];
            for ci in 0..c {
                let row = b * c + ci;
                let t = cur[row] as usize;
                let te = &self.tok_emb[t * d..(t + 1) * d];
                let xrow = &mut ar.xs[row * d..(row + 1) * d];
                for j in 0..d {
                    xrow[j] = te[j] + pe[j];
                }
            }
        }

        for (l, lay) in self.layers.iter().enumerate() {
            ar.hbuf.copy_from_slice(&ar.xs);
            for r in 0..rows {
                ln(&mut ar.hbuf[r * d..(r + 1) * d], &lay.ln1_g, &lay.ln1_b);
            }
            gemm::matmul_panel(&ar.hbuf, lay.wq.view(), rows, d, d, &mut ar.q, true, self.fast);
            gemm::matmul_panel(&ar.hbuf, lay.wk.view(), rows, d, d, &mut ar.k, true, self.fast);
            gemm::matmul_panel(&ar.hbuf, lay.wv.view(), rows, d, d, &mut ar.v, true, self.fast);
            // write K/V into each (sequence, candidate) private tail slot
            for b in 0..bn {
                for ci in 0..c {
                    let row = b * c + ci;
                    for hh in 0..nh {
                        let kb = ar.tail_base(nh, dh, b, l, 0, ci, hh) + slot * dh;
                        let vb = ar.tail_base(nh, dh, b, l, 1, ci, hh) + slot * dh;
                        ar.tail[kb..kb + dh]
                            .copy_from_slice(&ar.k[row * d + hh * dh..row * d + (hh + 1) * dh]);
                        ar.tail[vb..vb + dh]
                            .copy_from_slice(&ar.v[row * d + hh * dh..row * d + (hh + 1) * dh]);
                    }
                }
            }
            // attention: own committed prefix + own tail slots 0..=slot
            ar.att.fill(0.0);
            for b in 0..bn {
                let (base, base_len) = ar.bases[b];
                for ci in 0..c {
                    let row = b * c + ci;
                    for hh in 0..nh {
                        let qh = &ar.q[row * d + hh * dh..row * d + (hh + 1) * dh];
                        let kbase = self.cache_idx(l, 0, hh, 0);
                        let vbase = self.cache_idx(l, 1, hh, 0);
                        let kt = ar.tail_base(nh, dh, b, l, 0, ci, hh);
                        let vt = ar.tail_base(nh, dh, b, l, 1, ci, hh);
                        attend_one(
                            qh,
                            scale,
                            dh,
                            &base.data[kbase..kbase + base_len * dh],
                            &base.data[vbase..vbase + base_len * dh],
                            base_len,
                            &ar.tail[kt..kt + (slot + 1) * dh],
                            &ar.tail[vt..vt + (slot + 1) * dh],
                            slot + 1,
                            &mut ar.att[row * d + hh * dh..row * d + (hh + 1) * dh],
                            &mut ar.scores,
                            self.fast,
                        );
                    }
                }
            }
            gemm::matmul_panel(&ar.att, lay.wo.view(), rows, d, d, &mut ar.proj, true, self.fast);
            simd::add_assign(&mut ar.xs, &ar.proj);
            ar.hbuf.copy_from_slice(&ar.xs);
            for r in 0..rows {
                ln(&mut ar.hbuf[r * d..(r + 1) * d], &lay.ln2_g, &lay.ln2_b);
            }
            gemm::matmul_panel(&ar.hbuf, lay.w1.view(), rows, d, d_ff, &mut ar.ff, true, self.fast);
            for r in 0..rows {
                let row = &mut ar.ff[r * d_ff..(r + 1) * d_ff];
                for (j, f) in row.iter_mut().enumerate() {
                    *f = gelu_with(*f + lay.b1[j], self.fast);
                }
            }
            gemm::matmul_panel(&ar.ff, lay.w2.view(), rows, d_ff, d, &mut ar.proj, true, self.fast);
            for r in 0..rows {
                let xrow = &mut ar.xs[r * d..(r + 1) * d];
                let prow = &ar.proj[r * d..(r + 1) * d];
                simd::add2_assign(xrow, prow, &lay.b2);
            }
        }
        ar.hbuf.copy_from_slice(&ar.xs);
        for r in 0..rows {
            ln(&mut ar.hbuf[r * d..(r + 1) * d], &self.lnf_g, &self.lnf_b);
        }
        self.logits_rows(&ar.hbuf, rows)
    }

    /// Logits from one final hidden state (weight-tied head).
    fn logits(&self, h: &[f32]) -> Vec<f32> {
        self.logits_rows(h, 1)
    }

    /// Batched weight-tied logits head: `rows` hidden states (flat [rows, D])
    /// against the prepacked `[D, V]` embedding panel in one dense GEMM
    /// (per-element accumulation order identical to the seed `matmul_nt`
    /// head). Returns flat [rows, V].
    fn logits_rows(&self, h: &[f32], rows: usize) -> Vec<f32> {
        let d = self.dims.d_model;
        let v = self.vocab;
        debug_assert_eq!(self.packed.v_pad, v, "head panel is packed at exact vocab width");
        let mut out = vec![0.0f32; rows * v];
        gemm::matmul_panel(h, self.packed.head(), rows, d, v, &mut out, false, self.fast);
        out
    }

    /// Full-sequence forward from scratch: per-position logits.
    pub fn forward_logits(&self, tokens: &[u8]) -> Vec<Vec<f32>> {
        let mut cache = self.empty_cache();
        let hidden = self.cached_forward(&mut cache, tokens, 0);
        let flat = self.logits_rows(&hidden, tokens.len());
        let v = self.vocab;
        (0..tokens.len()).map(|i| flat[i * v..(i + 1) * v].to_vec()).collect()
    }
}

impl ModelBackend for CpuModel {
    type Cache = CpuCache;

    fn maxlen(&self) -> usize {
        self.dims.maxlen()
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn supported_c(&self) -> &[usize] {
        const SUPPORTED_C: [usize; 8] = [1, 2, 3, 4, 5, 6, 7, 8];
        &SUPPORTED_C
    }
    fn supported_gamma(&self) -> &[usize] {
        const SUPPORTED_GAMMA: [usize; 16] =
            [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16];
        &SUPPORTED_GAMMA
    }

    fn prefill(&self, tokens: &[u8]) -> Result<CpuCache> {
        let mut cache = self.empty_cache();
        if tokens.len() > 1 {
            self.cached_forward(&mut cache, &tokens[..tokens.len() - 1], 0);
        }
        Ok(cache)
    }

    fn generate(
        &self,
        cache: &mut CpuCache,
        feed: &[u8],
        pos: usize,
        c: usize,
        gamma: usize,
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> Result<DraftBlock> {
        debug_assert_eq!(u.len(), c * gamma);
        let d = self.dims.d_model;
        let v = self.vocab;
        let g = feed.len();
        // feed phase always runs: the trait contract is that the cache ends
        // in the post-feed (committed) state even for a degenerate gamma
        let hidden = self.cached_forward(cache, feed, pos);
        if gamma == 0 {
            return Ok(DraftBlock { tokens: vec![Vec::new(); c], dists: vec![Vec::new(); c] });
        }
        let last_logits = self.logits(&hidden[(g - 1) * d..g * d]);
        let start = pos + g;
        assert!(
            start + gamma <= self.dims.maxlen(),
            "draft block past maxlen: start {start} + gamma {gamma} > {}",
            self.dims.maxlen()
        );

        let mut tokens = vec![vec![0u8; gamma]; c];
        let mut dists: Vec<Vec<Vec<f32>>> = (0..c).map(|_| Vec::with_capacity(gamma)).collect();

        // step 0: every candidate samples from the same post-feed dist
        let dist0 = sampling::adjust_dist(&last_logits, temp, top_p);
        let mut cur = vec![0u8; c];
        for ci in 0..c {
            let tok = sampling::sample(&dist0, u[ci * gamma]) as u8;
            tokens[ci][0] = tok;
            cur[ci] = tok;
            dists[ci].push(dist0.clone());
        }
        // steps 1..gamma: one batched [c, D] forward per step over the
        // branched cache — no full-cache clones, no per-step allocation
        if gamma > 1 {
            let mut br = BranchedCache::new(self, cache, start, c, gamma, self.pool.take());
            for gi in 1..gamma {
                let logits = self.branched_step(&mut br, &cur, start + gi - 1, gi - 1);
                for ci in 0..c {
                    let dist = sampling::adjust_dist(&logits[ci * v..(ci + 1) * v], temp, top_p);
                    let tok = sampling::sample(&dist, u[ci * gamma + gi]) as u8;
                    tokens[ci][gi] = tok;
                    cur[ci] = tok;
                    dists[ci].push(dist);
                }
            }
            self.pool.put(br.into_bufs());
        }
        Ok(DraftBlock { tokens, dists })
    }

    fn verify(
        &self,
        cache: &mut CpuCache,
        toks: &[u8],
        pos: usize,
        temp: f32,
        top_p: f32,
    ) -> Result<VerifyBlock> {
        let hidden = self.cached_forward(cache, toks, pos);
        let flat = self.logits_rows(&hidden, toks.len());
        let v = self.vocab;
        let dists = (0..toks.len())
            .map(|i| sampling::adjust_dist(&flat[i * v..(i + 1) * v], temp, top_p))
            .collect();
        Ok(VerifyBlock { dists })
    }

    /// Lockstep draft over B sequences: one ragged `[ΣG_b, D]` feed
    /// dispatch, then γ−1 arena steps of `[B·c, D]` rows. Row-independent
    /// kernels make every sequence's block bitwise-equal to a solo
    /// `generate` call on the same cache. `temp`/`top_p` are per-sequence:
    /// they only gate each row's `adjust_dist`, never a shared dispatch.
    fn generate_batch(
        &self,
        seqs: &mut [DraftSeq<'_, CpuCache>],
        c: usize,
        gamma: usize,
    ) -> Result<Vec<DraftBlock>> {
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        let d = self.dims.d_model;
        let v = self.vocab;
        let bn = seqs.len();
        // split per-sequence pieces out of the DraftSeq views: the cache
        // reborrows feed the ragged forward, the uniforms and sampling
        // params drive each sequence's own adjust/sample steps
        let mut us: Vec<&[f32]> = Vec::with_capacity(bn);
        let mut sp: Vec<(f32, f32)> = Vec::with_capacity(bn);
        let mut items: Vec<(&mut CpuCache, &[u8], usize)> = Vec::with_capacity(bn);
        for s in seqs.iter_mut() {
            debug_assert_eq!(s.u.len(), c * gamma);
            us.push(s.u);
            sp.push((s.temp, s.top_p));
            items.push((&mut *s.cache, s.feed, s.pos));
        }
        // feed phase always runs (trait contract: post-feed committed state)
        let hidden = self.forward_ragged(&mut items);
        if gamma == 0 {
            return Ok((0..bn)
                .map(|_| DraftBlock { tokens: vec![Vec::new(); c], dists: vec![Vec::new(); c] })
                .collect());
        }
        // per-sequence post-feed logits: gather each last row, one GEMM
        let mut starts = Vec::with_capacity(bn);
        let mut lasth = vec![0.0f32; bn * d];
        let mut r = 0usize;
        for (b, it) in items.iter().enumerate() {
            let g = it.1.len();
            let start = it.2 + g;
            assert!(
                start + gamma <= self.dims.maxlen(),
                "draft block past maxlen: start {start} + gamma {gamma} > {}",
                self.dims.maxlen()
            );
            starts.push(start);
            lasth[b * d..(b + 1) * d].copy_from_slice(&hidden[(r + g - 1) * d..(r + g) * d]);
            r += g;
        }
        let last_logits = self.logits_rows(&lasth, bn);

        let mut tokens: Vec<Vec<Vec<u8>>> = (0..bn).map(|_| vec![vec![0u8; gamma]; c]).collect();
        let mut dists: Vec<Vec<Vec<Vec<f32>>>> = (0..bn)
            .map(|_| (0..c).map(|_| Vec::with_capacity(gamma)).collect())
            .collect();

        // step 0: a sequence's candidates all sample from its post-feed dist
        let mut cur = vec![0u8; bn * c];
        for b in 0..bn {
            let dist0 =
                sampling::adjust_dist(&last_logits[b * v..(b + 1) * v], sp[b].0, sp[b].1);
            for ci in 0..c {
                let tok = sampling::sample(&dist0, us[b][ci * gamma]) as u8;
                tokens[b][ci][0] = tok;
                cur[b * c + ci] = tok;
                dists[b][ci].push(dist0.clone());
            }
        }
        // steps 1..gamma: one [B·c, D] arena forward per step, the arena
        // riding the per-worker buffer pool round to round
        if gamma > 1 {
            let bases: Vec<(&CpuCache, usize)> = items
                .iter()
                .zip(&starts)
                .map(|(it, &start)| (&*it.0, start))
                .collect();
            let mut ar = BranchedArena::new(self, bases, c, gamma, self.pool.take());
            if validate_on() {
                if let Err(e) = ar.debug_validate(&self.dims) {
                    panic!("SPECMER_VALIDATE: BranchedArena invariant violated: {e}");
                }
            }
            for gi in 1..gamma {
                let logits = self.arena_step(&mut ar, &cur, gi - 1);
                for b in 0..bn {
                    for ci in 0..c {
                        let row = b * c + ci;
                        let dist = sampling::adjust_dist(
                            &logits[row * v..(row + 1) * v],
                            sp[b].0,
                            sp[b].1,
                        );
                        let tok = sampling::sample(&dist, us[b][ci * gamma + gi]) as u8;
                        tokens[b][ci][gi] = tok;
                        cur[row] = tok;
                        dists[b][ci].push(dist);
                    }
                }
            }
            self.pool.put(ar.into_bufs());
        }
        Ok(tokens
            .into_iter()
            .zip(dists)
            .map(|(t, ds)| DraftBlock { tokens: t, dists: ds })
            .collect())
    }

    /// Lockstep verification: the union of all sequences' teacher-forced
    /// rows through one ragged forward and one logits GEMM. `temp`/`top_p`
    /// adjust each sequence's own rows.
    fn verify_batch(&self, seqs: &mut [VerifySeq<'_, CpuCache>]) -> Result<Vec<VerifyBlock>> {
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        let v = self.vocab;
        let mut sp: Vec<(f32, f32)> = Vec::with_capacity(seqs.len());
        let mut items: Vec<(&mut CpuCache, &[u8], usize)> = Vec::with_capacity(seqs.len());
        for s in seqs.iter_mut() {
            sp.push((s.temp, s.top_p));
            items.push((&mut *s.cache, s.toks, s.pos));
        }
        let hidden = self.forward_ragged(&mut items);
        let lens: Vec<usize> = items.iter().map(|it| it.1.len()).collect();
        let rt: usize = lens.iter().sum();
        let flat = self.logits_rows(&hidden, rt);
        let mut out = Vec::with_capacity(lens.len());
        let mut r = 0usize;
        for (b, g) in lens.into_iter().enumerate() {
            let dists = (r..r + g)
                .map(|i| sampling::adjust_dist(&flat[i * v..(i + 1) * v], sp[b].0, sp[b].1))
                .collect();
            r += g;
            out.push(VerifyBlock { dists });
        }
        Ok(out)
    }

    /// Tree draft: feed the trunk, then walk the tree level by level —
    /// one `[F_d, D]` tree dispatch per depth. A node samples from its
    /// *parent's* adjusted distribution with its own uniform `u[node]`;
    /// siblings share the parent distribution and differ only in the
    /// uniform. For chain-shaped trees (node id `ci·γ+gi`) the levels, row
    /// order and per-row adjustments coincide exactly with [`Self::generate`],
    /// so results are bitwise identical to the flat path.
    fn draft_tree(
        &self,
        cache: &mut CpuCache,
        feed: &[u8],
        pos: usize,
        parents: &[Option<usize>],
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> Result<DraftTreeBlock> {
        let n = parents.len();
        debug_assert_eq!(u.len(), n);
        let d = self.dims.d_model;
        let v = self.vocab;
        let g = feed.len();
        // feed phase always runs (trait contract: post-feed committed state)
        let hidden = self.cached_forward(cache, feed, pos);
        if n == 0 {
            return Ok(DraftTreeBlock { tokens: Vec::new(), dists: Vec::new() });
        }
        let last_logits = self.logits(&hidden[(g - 1) * d..g * d]);
        let start = pos + g;
        let dist0 = sampling::adjust_dist(&last_logits, temp, top_p);

        let mut tt = TreeTails::new(self, cache, start, parents, self.pool.take());
        if validate_on() {
            if let Err(e) = tt.debug_validate(&self.dims) {
                panic!("SPECMER_VALIDATE: TreeTails invariant violated: {e}");
            }
        }
        let gamma = tt.gamma();
        assert!(
            start + gamma <= self.dims.maxlen(),
            "draft tree past maxlen: start {start} + depth {gamma} > {}",
            self.dims.maxlen()
        );
        // nodes by depth, in node-id order (id order == candidate order for
        // chain trees — load-bearing for the bitwise flat equivalence)
        let mut levels: Vec<Vec<usize>> = vec![Vec::new(); gamma];
        for (i, &dp) in tt.depths.iter().enumerate() {
            levels[dp].push(i);
        }

        let mut tokens = vec![0u8; n];
        let mut dists: Vec<Vec<f32>> = vec![Vec::new(); n];
        // depth 0: every root samples from the shared post-feed dist
        for &r in &levels[0] {
            tokens[r] = sampling::sample(&dist0, u[r]) as u8;
            dists[r] = dist0.clone();
        }
        // depth d: feed level d−1, each child samples from its parent's row
        let mut row_ix = vec![0usize; n];
        for dp in 1..gamma {
            let toks: Vec<u8> = levels[dp - 1].iter().map(|&q| tokens[q]).collect();
            let logits = self.tree_step(&mut tt, &levels[dp - 1], &toks);
            for (ri, &q) in levels[dp - 1].iter().enumerate() {
                row_ix[q] = ri;
            }
            let pd: Vec<Vec<f32>> = (0..levels[dp - 1].len())
                .map(|ri| sampling::adjust_dist(&logits[ri * v..(ri + 1) * v], temp, top_p))
                .collect();
            for &q in &levels[dp] {
                let p = parents[q].expect("non-root node must have a parent");
                let dist = &pd[row_ix[p]];
                tokens[q] = sampling::sample(dist, u[q]) as u8;
                dists[q] = dist.clone();
            }
        }
        self.pool.put(tt.into_bufs());
        Ok(DraftTreeBlock { tokens, dists })
    }

    /// Tree verification: feed the trunk into the committed cache, then
    /// teacher-force *every* tree node in one tree-masked ragged `[N, D]`
    /// dispatch (the ancestor-visible mask is realized by the per-row K/V
    /// gather in [`Self::tree_step`]). Node K/V stays in round-scratch tail
    /// slots — only the trunk advances the committed cache, which is the
    /// [`ModelBackend::verify_tree`] cache contract.
    fn verify_tree(
        &self,
        cache: &mut CpuCache,
        trunk: &[u8],
        pos: usize,
        tree: &TokenTree,
        temp: f32,
        top_p: f32,
    ) -> Result<VerifyTreeBlock> {
        tree.validate()?;
        let d = self.dims.d_model;
        let v = self.vocab;
        let t = trunk.len();
        debug_assert!(t > 0, "verify_tree needs a non-empty trunk");
        let hidden = self.cached_forward(cache, trunk, pos);
        let last_logits = self.logits(&hidden[(t - 1) * d..t * d]);
        let root_dist = sampling::adjust_dist(&last_logits, temp, top_p);
        let n = tree.len();
        if n == 0 {
            return Ok(VerifyTreeBlock { root_dist, dists: Vec::new() });
        }
        let start = pos + t;
        let mut tt = TreeTails::new(self, cache, start, &tree.parents, self.pool.take());
        if validate_on() {
            if let Err(e) = tt.debug_validate(&self.dims) {
                panic!("SPECMER_VALIDATE: TreeTails invariant violated: {e}");
            }
        }
        assert!(
            start + tt.gamma() <= self.dims.maxlen(),
            "verify tree past maxlen: start {start} + depth {} > {}",
            tt.gamma(),
            self.dims.maxlen()
        );
        let rows: Vec<usize> = (0..n).collect();
        let flat = self.tree_step(&mut tt, &rows, &tree.tokens);
        self.pool.put(tt.into_bufs());
        let dists = (0..n)
            .map(|q| sampling::adjust_dist(&flat[q * v..(q + 1) * v], temp, top_p))
            .collect();
        Ok(VerifyTreeBlock { root_dist, dists })
    }

    fn score(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        let logits = self.forward_logits(tokens);
        let mut nll = vec![0.0f32; tokens.len()];
        for i in 1..tokens.len() {
            let p = sampling::softmax(&logits[i - 1], 1.0);
            nll[i] = -(p[tokens[i] as usize].max(1e-12)).ln();
        }
        Ok(nll)
    }

    fn cache_to_host(&self, cache: &CpuCache) -> Result<Vec<f32>> {
        Ok(cache.data.clone())
    }

    fn cache_from_host(&self, data: &[f32]) -> Result<CpuCache> {
        Ok(CpuCache::owned(data.to_vec()))
    }

    fn prefill_begin(&self) -> Option<CpuCache> {
        Some(self.empty_cache())
    }

    fn prefill_chunked(&self, cache: &mut CpuCache, toks: &[u8], pos: usize) -> Result<()> {
        // the kernels are row-count-independent, so feeding a prefill in
        // chunks is bit-identical to the one-shot forward (pinned below)
        if !toks.is_empty() {
            self.cached_forward(cache, toks, pos);
        }
        Ok(())
    }

    fn prefill_into(&self, host: &std::sync::Arc<Vec<f32>>) -> Result<CpuCache> {
        if host.len() != self.dims.cache_len() {
            anyhow::bail!(
                "prefill_into: snapshot of {} floats does not fit cache of {}",
                host.len(),
                self.dims.cache_len()
            );
        }
        Ok(CpuCache::attached(std::sync::Arc::clone(host)))
    }

    fn embed(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        let mut cache = self.empty_cache();
        let hidden = self.cached_forward(&mut cache, tokens, 0);
        let d = self.dims.d_model;
        let g = tokens.len();
        let mut out = vec![0.0f32; d];
        for i in 0..g {
            let row = &hidden[i * d..(i + 1) * d];
            for j in 0..d {
                out[j] += row[j];
            }
        }
        let n = g.max(1) as f32;
        out.iter_mut().for_each(|x| *x /= n);
        Ok(out)
    }
}

/// The seed (pre-batching) scalar implementation, kept operation-for-
/// operation as the equivalence oracle and bench baseline: per-position
/// mat-vecs through every projection, and candidate drafting that clones
/// the full KV cache per candidate per round. Never used on a hot path —
/// `tests/cpu_batched_equivalence.rs` pins the batched forward to it, and
/// `bench_micro` measures the draft-round speedup against it.
pub mod reference {
    use super::*;

    /// The oracle runs on the exact f32 tier only: equivalence pins compare
    /// the batched hot path against this scalar path bitwise, which is only
    /// meaningful when both read identical f32 weights.
    fn pf(p: &Panel) -> &[f32] {
        p.f32_slice()
            .expect("reference oracle requires the f32 weight tier (unset SPECMER_WEIGHT_DTYPE)")
    }

    /// Seed scalar LayerNorm, kept independent of [`super::simd`] so the
    /// oracle cannot inherit a bug from the vectorized helpers it exists
    /// to check (the hot path's `ln` shares `simd::ln_apply`).
    fn ln_scalar(x: &mut [f32], g: &[f32], b: &[f32]) {
        let d = x.len();
        let mu: f32 = x.iter().sum::<f32>() / d as f32;
        let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for i in 0..d {
            x[i] = (x[i] - mu) * inv * g[i] + b[i];
        }
    }

    /// y[j] += Σ_i x[i] * w[i*cols + j]  (row-major [rows, cols])
    fn matvec_acc(x: &[f32], w: &[f32], y: &mut [f32]) {
        let cols = y.len();
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &w[i * cols..(i + 1) * cols];
            for j in 0..cols {
                y[j] += xi * row[j];
            }
        }
    }

    fn matvec(x: &[f32], w: &[f32], cols: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; cols];
        matvec_acc(x, w, &mut y);
        y
    }

    /// Seed teacher-forced forward: per-position scalar mat-vecs. Returns
    /// the final hidden state per input position [G][D].
    pub fn cached_forward(m: &CpuModel, cache: &mut CpuCache, toks: &[u8], pos: usize) -> Vec<Vec<f32>> {
        assert!(pos + toks.len() <= m.dims.maxlen());
        let d = m.dims.d_model;
        let nh = m.dims.n_head;
        let dh = m.dims.d_head();
        let g = toks.len();
        let scale = 1.0 / (dh as f32).sqrt();

        let mut xs: Vec<Vec<f32>> = toks
            .iter()
            .enumerate()
            .map(|(i, &t)| {
                let te = &m.tok_emb[t as usize * d..(t as usize + 1) * d];
                let pe = &m.pos_emb[(pos + i) * d..(pos + i + 1) * d];
                te.iter().zip(pe).map(|(a, b)| a + b).collect()
            })
            .collect();

        for (l, lay) in m.layers.iter().enumerate() {
            let mut qs: Vec<Vec<f32>> = Vec::with_capacity(g);
            for (i, x) in xs.iter().enumerate() {
                let mut h = x.clone();
                ln_scalar(&mut h, &lay.ln1_g, &lay.ln1_b);
                let q = matvec(&h, pf(&lay.wq), d);
                let k = matvec(&h, pf(&lay.wk), d);
                let v = matvec(&h, pf(&lay.wv), d);
                for hh in 0..nh {
                    let kslot = m.cache_idx(l, 0, hh, pos + i);
                    let vslot = m.cache_idx(l, 1, hh, pos + i);
                    cache.data[kslot..kslot + dh].copy_from_slice(&k[hh * dh..(hh + 1) * dh]);
                    cache.data[vslot..vslot + dh].copy_from_slice(&v[hh * dh..(hh + 1) * dh]);
                }
                qs.push(q);
            }
            for (i, x) in xs.iter_mut().enumerate() {
                let qpos = pos + i;
                let mut att_out = vec![0.0f32; d];
                for hh in 0..nh {
                    let qh = &qs[i][hh * dh..(hh + 1) * dh];
                    let mut scores = Vec::with_capacity(qpos + 1);
                    let mut max = f32::NEG_INFINITY;
                    for s in 0..=qpos {
                        let kslot = m.cache_idx(l, 0, hh, s);
                        let kv = &cache.data[kslot..kslot + dh];
                        let dot: f32 = qh.iter().zip(kv).map(|(a, b)| a * b).sum();
                        let sc = dot * scale;
                        max = max.max(sc);
                        scores.push(sc);
                    }
                    let mut z = 0.0f32;
                    for sc in scores.iter_mut() {
                        *sc = (*sc - max).exp();
                        z += *sc;
                    }
                    let out = &mut att_out[hh * dh..(hh + 1) * dh];
                    for (s, &w) in scores.iter().enumerate() {
                        let vslot = m.cache_idx(l, 1, hh, s);
                        let vv = &cache.data[vslot..vslot + dh];
                        let wz = w / z;
                        for j in 0..dh {
                            out[j] += wz * vv[j];
                        }
                    }
                }
                let proj = matvec(&att_out, pf(&lay.wo), d);
                for j in 0..d {
                    x[j] += proj[j];
                }
                let mut h = x.clone();
                ln_scalar(&mut h, &lay.ln2_g, &lay.ln2_b);
                let mut ff = matvec(&h, pf(&lay.w1), m.dims.d_ff);
                for (j, f) in ff.iter_mut().enumerate() {
                    *f = gelu(*f + lay.b1[j]);
                }
                let mut out2 = matvec(&ff, pf(&lay.w2), d);
                for j in 0..d {
                    out2[j] += lay.b2[j];
                    x[j] += out2[j];
                }
            }
        }
        for x in xs.iter_mut() {
            ln_scalar(x, &m.lnf_g, &m.lnf_b);
        }
        xs
    }

    /// Seed scalar logits head.
    pub fn logits(m: &CpuModel, h: &[f32]) -> Vec<f32> {
        let d = m.dims.d_model;
        (0..m.vocab)
            .map(|t| {
                let te = &m.tok_emb[t * d..(t + 1) * d];
                h.iter().zip(te).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    /// Seed full-sequence forward.
    pub fn forward_logits(m: &CpuModel, tokens: &[u8]) -> Vec<Vec<f32>> {
        let mut cache = m.empty_cache();
        let hidden = cached_forward(m, &mut cache, tokens, 0);
        hidden.iter().map(|h| logits(m, h)).collect()
    }

    /// Seed candidate drafting: one full KV-cache clone per candidate and a
    /// scalar single-token forward per (candidate, step).
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        m: &CpuModel,
        cache: &mut CpuCache,
        feed: &[u8],
        pos: usize,
        c: usize,
        gamma: usize,
        u: &[f32],
        temp: f32,
        top_p: f32,
    ) -> DraftBlock {
        let hidden = cached_forward(m, cache, feed, pos);
        let last_logits = logits(m, hidden.last().unwrap());
        let start = pos + feed.len();

        let mut tokens = vec![vec![0u8; gamma]; c];
        let mut dists: Vec<Vec<Vec<f32>>> = (0..c).map(|_| Vec::with_capacity(gamma)).collect();
        for ci in 0..c {
            // each candidate branches from the committed cache (full clone)
            let mut cc = CpuCache::owned(cache.data.clone());
            let mut lg = last_logits.clone();
            for gi in 0..gamma {
                let dist = sampling::adjust_dist(&lg, temp, top_p);
                let tok = sampling::sample(&dist, u[ci * gamma + gi]) as u8;
                tokens[ci][gi] = tok;
                dists[ci].push(dist);
                let h = cached_forward(m, &mut cc, &[tok], start + gi);
                lg = logits(m, &h[0]);
            }
        }
        DraftBlock { tokens, dists }
    }

    /// Seed teacher-forced verification.
    pub fn verify(
        m: &CpuModel,
        cache: &mut CpuCache,
        toks: &[u8],
        pos: usize,
        temp: f32,
        top_p: f32,
    ) -> VerifyBlock {
        let hidden = cached_forward(m, cache, toks, pos);
        let dists = hidden
            .iter()
            .map(|h| sampling::adjust_dist(&logits(m, h), temp, top_p))
            .collect();
        VerifyBlock { dists }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CpuModel {
        CpuModel::synthetic(2, 16, 2, 32, 42)
    }

    #[test]
    fn cached_equals_fresh_forward() {
        let m = tiny();
        let seq: Vec<u8> = vec![1, 5, 9, 13, 7, 4, 20];
        let full = m.forward_logits(&seq);
        // incremental: prefill 4 (feeds 3), then feed the rest one by one
        let mut cache = m.prefill(&seq[..4]).unwrap();
        let mut got = Vec::new();
        for i in 3..seq.len() {
            let h = m.cached_forward(&mut cache, &seq[i..i + 1], i);
            got.push(m.logits(&h));
        }
        for (i, g) in got.iter().enumerate() {
            let f = &full[3 + i];
            for (a, b) in g.iter().zip(f) {
                assert!((a - b).abs() < 1e-4, "pos {} mismatch {a} vs {b}", 3 + i);
            }
        }
    }

    #[test]
    fn verify_dists_are_normalized() {
        let m = tiny();
        let mut cache = m.prefill(&[1, 5, 9]).unwrap();
        let vb = m.verify(&mut cache, &[9, 4, 6, 8], 2, 1.0, 0.95).unwrap();
        assert_eq!(vb.dists.len(), 4);
        for d in &vb.dists {
            let s: f32 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn generate_respects_c_and_gamma() {
        let m = tiny();
        let mut cache = m.prefill(&[1, 5, 9]).unwrap();
        let u: Vec<f32> = (0..3 * 4).map(|i| (i as f32 * 0.37) % 1.0).collect();
        let db = m.generate(&mut cache, &[9], 2, 3, 4, &u, 1.0, 0.95).unwrap();
        assert_eq!(db.tokens.len(), 3);
        assert_eq!(db.tokens[0].len(), 4);
        assert_eq!(db.dists[0].len(), 4);
        // sampled token must have nonzero prob in its dist
        for ci in 0..3 {
            for gi in 0..4 {
                assert!(db.dists[ci][gi][db.tokens[ci][gi] as usize] > 0.0);
            }
        }
    }

    #[test]
    fn same_uniforms_same_candidates() {
        let m = tiny();
        let mut c1 = m.prefill(&[1, 5, 9]).unwrap();
        let mut c2 = m.prefill(&[1, 5, 9]).unwrap();
        let u: Vec<f32> = (0..10).map(|i| (i as f32 * 0.13) % 1.0).collect();
        let a = m.generate(&mut c1, &[9], 2, 2, 5, &u, 0.8, 0.9).unwrap();
        let b = m.generate(&mut c2, &[9], 2, 2, 5, &u, 0.8, 0.9).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn batched_draft_matches_seed_reference() {
        // the tentpole invariant at unit level: branched-cache drafting
        // reproduces the clone-per-candidate seed path exactly
        let m = tiny();
        let mut c1 = m.prefill(&[1, 5, 9, 13]).unwrap();
        let mut c2 = m.prefill(&[1, 5, 9, 13]).unwrap();
        let u: Vec<f32> = (0..3 * 5).map(|i| (i as f32 * 0.29) % 1.0).collect();
        let a = m.generate(&mut c1, &[13], 3, 3, 5, &u, 0.9, 0.95).unwrap();
        let b = reference::generate(&m, &mut c2, &[13], 3, 3, 5, &u, 0.9, 0.95);
        assert_eq!(a.tokens, b.tokens);
        for (da, db) in a.dists.iter().zip(&b.dists) {
            for (pa, pb) in da.iter().zip(db) {
                for (x, y) in pa.iter().zip(pb) {
                    assert!((x - y).abs() < 1e-6, "{x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn pooled_round_buffers_do_not_change_results() {
        // repeated identical calls ride the warm buffer pool; handout
        // re-zeroing must keep them bitwise-equal to the first (cold) call
        let m = tiny();
        let u: Vec<f32> = (0..3 * 5).map(|i| (i as f32 * 0.29) % 1.0).collect();
        let mut c1 = m.prefill(&[1, 5, 9, 13]).unwrap();
        let a = m.generate(&mut c1, &[13], 3, 3, 5, &u, 0.9, 0.95).unwrap();
        let mut c2 = m.prefill(&[1, 5, 9, 13]).unwrap();
        let b = m.generate(&mut c2, &[13], 3, 3, 5, &u, 0.9, 0.95).unwrap();
        assert_eq!(a.tokens, b.tokens);
        for (da, db) in a.dists.iter().zip(&b.dists) {
            for (pa, pb) in da.iter().zip(db) {
                assert_eq!(pa, pb, "pooled round diverged bitwise");
            }
        }
    }

    #[test]
    fn score_zero_at_origin_positive_after() {
        let m = tiny();
        let nll = m.score(&[1, 5, 9, 13]).unwrap();
        assert_eq!(nll[0], 0.0);
        assert!(nll[1..].iter().all(|&x| x > 0.0));
    }

    #[test]
    fn embed_shape() {
        let m = tiny();
        let e = m.embed(&[1, 5, 9]).unwrap();
        assert_eq!(e.len(), 16);
    }

    #[test]
    fn generate_batch_matches_solo_generate_per_sequence() {
        // lockstep over ragged prefixes == B independent draft rounds
        let m = tiny();
        let ctxs: Vec<Vec<u8>> = vec![vec![1, 5, 9, 13], vec![1, 7], vec![1, 5, 9, 13, 7, 4]];
        let (c, gamma) = (3usize, 4usize);
        let us: Vec<Vec<f32>> = (0..ctxs.len())
            .map(|b| (0..c * gamma).map(|i| ((b * 31 + i * 7) as f32 * 0.113) % 1.0).collect())
            .collect();

        // solo path
        let mut solo = Vec::new();
        for (b, ctx) in ctxs.iter().enumerate() {
            let mut cache = m.prefill(ctx).unwrap();
            let pos = ctx.len() - 1;
            let feed = vec![ctx[pos]];
            solo.push(m.generate(&mut cache, &feed, pos, c, gamma, &us[b], 0.9, 0.95).unwrap());
        }

        // lockstep path
        let mut caches: Vec<CpuCache> = ctxs.iter().map(|ctx| m.prefill(ctx).unwrap()).collect();
        let feeds: Vec<Vec<u8>> = ctxs.iter().map(|ctx| vec![*ctx.last().unwrap()]).collect();
        let mut seqs: Vec<DraftSeq<'_, CpuCache>> = Vec::new();
        for ((cache, ctx), (feed, u)) in
            caches.iter_mut().zip(&ctxs).zip(feeds.iter().zip(&us))
        {
            seqs.push(DraftSeq { cache, feed, pos: ctx.len() - 1, u, temp: 0.9, top_p: 0.95 });
        }
        let blocks = m.generate_batch(&mut seqs, c, gamma).unwrap();

        assert_eq!(blocks.len(), solo.len());
        for (b, (got, want)) in blocks.iter().zip(&solo).enumerate() {
            assert_eq!(got.tokens, want.tokens, "seq {b} tokens diverged");
            for (dg, dw) in got.dists.iter().zip(&want.dists) {
                for (pg, pw) in dg.iter().zip(dw) {
                    for (x, y) in pg.iter().zip(pw) {
                        assert!((x - y).abs() <= 1e-6, "seq {b}: {x} vs {y}");
                    }
                }
            }
        }
    }

    #[test]
    fn verify_batch_matches_solo_verify_and_caches_agree() {
        let m = tiny();
        let ctxs: Vec<Vec<u8>> = vec![vec![1, 5, 9], vec![1, 5, 9, 13, 7]];
        let vtokss: Vec<Vec<u8>> = vec![vec![9, 4, 6, 8], vec![7, 2, 11]];

        let mut solo_caches: Vec<CpuCache> =
            ctxs.iter().map(|ctx| m.prefill(ctx).unwrap()).collect();
        let mut solo = Vec::new();
        for ((cache, ctx), vtoks) in solo_caches.iter_mut().zip(&ctxs).zip(&vtokss) {
            solo.push(m.verify(cache, vtoks, ctx.len() - 1, 1.0, 0.95).unwrap());
        }

        let mut caches: Vec<CpuCache> = ctxs.iter().map(|ctx| m.prefill(ctx).unwrap()).collect();
        let mut seqs: Vec<VerifySeq<'_, CpuCache>> = Vec::new();
        for ((cache, ctx), vtoks) in caches.iter_mut().zip(&ctxs).zip(&vtokss) {
            seqs.push(VerifySeq { cache, toks: vtoks, pos: ctx.len() - 1, temp: 1.0, top_p: 0.95 });
        }
        let got = m.verify_batch(&mut seqs).unwrap();

        for (b, (g, w)) in got.iter().zip(&solo).enumerate() {
            assert_eq!(g.dists.len(), w.dists.len());
            for (dg, dw) in g.dists.iter().zip(&w.dists) {
                for (x, y) in dg.iter().zip(dw) {
                    assert!((x - y).abs() <= 1e-6, "seq {b}: {x} vs {y}");
                }
            }
        }
        for (b, (cg, cw)) in caches.iter().zip(&solo_caches).enumerate() {
            for (x, y) in cg.data.iter().zip(&cw.data) {
                assert!((x - y).abs() <= 1e-6, "seq {b} cache diverged: {x} vs {y}");
            }
        }
    }

    #[test]
    fn verify_then_reverify_overlapping_positions() {
        // stale-slot rewrite: verify 5 tokens, then re-verify from an
        // earlier position; dists must match a fresh forward.
        let m = tiny();
        let seq: Vec<u8> = vec![1, 5, 9, 13, 7, 4, 20, 11, 2, 6];
        let mut cache = m.prefill(&seq[..4]).unwrap();
        let _ = m.verify(&mut cache, &seq[3..9], 3, 1.0, 1.0).unwrap();
        // pretend only 2 of those were accepted: re-verify from pos 5
        let vb = m.verify(&mut cache, &seq[5..10], 5, 1.0, 1.0).unwrap();
        let full = m.forward_logits(&seq);
        for (i, d) in vb.dists.iter().enumerate() {
            let expect = sampling::adjust_dist(&full[5 + i], 1.0, 1.0);
            for (a, b) in d.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-4, "pos {} {a} vs {b}", 5 + i);
            }
        }
    }

    /// Chain-per-root parent table: node `ci * gamma + gi`, the id layout
    /// that must line a degenerate tree up with flat candidate blocks.
    fn chain_parents(c: usize, gamma: usize) -> Vec<Option<usize>> {
        let mut parents = Vec::with_capacity(c * gamma);
        for ci in 0..c {
            for gi in 0..gamma {
                parents.push(if gi == 0 { None } else { Some(ci * gamma + gi - 1) });
            }
        }
        parents
    }

    #[test]
    fn chain_draft_tree_matches_flat_generate_bitwise() {
        // the tentpole invariant at unit level: chain-shaped trees through
        // TreeTails reproduce the flat branched-cache draft bit for bit
        let m = tiny();
        let (c, gamma) = (3usize, 4usize);
        let u: Vec<f32> = (0..c * gamma).map(|i| (i as f32 * 0.37) % 1.0).collect();
        let mut c1 = m.prefill(&[1, 5, 9, 13]).unwrap();
        let flat = m.generate(&mut c1, &[13], 3, c, gamma, &u, 0.9, 0.95).unwrap();
        let mut c2 = m.prefill(&[1, 5, 9, 13]).unwrap();
        let parents = chain_parents(c, gamma);
        let tree = m.draft_tree(&mut c2, &[13], 3, &parents, &u, 0.9, 0.95).unwrap();
        for ci in 0..c {
            for gi in 0..gamma {
                let q = ci * gamma + gi;
                assert_eq!(tree.tokens[q], flat.tokens[ci][gi], "node {q} token diverged");
                assert_eq!(tree.dists[q], flat.dists[ci][gi], "node {q} dist diverged bitwise");
            }
        }
        assert_eq!(c1.data, c2.data, "committed caches diverged");
    }

    #[test]
    fn chain_verify_tree_matches_flat_verify_bitwise() {
        let m = tiny();
        let ctx = [1u8, 5, 9];
        let chain = [4u8, 6, 8, 2];
        let trunk = [9u8]; // re-feed the last committed token
        let pos = 2;
        let mut c1 = m.prefill(&ctx).unwrap();
        let mut toks = trunk.to_vec();
        toks.extend_from_slice(&chain);
        let flat = m.verify(&mut c1, &toks, pos, 1.0, 0.95).unwrap();

        let mut c2 = m.prefill(&ctx).unwrap();
        let tree = TokenTree { parents: chain_parents(1, chain.len()), tokens: chain.to_vec() };
        let got = m.verify_tree(&mut c2, &trunk, pos, &tree, 1.0, 0.95).unwrap();
        assert_eq!(got.root_dist, flat.dists[0], "root dist diverged bitwise");
        for depth in 0..chain.len() {
            assert_eq!(got.dists[depth], flat.dists[1 + depth], "depth {depth} diverged");
        }
        // only the trunk may advance the committed cache: the tree cache must
        // equal one where nothing but the trunk was ever verified
        let mut c3 = m.prefill(&ctx).unwrap();
        let _ = m.verify(&mut c3, &trunk, pos, 1.0, 0.95).unwrap();
        assert_eq!(c2.data, c3.data, "verify_tree leaked node KV into the cache");
    }

    /// CpuModel minus its tree overrides: drives the trait-default
    /// linearizations (chain-per-leaf draft, path-per-verify) instead.
    struct Linearized<'a>(&'a CpuModel);

    impl ModelBackend for Linearized<'_> {
        type Cache = CpuCache;
        fn maxlen(&self) -> usize {
            self.0.maxlen()
        }
        fn vocab(&self) -> usize {
            self.0.vocab()
        }
        fn supported_c(&self) -> &[usize] {
            self.0.supported_c()
        }
        fn supported_gamma(&self) -> &[usize] {
            self.0.supported_gamma()
        }
        fn prefill(&self, tokens: &[u8]) -> Result<CpuCache> {
            self.0.prefill(tokens)
        }
        #[allow(clippy::too_many_arguments)]
        fn generate(
            &self,
            cache: &mut CpuCache,
            feed: &[u8],
            pos: usize,
            c: usize,
            gamma: usize,
            u: &[f32],
            temp: f32,
            top_p: f32,
        ) -> Result<DraftBlock> {
            self.0.generate(cache, feed, pos, c, gamma, u, temp, top_p)
        }
        fn verify(
            &self,
            cache: &mut CpuCache,
            toks: &[u8],
            pos: usize,
            temp: f32,
            top_p: f32,
        ) -> Result<VerifyBlock> {
            self.0.verify(cache, toks, pos, temp, top_p)
        }
        fn score(&self, tokens: &[u8]) -> Result<Vec<f32>> {
            self.0.score(tokens)
        }
        fn cache_to_host(&self, cache: &CpuCache) -> Result<Vec<f32>> {
            self.0.cache_to_host(cache)
        }
        fn cache_from_host(&self, data: &[f32]) -> Result<CpuCache> {
            self.0.cache_from_host(data)
        }
    }

    #[test]
    fn branched_tree_matches_default_linearization() {
        // a genuinely branching tree: 1 root, depth 4, split at depth 2
        //   0 - 1 - 2 - 3
        //         \ 4 - 5
        let m = tiny();
        let parents = vec![None, Some(0), Some(1), Some(2), Some(1), Some(4)];
        let u: Vec<f32> = (0..parents.len()).map(|i| (i as f32 * 0.31 + 0.07) % 1.0).collect();

        let mut c1 = m.prefill(&[1, 5, 9, 13]).unwrap();
        let native = m.draft_tree(&mut c1, &[13], 3, &parents, &u, 0.9, 0.95).unwrap();
        let lin = Linearized(&m);
        let mut c2 = m.prefill(&[1, 5, 9, 13]).unwrap();
        let folded = lin.draft_tree(&mut c2, &[13], 3, &parents, &u, 0.9, 0.95).unwrap();
        assert_eq!(native.tokens, folded.tokens, "draft tokens diverged");
        for (q, (a, b)) in native.dists.iter().zip(&folded.dists).enumerate() {
            assert_eq!(a, b, "node {q} draft dist diverged");
        }
        assert_eq!(c1.data, c2.data, "draft caches diverged");

        // verify the drafted tree both ways (same trunk, same cache state)
        let tree = TokenTree { parents, tokens: native.tokens.clone() };
        let nat_v = m.verify_tree(&mut c1, &[13], 3, &tree, 0.9, 0.95).unwrap();
        let lin_v = lin.verify_tree(&mut c2, &[13], 3, &tree, 0.9, 0.95).unwrap();
        assert_eq!(nat_v.root_dist, lin_v.root_dist, "root dist diverged");
        for (q, (a, b)) in nat_v.dists.iter().zip(&lin_v.dists).enumerate() {
            assert_eq!(a, b, "node {q} verify dist diverged");
        }
        assert_eq!(c1.data, c2.data, "verify caches diverged");
    }

    #[test]
    fn draft_tree_tokens_lie_in_parent_dists() {
        // sampled node tokens must have nonzero mass in the dist they were
        // drawn from, branching or not
        let m = tiny();
        let parents = vec![None, Some(0), Some(1), Some(1), None, Some(4), Some(5), Some(5)];
        let u: Vec<f32> = (0..parents.len()).map(|i| (i as f32 * 0.23 + 0.11) % 1.0).collect();
        let mut cache = m.prefill(&[1, 5, 9, 13]).unwrap();
        let tb = m.draft_tree(&mut cache, &[13], 3, &parents, &u, 1.0, 0.95).unwrap();
        assert_eq!(tb.tokens.len(), parents.len());
        for q in 0..parents.len() {
            assert!(tb.dists[q][tb.tokens[q] as usize] > 0.0, "node {q}");
            let s: f32 = tb.dists[q].iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "node {q} dist not normalized");
        }
        // siblings share the parent dist but differ in uniforms
        assert_eq!(tb.dists[2], tb.dists[3], "siblings must share the parent dist");
    }

    // ---- seeded-corruption tests: each mutates exactly one invariant and
    // asserts debug_validate trips with a message naming that invariant ----

    fn fresh_cache(m: &CpuModel) -> CpuCache {
        CpuCache::owned(vec![0.0; m.dims.cache_len()])
    }

    #[test]
    fn tree_validator_clean_then_parent_cycle() {
        let m = tiny();
        let cache = fresh_cache(&m);
        let parents = vec![None, Some(0), Some(1)];
        let mut tt = TreeTails::new(&m, &cache, 4, &parents, m.pool.take());
        assert_eq!(tt.debug_validate(&m.dims), Ok(()));
        // corrupt: node 1 now claims a later node as parent — a back-edge
        // that would make the parent table cyclic
        tt.parents[1] = Some(2);
        let err = tt.debug_validate(&m.dims).unwrap_err();
        assert!(err.contains("parent-pointer order"), "got: {err}");
    }

    #[test]
    fn tree_validator_trips_on_depth_and_chain_corruption() {
        let m = tiny();
        let cache = fresh_cache(&m);
        let parents = vec![None, Some(0), Some(1)];
        let mut tt = TreeTails::new(&m, &cache, 4, &parents, m.pool.take());
        tt.depths[2] = 5;
        let err = tt.debug_validate(&m.dims).unwrap_err();
        assert!(err.contains("depth accounting"), "got: {err}");
        tt.depths[2] = 2;
        tt.anc[2] = vec![2];
        let err = tt.debug_validate(&m.dims).unwrap_err();
        assert!(err.contains("ancestor-chain"), "got: {err}");
    }

    #[test]
    fn tree_validator_trips_on_stale_kv_rows() {
        let m = tiny();
        let cache = fresh_cache(&m);
        let parents = vec![None, Some(0), Some(1)];
        // committed length 30 + tree depth 3 overruns maxlen 32: the KV row
        // count the table believes is committed is stale
        let tt = TreeTails::new(&m, &cache, 30, &parents, m.pool.take());
        let err = tt.debug_validate(&m.dims).unwrap_err();
        assert!(err.contains("KV row accounting"), "got: {err}");
    }

    #[test]
    fn tree_validator_trips_on_tail_truncation() {
        let m = tiny();
        let cache = fresh_cache(&m);
        let parents = vec![None, Some(0)];
        let mut tt = TreeTails::new(&m, &cache, 4, &parents, m.pool.take());
        let n = tt.tail.len();
        tt.tail.truncate(n - 1);
        let err = tt.debug_validate(&m.dims).unwrap_err();
        assert!(err.contains("tail sizing"), "got: {err}");
    }

    #[test]
    fn arena_validator_clean_then_corrupted() {
        let m = tiny();
        let c1 = fresh_cache(&m);
        let c2 = fresh_cache(&m);
        let bases: Vec<(&CpuCache, usize)> = vec![(&c1, 4), (&c2, 6)];
        let mut ar = BranchedArena::new(&m, bases, 2, 3, m.pool.take());
        assert_eq!(ar.debug_validate(&m.dims), Ok(()));
        // corrupt: stride no longer matches L*2*c*H*gamma*Dh
        ar.seq_stride += 1;
        let err = ar.debug_validate(&m.dims).unwrap_err();
        assert!(err.contains("seq_stride"), "got: {err}");
    }

    #[test]
    fn arena_validator_trips_on_stale_committed_length() {
        let m = tiny();
        let c1 = fresh_cache(&m);
        let bases: Vec<(&CpuCache, usize)> = vec![(&c1, 31)];
        // committed length 31 + gamma 3 overruns maxlen 32
        let ar = BranchedArena::new(&m, bases, 1, 3, m.pool.take());
        let err = ar.debug_validate(&m.dims).unwrap_err();
        assert!(err.contains("KV row accounting"), "got: {err}");
    }

    // ---- chunked prefill and copy-on-write snapshot attach ----

    #[test]
    fn chunked_prefill_bitwise_matches_one_shot() {
        let m = tiny();
        let ctx: Vec<u8> = vec![1, 5, 9, 13, 6, 7, 8, 9, 10, 11];
        let one_shot = m.prefill(&ctx).unwrap();
        // feed the first n-1 tokens in ragged chunks at round boundaries
        for chunk in [1usize, 2, 3, 7] {
            let mut cache = m.prefill_begin().expect("cpu backend chunks");
            let feed = &ctx[..ctx.len() - 1];
            let mut pos = 0;
            while pos < feed.len() {
                let end = (pos + chunk).min(feed.len());
                m.prefill_chunked(&mut cache, &feed[pos..end], pos).unwrap();
                pos = end;
            }
            assert_eq!(
                cache.data, one_shot.data,
                "chunk={chunk}: chunked prefill must be bit-identical to one-shot"
            );
        }
    }

    #[test]
    fn attached_snapshot_shares_until_first_write() {
        use std::sync::Arc;
        let m = tiny();
        let ctx: Vec<u8> = vec![1, 5, 9, 13];
        let cold = m.prefill(&ctx).unwrap();
        let snap = Arc::new(m.cache_to_host(&cold).unwrap());
        let mut warm = m.prefill_into(&snap).unwrap();
        assert!(warm.data.is_shared(), "attach must not copy");
        assert_eq!(warm.data, cold.data, "attached bits equal the cold prefill");
        // decode writes detach and never touch the snapshot
        let u: Vec<f32> = (0..4).map(|i| (i as f32 * 0.17 + 0.03) % 1.0).collect();
        let a = m.generate(&mut warm, &[13], 3, 2, 2, &u, 1.0, 0.95).unwrap();
        assert!(!warm.data.is_shared(), "first write detaches");
        let mut solo = m.prefill(&ctx).unwrap();
        let b = m.generate(&mut solo, &[13], 3, 2, 2, &u, 1.0, 0.95).unwrap();
        assert_eq!(a.tokens, b.tokens, "warm-attached draft diverged from cold");
        assert_eq!(warm.data, solo.data, "post-write caches diverged");
        assert_eq!(
            *snap,
            m.cache_to_host(&cold).unwrap(),
            "snapshot must be untouched by the detached writer"
        );
        // oversized/undersized snapshots are refused
        let bad = Arc::new(vec![0.0f32; 3]);
        assert!(m.prefill_into(&bad).is_err());
    }
}
