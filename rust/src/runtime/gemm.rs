//! Blocked, SIMD-dispatched GEMM kernels for the pure-Rust runtime.
//!
//! The batched draft/verify paths funnel every projection (`[B,D]×[D,N]`,
//! weights row-major `[in, out]`) and — via the prepacked `[D, V]` head
//! panel ([`crate::params::PackedWeights`]) — the weight-tied logits head
//! through [`matmul`]/[`matmul_dense`], so all `c` candidate rows — or all
//! `G` teacher-forced feed positions — share one streaming pass over each
//! weight matrix instead of `B` scalar mat-vecs.
//!
//! # Kernel tiers
//!
//! Both entry points dispatch once per process ([`super::simd::active`]):
//! an explicit AVX2 arm (register-tiled 4-row × 16-column micro-kernel,
//! separate mul + add — never FMA) when the CPU supports it, and a portable
//! chunked-lane arm that is the same code path on every architecture.
//! `SPECMER_FORCE_PORTABLE` pins the portable arm for CI. The seed scalar
//! kernels are kept verbatim ([`matmul_scalar`], [`matmul_dense_scalar`],
//! [`matmul_nt`]) as the equivalence oracle and bench baseline.
//!
//! # Properties the rest of the runtime relies on
//!
//!   * **Bitwise-stable accumulation.** Each output element accumulates
//!     over the shared `k` dimension strictly in index order with a single
//!     accumulator, exactly like the seed scalar mat-vec (including its
//!     skip of zero inputs; the `_dense` variants match the seed logits
//!     head, which has no skip). Vector lanes run across *independent
//!     output columns* and every multiply-accumulate is a separate IEEE
//!     mul then add, so all tiers — and row partitioning across threads —
//!     are bit-identical to the per-position reference path.
//!     `tests/cpu_batched_equivalence.rs` and `tests/kernel_equivalence.rs`
//!     assert this.
//!   * **Bounded threading.** Row-parallelism (via
//!     [`crate::util::threadpool::parallel_chunks_mut`], running on the
//!     persistent [`crate::util::threadpool::compute_pool`] rather than
//!     per-call thread spawns) only kicks in past a FLOP threshold, so tiny
//!     test models never pay threading overhead. The thread budget is
//!     resolved once per process (`SPECMER_THREADS` overrides it).

use super::simd::{self, Kernel};
use crate::util::threadpool::{compute_threads, parallel_chunks_mut};

/// 2·m·k·n below this runs single-threaded (pool handoff ≫ work).
const PAR_FLOPS: usize = 1 << 22;

/// Threads worth engaging for an `m × k × n` product.
fn plan_threads(m: usize, k: usize, n: usize) -> usize {
    if 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n) < PAR_FLOPS {
        1
    } else {
        compute_threads().min(m)
    }
}

/// `out[m,n] = a[m,k] × b[k,n]`, `b` row-major `[k,n]` (projection weights),
/// with the seed mat-vec's skip of exactly-zero inputs. Overwrites `out`.
/// Rows are partitioned across the persistent compute pool for large shapes.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        rows_dispatch(simd::active(), a, b, k, n, out, true);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    parallel_chunks_mut(out, rows_per * n, |ci, chunk| {
        let r0 = ci * rows_per;
        let rows = chunk.len() / n;
        rows_dispatch(simd::active(), &a[r0 * k..(r0 + rows) * k], b, k, n, chunk, true);
    });
}

/// [`matmul`] without the zero-input skip: accumulation per element matches
/// the seed weight-tied logits head (a plain dot product over `k`). Used
/// with the prepacked `[D, V]` embedding panel.
pub fn matmul_dense(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        rows_dispatch(simd::active(), a, b, k, n, out, false);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    parallel_chunks_mut(out, rows_per * n, |ci, chunk| {
        let r0 = ci * rows_per;
        let rows = chunk.len() / n;
        rows_dispatch(simd::active(), &a[r0 * k..(r0 + rows) * k], b, k, n, chunk, false);
    });
}

/// Single-threaded [`matmul`] on the active kernel arm (benches).
pub fn matmul_st(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    matmul_st_with(simd::active(), a, b, m, k, n, out)
}

/// Single-threaded [`matmul`] on an explicit kernel arm (tests compare the
/// arms bitwise; an AVX2 request on a machine without it runs portable).
pub fn matmul_st_with(
    kernel: Kernel,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    rows_dispatch(kernel, a, b, k, n, out, true);
}

/// Single-threaded [`matmul_dense`] on the active kernel arm (benches).
pub fn matmul_dense_st(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    matmul_dense_st_with(simd::active(), a, b, m, k, n, out)
}

/// Single-threaded [`matmul_dense`] on an explicit kernel arm.
pub fn matmul_dense_st_with(
    kernel: Kernel,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    rows_dispatch(kernel, a, b, k, n, out, false);
}

/// Row-block kernel dispatch (see module docs for the tier map).
fn rows_dispatch(
    kernel: Kernel,
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    out: &mut [f32],
    skip: bool,
) {
    match kernel {
        Kernel::Avx2 => rows_avx2(a, b, k, n, out, skip),
        Kernel::Portable => portable::matmul_rows(a, b, k, n, out, skip),
    }
}

#[cfg(target_arch = "x86_64")]
fn rows_avx2(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32], skip: bool) {
    if simd::has_avx2() {
        // SAFETY: AVX2 support was just confirmed at runtime.
        unsafe { avx2::matmul_rows(a, b, k, n, out, skip) }
    } else {
        portable::matmul_rows(a, b, k, n, out, skip)
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn rows_avx2(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32], skip: bool) {
    portable::matmul_rows(a, b, k, n, out, skip)
}

/// The seed scalar mat-vec, kept verbatim (per-row streaming passes with
/// the zero-input skip): equivalence oracle and bench baseline for the
/// vectorized arms. Single-threaded by design.
pub fn matmul_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        orow.fill(0.0);
        for (i, &x) in arow.iter().enumerate() {
            if x == 0.0 {
                continue; // the seed mat-vec's sparse-input skip
            }
            let brow = &b[i * n..(i + 1) * n];
            for (o, &w) in orow.iter_mut().zip(brow) {
                *o += x * w;
            }
        }
    }
}

/// [`matmul_scalar`] without the zero-input skip: the seed logits head's
/// accumulation order on a pre-transposed panel. Oracle for the `_dense`
/// vectorized arms.
pub fn matmul_dense_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        orow.fill(0.0);
        for (i, &x) in arow.iter().enumerate() {
            let brow = &b[i * n..(i + 1) * n];
            for (o, &w) in orow.iter_mut().zip(brow) {
                *o += x * w;
            }
        }
    }
}

/// `out[m,n] = a[m,k] × b[n,k]ᵀ` — the seed weight-tied logits head (`b` is
/// the token-embedding table, row-major `[vocab, d]`). Contiguous row-row
/// dot products; `k` accumulates in order. **No longer on the hot path**:
/// the runtime prepacks the embedding into `[D, V]` at model load and runs
/// the head through [`matmul_dense`], which accumulates in the identical
/// per-element order. Kept as the oracle and bench baseline for that claim.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        for t in 0..n {
            let brow = &b[t * k..(t + 1) * k];
            let mut acc = 0.0f32;
            for (x, w) in arow.iter().zip(brow) {
                acc += x * w;
            }
            out[r * n + t] = acc;
        }
    }
}

/// Portable chunked-lane arm: the same code path on every architecture.
/// Column tiles of [`simd::LANES`] accumulators stay in registers across
/// the whole `k` loop (the seed kernel re-loaded and re-stored the output
/// tile on every `k` step), with `k` strictly in index order per element.
mod portable {
    use crate::runtime::simd::LANES;

    pub fn matmul_rows(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32], skip: bool) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        for r in 0..rows {
            let arow = &a[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            let mut jb = 0usize;
            while jb + LANES <= n {
                let mut acc = [0.0f32; LANES];
                for (i, &x) in arow.iter().enumerate() {
                    if skip && x == 0.0 {
                        continue;
                    }
                    let btile = &b[i * n + jb..i * n + jb + LANES];
                    for (l, acc_l) in acc.iter_mut().enumerate() {
                        *acc_l += x * btile[l];
                    }
                }
                orow[jb..jb + LANES].copy_from_slice(&acc);
                jb += LANES;
            }
            if jb < n {
                tail_cols(arow, b, n, jb, &mut orow[jb..], skip);
            }
        }
    }

    /// Scalar tail for the `n % LANES` trailing columns (same `i` order).
    pub fn tail_cols(arow: &[f32], b: &[f32], n: usize, jb: usize, out: &mut [f32], skip: bool) {
        out.fill(0.0);
        for (i, &x) in arow.iter().enumerate() {
            if skip && x == 0.0 {
                continue;
            }
            let btile = &b[i * n + jb..i * n + n];
            for (o, &w) in out.iter_mut().zip(btile) {
                *o += x * w;
            }
        }
    }
}

/// AVX2 arm: register-tiled micro-kernel, 4 rows × 16 columns of
/// accumulators held in ymm registers across the whole `k` loop. Every
/// accumulate is `_mm256_add_ps(acc, _mm256_mul_ps(x, b))` — separate mul
/// and add, never `fmadd`, because fusing rounds once where the seed scalar
/// path rounds twice and would break bitwise equivalence.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_rows(
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
        skip: bool,
    ) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        let mut r = 0usize;
        while r + 4 <= rows {
            row_block4(&a[r * k..(r + 4) * k], b, k, n, &mut out[r * n..(r + 4) * n], skip);
            r += 4;
        }
        while r < rows {
            row_block1(&a[r * k..(r + 1) * k], b, k, n, &mut out[r * n..(r + 1) * n], skip);
            r += 1;
        }
    }

    /// 4 rows × 16 columns per tile: 8 ymm accumulators, each weight tile
    /// loaded once and reused by all four rows.
    #[target_feature(enable = "avx2")]
    unsafe fn row_block4(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32], skip: bool) {
        let mut jb = 0usize;
        while jb + 16 <= n {
            let mut acc = [_mm256_setzero_ps(); 8];
            for i in 0..k {
                // in-bounds: jb + 16 <= n, so i*n + jb + 16 <= (i+1)*n <= k*n
                let b0 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb));
                let b1 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb + 8));
                for rr in 0..4 {
                    let x = *a.get_unchecked(rr * k + i);
                    if skip && x == 0.0 {
                        continue; // per-(row, i) skip, same as the seed path
                    }
                    let xv = _mm256_set1_ps(x);
                    acc[rr * 2] = _mm256_add_ps(acc[rr * 2], _mm256_mul_ps(xv, b0));
                    acc[rr * 2 + 1] = _mm256_add_ps(acc[rr * 2 + 1], _mm256_mul_ps(xv, b1));
                }
            }
            for rr in 0..4 {
                _mm256_storeu_ps(out.as_mut_ptr().add(rr * n + jb), acc[rr * 2]);
                _mm256_storeu_ps(out.as_mut_ptr().add(rr * n + jb + 8), acc[rr * 2 + 1]);
            }
            jb += 16;
        }
        while jb + 8 <= n {
            let mut acc = [_mm256_setzero_ps(); 4];
            for i in 0..k {
                let b0 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb));
                for (rr, acc_r) in acc.iter_mut().enumerate() {
                    let x = *a.get_unchecked(rr * k + i);
                    if skip && x == 0.0 {
                        continue;
                    }
                    *acc_r = _mm256_add_ps(*acc_r, _mm256_mul_ps(_mm256_set1_ps(x), b0));
                }
            }
            for (rr, acc_r) in acc.iter().enumerate() {
                _mm256_storeu_ps(out.as_mut_ptr().add(rr * n + jb), *acc_r);
            }
            jb += 8;
        }
        if jb < n {
            for rr in 0..4 {
                super::portable::tail_cols(
                    &a[rr * k..(rr + 1) * k],
                    b,
                    n,
                    jb,
                    &mut out[rr * n + jb..rr * n + n],
                    skip,
                );
            }
        }
    }

    /// Single-row kernel for the `rows % 4` remainder.
    #[target_feature(enable = "avx2")]
    unsafe fn row_block1(
        arow: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
        skip: bool,
    ) {
        let mut jb = 0usize;
        while jb + 16 <= n {
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            for i in 0..k {
                let x = *arow.get_unchecked(i);
                if skip && x == 0.0 {
                    continue;
                }
                let xv = _mm256_set1_ps(x);
                let b0 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb));
                let b1 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb + 8));
                acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(xv, b0));
                acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(xv, b1));
            }
            _mm256_storeu_ps(out.as_mut_ptr().add(jb), acc0);
            _mm256_storeu_ps(out.as_mut_ptr().add(jb + 8), acc1);
            jb += 16;
        }
        while jb + 8 <= n {
            let mut acc = _mm256_setzero_ps();
            for i in 0..k {
                let x = *arow.get_unchecked(i);
                if skip && x == 0.0 {
                    continue;
                }
                let xv = _mm256_set1_ps(x);
                let b0 = _mm256_loadu_ps(b.as_ptr().add(i * n + jb));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, b0));
            }
            _mm256_storeu_ps(out.as_mut_ptr().add(jb), acc);
            jb += 8;
        }
        if jb < n {
            super::portable::tail_cols(arow, b, n, jb, &mut out[jb..], skip);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg64;

    fn randv(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..n).map(|_| (rng.gaussian() * 0.5) as f32).collect()
    }

    /// Same per-element accumulation order as the kernels: i in order.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for r in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for i in 0..k {
                    acc += a[r * k + i] * b[i * n + j];
                }
                out[r * n + j] = acc;
            }
        }
        out
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn matches_naive_bitwise_across_shapes() {
        let mut rng = Pcg64::new(11);
        for &(m, k, n) in &[(1, 16, 16), (3, 7, 300), (5, 64, 64), (8, 33, 257), (2, 1, 1)] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut out = vec![0.0f32; m * n];
            matmul(&a, &b, m, k, n, &mut out);
            let want = naive(&a, &b, m, k, n);
            assert!(bits_eq(&out, &want), "({m},{k},{n}) not bitwise equal");
        }
    }

    #[test]
    fn parallel_path_matches_naive() {
        // 2*m*k*n >= PAR_FLOPS so the row-partitioned path engages.
        let (m, k, n) = (64, 64, 600);
        let mut rng = Pcg64::new(3);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut out = vec![0.0f32; m * n];
        matmul(&a, &b, m, k, n, &mut out);
        let want = naive(&a, &b, m, k, n);
        assert!(bits_eq(&out, &want));
    }

    #[test]
    fn nt_matches_transposed_naive() {
        let (m, k, n) = (4, 24, 32);
        let mut rng = Pcg64::new(7);
        let a = randv(m * k, &mut rng);
        let bt = randv(n * k, &mut rng); // [n, k]
        let mut b = vec![0.0f32; k * n]; // [k, n]
        for t in 0..n {
            for i in 0..k {
                b[i * n + t] = bt[t * k + i];
            }
        }
        let mut out = vec![0.0f32; m * n];
        matmul_nt(&a, &bt, m, k, n, &mut out);
        let want = naive(&a, &b, m, k, n);
        for (x, y) in out.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn zero_rows_and_inputs_are_safe() {
        let b = [1.0f32, 2.0, 3.0, 4.0];
        let mut empty: [f32; 0] = [];
        matmul(&[], &b, 0, 2, 2, &mut empty);
        let a = [0.0f32, 1.0, 0.0, 2.0];
        let mut o = vec![0.0f32; 4];
        // [2,2] x [2,2]: zero inputs exercise the skip branch
        matmul(&a, &b, 2, 2, 2, &mut o);
        assert_eq!(o, vec![3.0, 4.0, 6.0, 8.0]);
    }

    /// The tentpole invariant at kernel level: the AVX2 arm, the portable
    /// arm, and the seed scalar kernel are bitwise-identical across
    /// randomized shapes — including non-multiple-of-lane widths, the
    /// 4-row block boundary, and exact-zero inputs (the skip edge).
    #[test]
    fn dispatch_arms_bitwise_equal_proptest() {
        check("matmul arms bitwise equal", 80, |g| {
            let m = g.usize_in(1..10);
            let k = g.usize_in(1..40);
            let n = g.usize_in(1..70);
            // ~30% exact zeros exercise the skip edge on every arm
            let a: Vec<f32> = (0..m * k)
                .map(|_| {
                    if g.f64_in(0.0..1.0) < 0.3 {
                        0.0
                    } else {
                        g.f64_in(-2.0..2.0) as f32
                    }
                })
                .collect();
            let b: Vec<f32> = (0..k * n).map(|_| g.f64_in(-2.0..2.0) as f32).collect();

            let mut scalar = vec![0.0f32; m * n];
            matmul_scalar(&a, &b, m, k, n, &mut scalar);
            for kernel in [Kernel::Avx2, Kernel::Portable] {
                let mut got = vec![0.0f32; m * n];
                matmul_st_with(kernel, &a, &b, m, k, n, &mut got);
                assert!(bits_eq(&got, &scalar), "{kernel:?} skip ({m},{k},{n})");
            }

            let mut scalar_d = vec![0.0f32; m * n];
            matmul_dense_scalar(&a, &b, m, k, n, &mut scalar_d);
            for kernel in [Kernel::Avx2, Kernel::Portable] {
                let mut got = vec![0.0f32; m * n];
                matmul_dense_st_with(kernel, &a, &b, m, k, n, &mut got);
                assert!(bits_eq(&got, &scalar_d), "{kernel:?} dense ({m},{k},{n})");
            }
        });
    }

    /// Row partitioning across the persistent pool must not change bits
    /// (chunks are whole rows; each element keeps its serial accumulator).
    #[test]
    fn parallel_rows_bitwise_equal_single_thread() {
        // 2*16*256*520 > PAR_FLOPS: the pool path engages (given >1 thread)
        let (m, k, n) = (16, 256, 520);
        let mut rng = Pcg64::new(29);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut par = vec![0.0f32; m * n];
        matmul(&a, &b, m, k, n, &mut par);
        let mut st = vec![0.0f32; m * n];
        matmul_st(&a, &b, m, k, n, &mut st);
        assert!(bits_eq(&par, &st), "row partitioning changed bits");
        let mut par_d = vec![0.0f32; m * n];
        matmul_dense(&a, &b, m, k, n, &mut par_d);
        let mut st_d = vec![0.0f32; m * n];
        matmul_dense_st(&a, &b, m, k, n, &mut st_d);
        assert!(bits_eq(&par_d, &st_d), "dense row partitioning changed bits");
    }
}
